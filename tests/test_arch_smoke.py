"""Per-architecture smoke tests: REDUCED config, one forward/train step on
CPU, asserting output shapes and no NaNs (the brief's required smoke tier).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ASSIGNED_ARCHS, ShapeConfig, get_config
from repro.models.model_zoo import build_model
from repro.parallel.ctx import SINGLE
from repro.parallel.runner import resolve_cell, run_pipeline


def _mk_inputs(cfg, B, S, key):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    labels = jnp.roll(tokens, -1, axis=1)
    context = None
    if cfg.cross_attn is not None:
        nctx = (cfg.n_frames if cfg.encoder_layers
                else cfg.cross_attn.n_context_tokens)
        context = jax.random.normal(key, (B, nctx, cfg.d_model), jnp.bfloat16)
    return tokens, labels, context


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_train_step(arch):
    cfg = get_config(arch).reduced()
    mdef = build_model(cfg)
    B, S = 2, 256
    shape = ShapeConfig("smoke", S, B, "train")
    cell = resolve_cell(mdef, shape, data_size=1, model_size=1,
                        overrides=dict(n_chunks=2, grad_accum=1))
    key = jax.random.PRNGKey(0)
    stage_p = mdef.init_stage_params(key, 0, 1, jnp.bfloat16)
    g = mdef.init_globals(key, jnp.bfloat16)
    tokens, labels, context = _mk_inputs(cfg, B, S, key)

    def loss_fn(stage_p, g):
        out = run_pipeline(cell, SINGLE, stage_p, g, tokens, labels, context,
                           with_loss=True)
        return out["loss"] / jnp.maximum(out["denom"], 1.0), out

    (loss, out), grads = jax.jit(
        lambda s, gg: jax.value_and_grad(loss_fn, argnums=(0, 1),
                                         has_aux=True)(s, gg))(stage_p, g)
    loss = float(loss)
    # a fresh init should sit near ln(vocab)
    assert 0.5 * np.log(cfg.vocab_size) < loss < 2.5 * np.log(cfg.vocab_size)
    assert np.isfinite(loss)
    # last-chunk hidden has the right shard shape
    last = out["last_x"]
    assert last.shape[0] == B and last.shape[2] == cfg.d_model
    gn = sum(float(jnp.sum(jnp.square(x.astype(jnp.float32))))
             for x in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ["qwen2-7b", "zamba2-7b", "rwkv6-3b",
                                  "deepseek-v3-671b", "whisper-tiny"])
def test_reduced_decode_step(arch):
    """Prefill then one decode step; asserts finite logits + cache growth."""
    cfg = get_config(arch).reduced()
    mdef = build_model(cfg)
    B, S = 2, 64
    from repro.models.transformer import ChunkMeta
    from repro.core.offload import null_tag

    key = jax.random.PRNGKey(1)
    stage_p = mdef.init_stage_params(key, 0, 1, jnp.float32)
    g = mdef.init_globals(key, jnp.float32)
    tokens, _, context = _mk_inputs(cfg, B, S, key)
    if context is not None:
        context = context.astype(jnp.float32)  # match the fp32 params
    shape = ShapeConfig("smoke_pre", S, B, "prefill")
    cell = resolve_cell(mdef, shape, data_size=1, model_size=1,
                        overrides=dict(n_chunks=1, offload=False,
                                       remat="none"))
    import dataclasses
    cell = dataclasses.replace(cell, dtype=jnp.float32)
    out = jax.jit(lambda sp, gg: run_pipeline(
        cell, SINGLE, sp, gg, tokens, tokens, context,
        with_loss=False))(stage_p, g)
    state = out["state"]

    meta = ChunkMeta(q_pos=jnp.full((1,), S, jnp.int32), cache_off=0,
                     kv_view=cell.cache_loc, tag=null_tag, decode=True,
                     my_slot=jnp.int32(S))
    new_tok = jnp.full((B, 1), 5, jnp.int32)

    def dec(sp, gg, st):
        x = mdef.embed(gg, new_tok, jnp.full((1,), S, jnp.int32), SINGLE,
                       decode=True)
        x, st, _ = mdef.stage_apply(sp, st, x, SINGLE, meta, gg,
                                    offload=False, remat="none")
        return mdef.head_logits(gg, x, SINGLE), st

    logits, state2 = jax.jit(dec)(stage_p, g, state)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
