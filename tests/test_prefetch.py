"""Prefetch="ahead" H2D seam tests (DESIGN.md §12).

The tick-level custom_vjp seam must be numerically invisible — loss and
gradients identical to the autodiff placement ("sync") across pipeline
depths and offload ratios — while changing only *where* the backward
reloads sit: the measured §5.2 peak may never rise (one-slot staging
invariant) and the priced exposed-H2D over measured bytes/windows must
strictly drop.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.base import ShapeConfig, get_config
from repro.models.model_zoo import build_model
from repro.parallel.ctx import SINGLE
from repro.parallel.runner import resolve_cell, run_pipeline
from repro.runtime import memledger as ml

ALPHAS = (1.0, 0.7, 0.5, 0.0)   # full / fractional / fractional / reserved


def _mk_cell(mdef, *, pp, prefetch, alphas=ALPHAS, data_size=4,
             model_size=2, seq=256, batch=4, offload=True):
    shape = ShapeConfig("t", seq, batch, "train")
    cell = resolve_cell(
        mdef, shape, data_size=data_size, model_size=model_size,
        overrides=dict(pp=pp, dp=data_size // pp, n_chunks=len(alphas),
                       grad_accum=1, partition="length", offload=offload,
                       prefetch=prefetch))
    cell = dataclasses.replace(cell, dtype=jnp.float32)
    if offload:
        cell = dataclasses.replace(cell, alphas=tuple(alphas))
    return cell


def _tokens(cfg, B=4, S=256):
    key = jax.random.PRNGKey(7)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return tokens, jnp.roll(tokens, -1, axis=1)


def _loss_grads_pp1(mdef, cfg, alpha_set, prefetch):
    tokens, labels = _tokens(cfg, B=2)
    key = jax.random.PRNGKey(0)
    sp = mdef.init_stage_params(key, 0, 1, jnp.float32)
    g = mdef.init_globals(key, jnp.float32)
    cell = resolve_cell(
        mdef, ShapeConfig("t", 256, 2, "train"), data_size=1, model_size=1,
        overrides=dict(n_chunks=len(alpha_set), grad_accum=1, offload=True,
                       partition="length", prefetch=prefetch))
    cell = dataclasses.replace(cell, dtype=jnp.float32,
                               alphas=tuple(alpha_set))

    def loss(sp_, g_):
        out = run_pipeline(cell, SINGLE, sp_, g_, tokens, labels, None,
                           with_loss=True)
        return out["loss"] / jnp.maximum(out["denom"], 1.0)

    return jax.jit(jax.value_and_grad(loss, argnums=(0, 1)))(sp, g)


def _loss_grads_pp2(mdef, cfg, alpha_set, prefetch):
    tokens, labels = _tokens(cfg)
    cell = _mk_cell(mdef, pp=2, prefetch=prefetch, alphas=alpha_set)
    fn, args = ml.build_step(cell, data_size=4, model_size=2,
                             tokens=tokens, labels=labels)
    return jax.jit(fn)(*args)


# ---------------------------------------------------------------------------
# (a) numerics: ahead == sync, across pp and deployed ratios
# ---------------------------------------------------------------------------


@settings(max_examples=4, deadline=None)
@given(st.sampled_from([1, 2]), st.sampled_from([0.0, 0.45, 1.0]))
def test_ahead_vs_sync_loss_and_grads_match(pp, alpha):
    """The seam's capture/inject replay is a gradient-exact restructuring:
    loss and every gradient leaf agree with the autodiff placement to
    <= 1e-5 fp32 — at pp 1 and 2, for α of 0, fractional, and 1."""
    if pp == 2 and len(jax.devices()) < 8:
        pytest.skip("needs 8 fake CPU devices")
    cfg = get_config("qwen2-7b").reduced()
    mdef = build_model(cfg)
    alpha_set = (alpha, alpha, alpha, 0.0)
    run = _loss_grads_pp1 if pp == 1 else _loss_grads_pp2
    l_a, g_a = run(mdef, cfg, alpha_set, "ahead")
    l_s, g_s = run(mdef, cfg, alpha_set, "sync")
    np.testing.assert_allclose(float(l_a), float(l_s), rtol=0, atol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(g_a),
                    jax.tree_util.tree_leaves(g_s)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0, atol=1e-5)


# ---------------------------------------------------------------------------
# (b) staging-buffer invariant + strict exposed-H2D reduction
# ---------------------------------------------------------------------------


def test_ahead_peak_bounded_and_exposed_h2d_reduced(eight_devices):
    """Measured on the same cell: prefetch='ahead' may not raise the §5.2
    ledger peak (the link carries exactly one staged chunk), and the priced
    exposed-H2D over the measured bytes/backward-windows must be strictly
    below 'sync' (every reload is fully exposed there) — the memgate's
    ablation contract at test scale."""
    cfg = get_config("qwen2-7b").reduced()
    mdef = build_model(cfg)
    led_a = ml.measure(_mk_cell(mdef, pp=2, prefetch="ahead"),
                       data_size=4, model_size=2, baseline=False)
    led_s = ml.measure(_mk_cell(mdef, pp=2, prefetch="sync"),
                       data_size=4, model_size=2, baseline=False)
    assert led_a.peak_bytes <= led_s.peak_bytes
    assert led_a.runtime_coverage_ok() and led_s.runtime_coverage_ok()
    # identical measured byte channel: the seam moves reloads, not bytes
    assert [r.off_bytes for r in led_a.ticks] == \
        [r.off_bytes for r in led_s.ticks]
    assert led_a.h2d_exposed_s is not None
    assert led_s.h2d_exposed_s is not None
    assert led_a.h2d_exposed_s < led_s.h2d_exposed_s
    # sync exposes every reload in full: sum(off_bytes)/bw
    from repro.core import costmodel as cm
    want = sum(r.off_bytes for r in led_s.ticks) / cm.V5E.d2h_bw
    assert led_s.h2d_exposed_s == pytest.approx(want)


def test_prediction_uses_quantized_alphas(eight_devices):
    """The analytic side discretizes α by the deployed row split
    (offload.quantized_alpha), so measured == predicted off-bytes exactly
    even where round(rows·α) drifts from rows·α."""
    cfg = get_config("qwen2-7b").reduced()
    mdef = build_model(cfg)
    # α = 0.01 on 32 local rows quantizes to 0 rows — the old max(1, ...)
    # floor forced 1 row off-device while the continuous prediction assumed
    # 0.32 rows; both sides now agree on exactly 0
    cell = _mk_cell(mdef, pp=2, prefetch="ahead",
                    alphas=(0.01, 0.7, 0.5, 0.0))
    led = ml.measure(cell, data_size=4, model_size=2, baseline=False)
    assert led.ticks[0].off_bytes == 0
    from repro.core import offload as ofl
    lloc = 256 // 4 // 2
    assert ofl.quantized_alpha(lloc, 0.01) == 0.0
    assert led.peak_bytes <= 1.1 * ml.predicted_spmd_peak(cell)


# ---------------------------------------------------------------------------
# (c) h2d_stall CSV round trip
# ---------------------------------------------------------------------------


def test_h2d_stall_csv_round_trip(tmp_path):
    led = ml.MemLedger(alphas=(0.5, 0.0))
    led.ticks = [
        ml.TickRow(tick=0, chunk=0, valid=True, alpha=0.5, mat_bytes=100,
                   off_bytes=50, resident=100, bwd_t=2.0),
        ml.TickRow(tick=1, chunk=1, valid=True, alpha=0.0, mat_bytes=100,
                   off_bytes=0, resident=200, bwd_t=1.0),
    ]
    led.prefetch = "ahead"
    total = led.price_h2d(bw=100.0)
    # tick 0's reload (0.5s) hides fully under tick 1's backward (1.0s
    # window); tick 1 offloads nothing — everything hidden
    assert total == 0.0
    # counterfactual pricing must not corrupt the stored channel
    sync_total = led.price_h2d(bw=100.0, prefetch="sync")
    assert sync_total == pytest.approx(0.5)
    assert led.h2d_exposed_s == 0.0
    assert [r.h2d_stall_s for r in led.ticks] == [0.0, 0.0]
    path = tmp_path / "ledger.csv"
    led.to_csv(str(path))
    got = ml.read_csv(str(path))
    assert [r["h2d_stall_s"] for r in got["rows"]] == [0.0, 0.0]
    assert got["summary"]["h2d_exposed_s"] == 0.0
    assert got["summary"]["prefetch_ahead"] == 1
    assert got["summary"]["peak_bytes"] == 200
    # a sync-mode ledger stores the fully-exposed pricing
    led.prefetch = "sync"
    assert led.price_h2d(bw=100.0) == pytest.approx(0.5)
    assert [r.h2d_stall_s for r in led.ticks] == [0.5, 0.0]
