"""End-to-end behaviour tests: the train driver learns, resumes, and the
serve driver decodes — on a reduced config through the public entry points."""
import json
import os
import subprocess
import sys

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
           XLA_FLAGS="--xla_force_host_platform_device_count=8")


def _run(args, timeout=540):
    return subprocess.run([sys.executable, "-m"] + args, env=ENV,
                          capture_output=True, text=True, timeout=timeout)


def test_train_loss_decreases(tmp_path):
    metrics = tmp_path / "m.json"
    r = _run(["repro.launch.train", "--arch", "starcoder2-3b", "--reduced",
              "--steps", "30", "--seq", "256", "--batch", "8",
              "--mesh", "1x1", "--n-chunks", "2",
              "--metrics-out", str(metrics)])
    assert r.returncode == 0, r.stderr[-2000:]
    hist = json.loads(metrics.read_text())
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.5
    assert all(np.isfinite(h["loss"]) for h in hist)


def test_train_distributed_with_restart(tmp_path):
    ck = tmp_path / "ckpt"
    r1 = _run(["repro.launch.train", "--arch", "qwen2-7b", "--reduced",
               "--steps", "8", "--seq", "256", "--batch", "8",
               "--mesh", "4x2", "--pp", "2", "--n-chunks", "2",
               "--ckpt-dir", str(ck), "--ckpt-every", "4"])
    assert r1.returncode == 0, r1.stderr[-2000:]
    r2 = _run(["repro.launch.train", "--arch", "qwen2-7b", "--reduced",
               "--steps", "12", "--seq", "256", "--batch", "8",
               "--mesh", "4x2", "--pp", "2", "--n-chunks", "2",
               "--ckpt-dir", str(ck), "--resume", "auto"])
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "resumed from step 8" in (r2.stderr + r2.stdout)


def test_serve_decodes():
    r = _run(["repro.launch.serve", "--arch", "qwen2-7b", "--reduced",
              "--mesh", "2x2", "--prompt-len", "128", "--batch", "4",
              "--decode-steps", "4"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "decoded 4 tokens/seq" in (r.stderr + r.stdout)
