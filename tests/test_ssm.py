"""SSM mixers vs sequential recurrence oracles (exact math, fp64-ish fp32).

The chunked SSD (Mamba2) and chunked WKV6 (RWKV) implementations must equal
a token-by-token recurrence, including across chunk boundaries (the SPPO
state carry) and across sequence shards (the cross-rank composition)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import ssm as S
from repro.parallel.ctx import SINGLE


def _mamba_ref(x, p, cfg):
    """Sequential SSD recurrence (single device, full heads)."""
    ssm = cfg.ssm
    d_in = ssm.expand * cfg.d_model
    H = d_in // ssm.head_dim
    hd, ds = ssm.head_dim, ssm.d_state
    B, T, _ = x.shape
    xs = x @ p["in_x"]
    bc = x @ p["in_bc"]
    dt = x @ p["in_dt"] + p["dt_bias"]
    z = x @ p["in_z"]
    kern = jnp.concatenate([p["conv_x"], p["conv_bc"]], axis=-1)
    conv_in = jnp.concatenate([xs, bc], axis=-1)
    W = kern.shape[0]
    pad = jnp.concatenate([jnp.zeros((B, W - 1, conv_in.shape[-1]),
                                     conv_in.dtype), conv_in], axis=1)
    conv = sum(pad[:, i:i + T] * kern[i][None, None] for i in range(W))
    conv = jax.nn.silu(conv)
    xs = conv[..., :d_in]
    Bm = conv[..., d_in:d_in + ds].astype(jnp.float32)
    Cm = conv[..., d_in + ds:].astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xs.reshape(B, T, H, hd).astype(jnp.float32)

    Sst = jnp.zeros((B, H, hd, ds), jnp.float32)
    ys = []
    for t in range(T):
        da = jnp.exp(dt[:, t] * A[None, :])                       # [B,H]
        Sst = Sst * da[:, :, None, None] + jnp.einsum(
            "bh,bhd,bn->bhdn", dt[:, t], xh[:, t], Bm[:, t])
        ys.append(jnp.einsum("bhdn,bn->bhd", Sst, Cm[:, t]))
    y = jnp.stack(ys, axis=1)
    y = y + xh * p["D"].astype(jnp.float32)[None, None, :, None]
    yg = (y.reshape(B, T, d_in)
          * jax.nn.silu(z.astype(jnp.float32))).reshape(B, T, H, hd)
    var = jnp.mean(yg * yg, axis=-1, keepdims=True)
    yg = yg * jax.lax.rsqrt(var + 1e-6)
    yn = (yg.reshape(B, T, d_in)
          * (1.0 + p["norm_scale"].astype(jnp.float32))).astype(x.dtype)
    return yn @ p["out"], Sst


@pytest.mark.parametrize("T,nchunks", [(32, 1), (64, 2), (96, 3)])
def test_mamba2_chunked_equals_recurrence(T, nchunks):
    cfg = get_config("zamba2-7b").reduced()
    from repro.models.model_zoo import _mamba
    key = jax.random.PRNGKey(0)
    p = _mamba(key, cfg, jnp.float32)
    B = 2
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model),
                          jnp.float32) * 0.5
    want, want_state = _mamba_ref(x, p, cfg)

    state = S.mamba2_init_state(cfg, B, 1)
    outs = []
    cl = T // nchunks
    for c in range(nchunks):
        y, state = S.mamba2_mixer(x[:, c * cl:(c + 1) * cl], p, cfg, SINGLE,
                                  state, subchunk=16)
        outs.append(y)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state.ssm), np.asarray(want_state),
                               rtol=2e-4, atol=2e-4)


def _rwkv_ref_timemix(x, p, cfg, state):
    """Token-by-token WKV6 recurrence."""
    H, dk = cfg.n_heads, cfg.hd
    dv = dk
    B, T, d = x.shape
    xf = x.astype(jnp.float32)
    xprev = jnp.concatenate([state.shift_t.astype(jnp.float32), xf[:, :-1]],
                            axis=1)
    xx = xprev - xf
    xbar = xf + xx * p["mu_x"]
    lora = jnp.tanh(xbar @ p["ddl_a"]) @ p["ddl_b"]
    lam = lora.reshape(B, T, 5, d) + p["mu_rkvwg"][None, None]
    xr, xk, xv, xw, xg = [(xf + xx * lam[:, :, i]) for i in range(5)]
    r = (xr @ p["wr"]).reshape(B, T, H, dk).astype(jnp.float32)
    k = (xk @ p["wk"]).reshape(B, T, H, dk).astype(jnp.float32)
    v = (xv @ p["wv"]).reshape(B, T, H, dv).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["wg"])
    dd = p["w0"][None, None] + jnp.tanh(xw @ p["dec_a"]) @ p["dec_b"]
    w = jnp.exp(-jnp.exp(dd.astype(jnp.float32))).reshape(B, T, H, dk)
    u = p["u"].reshape(H, dk).astype(jnp.float32)

    Sst = state.wkv
    ys = []
    for t in range(T):
        kv = jnp.einsum("bhc,bhv->bhcv", k[:, t], v[:, t])
        ys.append(jnp.einsum("bhc,bhcv->bhv", r[:, t],
                             Sst + u[None, :, :, None] * kv))
        Sst = Sst * w[:, t][..., None] + kv
    y = jnp.stack(ys, axis=1)
    mu = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    y = (y - mu) * jax.lax.rsqrt(var + 64e-5)
    y = y.reshape(B, T, H * dv) * p["ln_x_scale"] + p["ln_x_bias"]
    y = (y * g).astype(x.dtype)
    return y @ p["wo"], Sst


@pytest.mark.parametrize("T,nchunks", [(32, 1), (64, 2)])
def test_rwkv6_chunked_equals_recurrence(T, nchunks):
    cfg = get_config("rwkv6-3b").reduced()
    from repro.models.model_zoo import _rwkv_tmix
    key = jax.random.PRNGKey(0)
    p = _rwkv_tmix(key, cfg, jnp.float32)
    B = 2
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model),
                          jnp.float32) * 0.5
    st0 = S.rwkv6_init_state(cfg, B, 1)
    want, want_state = _rwkv_ref_timemix(x, p, cfg, st0)

    state = st0
    outs = []
    cl = T // nchunks
    for c in range(nchunks):
        y, state = S.rwkv6_time_mix(x[:, c * cl:(c + 1) * cl], p, cfg,
                                    SINGLE, state, subchunk=8)
        outs.append(y)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(state.wkv),
                               np.asarray(want_state), rtol=3e-4, atol=3e-4)
