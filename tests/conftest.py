"""Shared fixtures. NOTE: device count must stay 1 here (the dry-run sets
its own 512-device flag in its own process); distributed tests spawn their
fake-device meshes via XLA_FLAGS in subprocess or use the 8-device session
started by tests that need it."""
import os
import sys

# distributed integration tests need a handful of fake devices; smoke tests
# and benches are written against whatever the session provides, so a small
# fixed count keeps both worlds working in one pytest process.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

# property tests prefer real hypothesis (the CI `[test]` extra installs it);
# fall back to the deterministic stub so the suite stays collectable in
# minimal containers.
try:
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover - depends on environment
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub
    _hypothesis_stub.strategies = _hypothesis_stub

# the weekly slow CI leg reruns the property suites with a deeper budget:
# HYPOTHESIS_PROFILE=nightly raises max_examples for every @given that does
# not pin its own (tests that pin max_examples in @settings keep their pin —
# that is hypothesis' documented precedence, so per-test budgets stay exact).
# hasattr-guarded: the deterministic stub has no profile machinery.
from hypothesis import settings as _hyp_settings  # noqa: E402

if hasattr(_hyp_settings, "register_profile"):
    _hyp_settings.register_profile("nightly", max_examples=300,
                                   deadline=None)
    _profile = os.environ.get("HYPOTHESIS_PROFILE")
    if _profile:
        _hyp_settings.load_profile(_profile)

import jax  # noqa: E402

import pytest  # noqa: E402


@pytest.fixture(scope="session")
def eight_devices():
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 fake CPU devices (XLA_FLAGS was preset)")
    return jax.devices()[:8]


@pytest.fixture(autouse=True)
def _no_backend_leak():
    """Restore the global attention backend after every test, so a test (or
    a failure mid-`kops.backend(...)` block) can't leak pallas/jnp mode into
    unrelated modules."""
    from repro.kernels import ops as kops

    prev = kops.get_backend()
    yield
    kops.set_backend(prev)


@pytest.fixture
def kernel_backend():
    """Scoped backend flipper: ``with kernel_backend("pallas"): ...``."""
    from repro.kernels import ops as kops

    return kops.backend
