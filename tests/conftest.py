"""Shared fixtures. NOTE: device count must stay 1 here (the dry-run sets
its own 512-device flag in its own process); distributed tests spawn their
fake-device meshes via XLA_FLAGS in subprocess or use the 8-device session
started by tests that need it."""
import os

# distributed integration tests need a handful of fake devices; smoke tests
# and benches are written against whatever the session provides, so a small
# fixed count keeps both worlds working in one pytest process.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402

import pytest  # noqa: E402


@pytest.fixture(scope="session")
def eight_devices():
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 fake CPU devices (XLA_FLAGS was preset)")
    return jax.devices()[:8]
