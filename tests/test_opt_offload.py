"""Executed optimizer-state offload honesty tests (DESIGN.md §11).

``offload_moments`` must be *executable end to end*, mirroring the PR-3
activation contract: host-resident AdamW moments update to exactly the same
values as device-resident ones (the H2D/H2D round trip is a value-level
identity), the explicit update stages exactly one H2D per moment leaf and
writes back with one D2H, the ledger's moments channel (opt_m@/opt_v@ jaxpr
walk) matches the cost model's closed form, and init births the moments in
host space with zero device materialization (the step-0 peak fix).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.base import get_config
from repro.core import costmodel as cm
from repro.models.model_zoo import build_model
from repro.optim import adamw
from repro.runtime import hostmem
from repro.runtime import memledger as ml

pytestmark = pytest.mark.optstate


@functools.lru_cache(maxsize=None)
def _params(pp: int):
    """Stacked stage-param tree of the reduced sppo config, the same
    stage-major layout the runner's optimizer updates."""
    cfg = get_config("sppo-gpt-7b").reduced()
    mdef = build_model(cfg)
    key = jax.random.PRNGKey(0)
    stages = [mdef.init_stage_params(key, s, pp, jnp.float32)
              for s in range(pp)]
    return jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *stages)


def _grads(params, scale: float):
    key = jax.random.PRNGKey(3)
    return jax.tree_util.tree_map(
        lambda p: scale * jax.random.normal(key, p.shape, jnp.float32),
        params)


# ---------------------------------------------------------------------------
# (a) property: offload on == offload off after repeated updates
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(st.sampled_from(["float32", "bfloat16"]),
       st.sampled_from([1, 2]),
       st.sampled_from([True, False]))
def test_offload_identity_after_three_steps(opt_dtype, pp, clip_active):
    """With offload_moments on vs off, params and the full AdamWState agree
    to <= 1e-6 fp32 after 3 apply_update steps — across moment dtypes,
    pipeline depths, and clip-active/inactive gradients."""
    dt = jnp.bfloat16 if opt_dtype == "bfloat16" else jnp.float32
    params = _params(pp)
    grads = _grads(params, 1e3 if clip_active else 1e-4)
    p_on, p_off = params, params
    s_on = adamw.init_state(params, dt, offload_moments=True)
    s_off = adamw.init_state(params, dt)
    for _ in range(3):
        p_on, s_on, _ = adamw.apply_update(p_on, grads, s_on, lr=1e-3,
                                           offload_moments=True)
        p_off, s_off, _ = adamw.apply_update(p_off, grads, s_off, lr=1e-3)
    assert int(s_on.step) == int(s_off.step) == 3
    for a, b in zip(jax.tree_util.tree_leaves((p_on, s_on.m, s_on.v)),
                    jax.tree_util.tree_leaves((p_off, s_off.m, s_off.v))):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=0, atol=1e-6)


def test_xla_mode_matches_explicit():
    """moments_mode='xla' (host-committed shardings, XLA streaming) and
    'explicit' (one H2D/D2H per leaf) compute identical updates."""
    params = _params(1)
    grads = _grads(params, 1.0)
    outs = []
    for mode in ("explicit", "xla"):
        p, s = params, adamw.init_state(params, jnp.float32,
                                        offload_moments=True)
        p, s, _ = adamw.apply_update(p, grads, s, lr=1e-3,
                                     offload_moments=True, moments_mode=mode)
        outs.append((p, s.m, s.v))
    for a, b in zip(jax.tree_util.tree_leaves(outs[0]),
                    jax.tree_util.tree_leaves(outs[1])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0, atol=1e-6)


# ---------------------------------------------------------------------------
# (b) the explicit path's jaxpr: host markers + one H2D per moment leaf
# ---------------------------------------------------------------------------


def test_explicit_update_jaxpr_contract():
    params = _params(2)
    grads = _grads(params, 1.0)
    state = adamw.init_state(params, jnp.float32, offload_moments=True)
    n_leaves = len(jax.tree_util.tree_leaves(state.m))

    def fn(p, g, s):
        return adamw.apply_update(p, g, s, lr=1e-3, offload_moments=True)

    cjx = jax.make_jaxpr(fn)(params, grads, state)
    kinds = ml.device_put_kinds(cjx)
    # exactly one H2D per moment leaf per step (m and v trees each)
    assert kinds.get(hostmem.DEVICE_KIND, 0) == 2 * n_leaves, kinds
    # ... and one D2H writes each new moment back to host
    host_kind = hostmem.host_memory_kind()
    if host_kind is not None:
        assert kinds.get(host_kind, 0) == 2 * n_leaves, kinds
        assert str(cjx).count(host_kind) >= 2 * n_leaves
    # every moment leaf carries its ledger name
    named = ml.moment_bytes_from_jaxpr(cjx)
    assert len(named["leaves"]) == 2 * n_leaves


def test_no_copies_or_names_without_offload():
    params = _params(1)
    grads = _grads(params, 1.0)
    state = adamw.init_state(params, jnp.float32)

    def fn(p, g, s):
        return adamw.apply_update(p, g, s, lr=1e-3)

    cjx = jax.make_jaxpr(fn)(params, grads, state)
    assert ml.device_put_kinds(cjx) == {}
    assert ml.moment_bytes_from_jaxpr(cjx)["leaves"] == {}


# ---------------------------------------------------------------------------
# (c) ledger moments channel == cost-model closed form
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("opt_dtype", ["float32", "bfloat16"])
def test_moment_bytes_match_closed_form(opt_dtype):
    """The jaxpr walk over opt_m@/opt_v@ names must sum to exactly
    n_params * moment_bytes_per_param(opt_dtype) on the reduced cell."""
    dt = jnp.bfloat16 if opt_dtype == "bfloat16" else jnp.float32
    params = _params(2)
    grads = _grads(params, 1.0)
    state = adamw.init_state(params, dt, offload_moments=True)

    def fn(p, g, s):
        return adamw.apply_update(p, g, s, lr=1e-3, offload_moments=True)

    named = ml.moment_bytes_from_jaxpr(jax.make_jaxpr(fn)(params, grads,
                                                          state))
    n_params = sum(int(np.prod(l.shape))
                   for l in jax.tree_util.tree_leaves(params))
    assert named["m"] + named["v"] == \
        n_params * cm.moment_bytes_per_param(opt_dtype)
    # the real state buffers agree with the walk — the names cover every leaf
    real = sum(int(l.nbytes)
               for l in jax.tree_util.tree_leaves((state.m, state.v)))
    assert named["m"] + named["v"] == real


def test_runtime_coverage_requires_update_probe():
    """A ledger with a measured moments channel is only covered once an
    update-phase probe fired — fwd/bwd tick evidence alone is not enough."""
    led = ml.MemLedger()
    led.moments = ml.MomentChannel(
        offloaded=True, mode="explicit", opt_dtype="float32",
        host_kind=hostmem.host_memory_kind(), m_bytes=8, v_bytes=8,
        n_leaves=1, max_pair_bytes=16, named_bytes=16, h2d_count=2,
        d2h_count=2, init_dev_bytes=0)
    assert not led.runtime_coverage_ok()
    led.record_runtime("upd", 0)
    assert led.runtime_coverage_ok()
    # without a moments channel the update probe is not required
    led2 = ml.MemLedger()
    assert led2.runtime_coverage_ok()


def test_csv_roundtrip_moments_column(tmp_path):
    led = ml.MemLedger()
    led.load_tagged({"@c0": {"off": 64, "keep": 64},
                     "@c1": {"off": 0, "keep": 128}},
                    [(0, 0, 1), (1, 0, 1)], 1, (0.5, 0.0))
    led.moments = ml.MomentChannel(
        offloaded=False, mode="explicit", opt_dtype="float32",
        host_kind=None, m_bytes=300, v_bytes=300, n_leaves=3,
        max_pair_bytes=200, named_bytes=0, h2d_count=0, d2h_count=0,
        init_dev_bytes=600)
    led.opt_time_s = 0.25
    path = str(tmp_path / "led.csv")
    led.to_csv(path)
    back = ml.read_csv(path)
    assert [r["moments_dev_bytes"] for r in back["rows"]] == [600, 600]
    assert [r["resident_bytes"] for r in back["rows"]] == \
        [r.resident for r in led.ticks]
    s = back["summary"]
    assert s["moments_total_bytes"] == 600
    assert s["moments_dev_peak_bytes"] == 600
    assert s["combined_peak_bytes"] == led.combined_peak_bytes
    assert s["moments_offloaded"] == 0
    assert s["opt_time_s"] == pytest.approx(0.25)


# ---------------------------------------------------------------------------
# (d) init_state births moments in host space: step-0 peak == steady state
# ---------------------------------------------------------------------------


def test_init_state_no_device_spike_regression():
    """The traced init must materialize zero moment bytes in device space
    when offloading (zeros born host-side), so the step-0 combined peak
    equals the steady-state peak; without offload the full set
    materializes on device — the measure is not vacuous."""
    params = _params(2)
    total = 2 * sum(int(np.prod(l.shape)) * 4
                    for l in jax.tree_util.tree_leaves(params))
    assert ml.init_moment_device_bytes(
        params, jnp.float32, offload_moments=True) == 0
    assert ml.init_moment_device_bytes(
        params, jnp.float32, offload_moments=False) == total
    # the concrete arrays really live in the host space
    kind = hostmem.host_memory_kind()
    if kind is not None:
        state = adamw.init_state(params, jnp.float32, offload_moments=True)
        for leaf in jax.tree_util.tree_leaves((state.m, state.v)):
            assert hostmem.memory_kind_of(leaf) == kind
    # ledger arithmetic: steady-state device contribution is the staging
    # pair; step 0 adds init_dev_bytes on top — offloaded init adds nothing
    act_peak = 1000
    steady = act_peak + 16     # max_pair staging
    step0 = steady + ml.init_moment_device_bytes(
        params, jnp.float32, offload_moments=True)
    assert step0 == steady


def test_solver_prices_opt_epilogue():
    """offload_moments adds the unhidden moment round trip to the solver's
    iteration time — strictly positive, linear in the moment volume."""
    from repro.core import simulate as sim
    cfg = get_config("sppo-gpt-7b").reduced()
    from repro.core import solver
    kw = dict(seq_len=256, batch=4, n_params=100_000, pp=2, n=4, sp=2)
    t0, _ = solver.iteration_time(cfg, **kw)
    t1, _ = solver.iteration_time(cfg, **kw, offload_moments=True)
    per = cm.moment_bytes_per_param("float32")
    want = sim.opt_update_transfer(kw["n_params"] / (kw["sp"] * kw["pp"]),
                                   per, cm.V5E.d2h_bw)
    assert t1 - t0 == pytest.approx(want)
    assert want > 0
    # bf16 moments halve the epilogue
    t2, _ = solver.iteration_time(cfg, **kw, offload_moments=True,
                                  opt_dtype="bfloat16")
    assert t2 - t0 == pytest.approx(want / 2)
