"""Substrate tests: checkpointing (atomic/rolling/bf16), data pipeline
determinism + layout properties, watchdog, offload-to-host compilation."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import SyntheticLM, shard_batch
from repro.runtime.fault_tolerance import StepWatchdog


# ---------------------------------------------------------------------------
# Checkpointer
# ---------------------------------------------------------------------------


def _tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.bfloat16) * 1.5,
                  "d": jnp.int32(7)}}


def test_checkpoint_roundtrip_and_bf16(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    t = _tree()
    ck.save(3, t, extra={"data": {"seed": 1, "step": 3}})
    got, step, extra = ck.restore(t)
    assert step == 3 and extra["data"]["step"] == 3
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(got)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_atomicity_ignores_uncommitted(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(1, _tree())
    # simulate a torn write: step_2 without COMMIT
    d = tmp_path / "step_000000002"
    d.mkdir()
    (d / "manifest.json").write_text("{}")
    assert ck.latest_step() == 1


def test_checkpoint_rolling_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree())
    assert ck.all_steps() == [3, 4]


def test_checkpoint_async(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=True)
    ck.save(5, _tree())
    ck.wait()
    assert ck.latest_step() == 5


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------


def test_data_determinism():
    a = SyntheticLM(1000, 64, 4, seed=3).sample_step(7)
    b = SyntheticLM(1000, 64, 4, seed=3).sample_step(7)
    np.testing.assert_array_equal(a[0], b[0])
    c = SyntheticLM(1000, 64, 4, seed=4).sample_step(7)
    assert not np.array_equal(a[0], c[0])


def test_labels_are_shifted_tokens():
    toks, labs = SyntheticLM(1000, 64, 2, seed=0).sample_step(0)
    assert toks.shape == labs.shape == (2, 64)
    assert toks.max() < 1000 and toks.min() >= 0


@given(st.sampled_from([1, 2, 4]), st.sampled_from([1, 2, 4]),
       st.sampled_from([1, 2]))
@settings(max_examples=20, deadline=None)
def test_shard_batch_layout(pp, dp_mult, pods):
    data_size = pp * dp_mult
    dp = dp_mult
    B = dp * pods * 2
    toks = np.arange(B * 8, dtype=np.int32).reshape(B, 8)
    out = shard_batch(toks, toks, pods=pods, data_size=data_size, pp=pp)
    t = out["tokens"]
    assert t.shape == (pods, data_size, B // (pods * dp), 8)
    for p in range(pods):
        for i in range(data_size):
            g = i // pp
            b_loc = B // (pods * dp)
            np.testing.assert_array_equal(
                t[p, i], toks[(p * dp + g) * b_loc:(p * dp + g + 1) * b_loc])
    # stages within a dp group see identical shards
    for p in range(pods):
        for g in range(dp):
            for s in range(1, pp):
                np.testing.assert_array_equal(t[p, g * pp], t[p, g * pp + s])


# ---------------------------------------------------------------------------
# Watchdog
# ---------------------------------------------------------------------------


def test_watchdog_flags_stragglers_and_timeouts():
    wd = StepWatchdog(window=20, straggler_factor=1.5, timeout_factor=5.0,
                      min_samples=5)
    for i in range(10):
        assert wd.observe(i, 1.0) == "ok"
    assert wd.observe(10, 2.0) == "straggler"
    assert wd.observe(11, 10.0) == "timeout"
    assert wd.stragglers == 1 and wd.trips == 1


# ---------------------------------------------------------------------------
# Two-level activation management compiles to real host offload
# ---------------------------------------------------------------------------


def test_offload_policy_moves_bytes_to_host():
    """With α=1 the tagged activations are offloaded: the differentiated
    program contains device_put transfers into host memory space on BOTH
    execution forms — 'explicit' (memory-kind device_puts in the tick
    loop, DESIGN.md §10) and 'xla' (the remat offload policy) — and none
    with offload disabled (two-level activation management end-to-end).

    NOTE: verified at the jaxpr level — the XLA *CPU* backend folds the
    host space into device during lowering (host == device RAM), so
    compiled host_temp bytes only show on the TPU target.  The jaxpr is the
    backend-independent proof that the tensors are routed."""
    import dataclasses
    from repro.configs.base import ShapeConfig, get_config
    from repro.models.model_zoo import build_model
    from repro.parallel.ctx import SINGLE
    from repro.parallel.runner import resolve_cell, run_pipeline

    cfg = get_config("qwen2-7b").reduced()
    mdef = build_model(cfg)
    shape = ShapeConfig("t", 256, 2, "train")

    def host_transfers(offload, mode="explicit"):
        cell = resolve_cell(mdef, shape, data_size=1, model_size=1,
                            overrides=dict(n_chunks=2, grad_accum=1,
                                           offload=offload,
                                           offload_mode=mode))
        if offload:  # force full offload ratios
            cell = dataclasses.replace(cell, alphas=(1.0, 1.0))
        key = jax.random.PRNGKey(0)
        sp = mdef.init_stage_params(key, 0, 1, jnp.bfloat16)
        g = mdef.init_globals(key, jnp.bfloat16)
        toks = jax.random.randint(key, (2, 256), 0, cfg.vocab_size)

        def loss(sp_, g_):
            out = run_pipeline(cell, SINGLE, sp_, g_, toks, toks, None,
                               with_loss=True)
            return out["loss"] / jnp.maximum(out["denom"], 1.0)

        jaxpr = str(jax.make_jaxpr(jax.grad(loss))(sp, g))
        # newer jax prints the residual space as "<host>"; older jax prints
        # TransferToMemoryKind(memory_kind='[un]pinned_host') device_puts
        return (jaxpr.count("<host>") + jaxpr.count("pinned_host")
                + jaxpr.count("unpinned_host"))

    from repro.core import offload as ofl

    exec_off = host_transfers(True, "explicit")
    xla_off = host_transfers(True, "xla")
    without = host_transfers(False)
    if ofl.host_memory_kind() is not None:
        assert exec_off >= 10, (
            f"expected explicit host transfers, got {exec_off}")
    assert xla_off >= 10, f"expected policy host residuals, got {xla_off}"
    assert without == 0
