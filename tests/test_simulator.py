"""Property + contract tests for the event-driven pipeline simulator
(core/simulate.py, DESIGN.md §3) and its wiring into the solver and the
SPMD runner: closed-form agreement when transfers are free, MSP fill-bubble
scaling, the §5.2 memory recurrence, unhidden-D2H stall charging, and the
runner-vs-simulator feed-event contract."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import get_config
from repro.core import offload as ofl
from repro.core import schedule as sched
from repro.core import simulate as sim
from repro.core import solver


# ---------------------------------------------------------------------------
# Closed-form agreement (free transfers)
# ---------------------------------------------------------------------------


@given(st.integers(1, 8), st.integers(1, 64), st.floats(0.1, 10.0))
@settings(max_examples=60, deadline=None)
def test_plain_uniform_matches_closed_form(pp, n, per):
    """With equal chunks and free transfers the playout IS the paper's
    T = (p−1+N)/N · F(N)."""
    if n < pp:
        return
    costs = [per] * n
    r = sim.simulate_schedule(costs, pp=pp)
    assert r.total == pytest.approx(sched.total_time(pp, n, sum(costs)))
    assert r.feed_events == tuple(sim.plain_events(n))
    # work is conserved: every stage computes every chunk once
    assert all(b == pytest.approx(sum(costs)) for b in r.stage_busy)


def test_pp1_arbitrary_costs_are_just_the_work():
    costs = [0.3, 1.7, 2.0, 0.5]
    r = sim.simulate_schedule(costs, pp=1)
    assert r.total == pytest.approx(sum(costs))
    assert r.bubble_ratio == pytest.approx(0.0)


def test_imbalanced_chunks_diverge_from_closed_form_average():
    """The closed form charges the *average* chunk for the bubble; the
    playout sees the actual fill/drain chunks — this is why the solver
    simulates instead of using T = (p−1+N)/N · F(N)."""
    costs = [0.1, 0.1, 4.0, 4.0]  # cheap fill, expensive tail
    r = sim.simulate_schedule(costs, pp=2)
    cf = sched.total_time(2, 4, sum(costs))
    assert abs(r.total - cf) > 0.1 * cf
    # the fill bubble is the actual first chunk's forward, not the average
    assert r.fill_bubble[1] == pytest.approx(0.1 / 3.0)


# ---------------------------------------------------------------------------
# MSP ramp schedule
# ---------------------------------------------------------------------------


@given(st.integers(2, 8), st.integers(2, 5), st.floats(0.2, 5.0))
@settings(max_examples=40, deadline=None)
def test_msp_fill_and_drain_bubble_shrink_by_split(pp, split, per):
    """The ramp schedule's fill bubble (idle before a stage's first chunk)
    shrinks by exactly 1/split, and total time never regresses.  Note the
    event-driven playout shows the *total* win is smaller than the closed
    form's (p−1)·F/(split·N) claim — steady chunks resynchronize the stages
    (DESIGN.md §3.3) — which is exactly why the solver simulates."""
    n = 4 * pp
    costs = [per] * n
    plain = sim.simulate_schedule(costs, pp=pp)
    msp = sim.simulate_schedule(costs, pp=pp, msp=True, split=split)
    for s in range(1, pp):
        assert (plain.fill_bubble[s] / msp.fill_bubble[s]
                == pytest.approx(split))
    assert msp.total <= plain.total * (1 + 1e-9)
    assert msp.feed_events == tuple(sched.msp_ramp_schedule(n, pp, split))
    # work conserved under splitting
    assert sum(msp.stage_busy) == pytest.approx(sum(plain.stage_busy))


# ---------------------------------------------------------------------------
# §5.2 memory recurrence + offload lanes
# ---------------------------------------------------------------------------


@given(st.integers(2, 20), st.floats(0.5, 50.0), st.floats(0.5, 2.0))
@settings(max_examples=60, deadline=None)
def test_sim_peak_matches_offload_recurrence(n, bw, tscale):
    """Simulated forward peak == offload.peak_memory when the alphas come
    from the sequence-aware solver (transfers hide by construction)."""
    acts = [(n - i) * 1.0 for i in range(n)]
    times = [tscale] * n
    fwd = [t / 3.0 for t in times]
    plan = ofl.sequence_aware_alphas(acts, fwd, bw)
    r = sim.simulate_schedule(times, pp=1, chunk_acts=acts,
                              alphas=plan.alphas, d2h_bw=bw)
    assert r.peak_units[0] == pytest.approx(
        ofl.peak_memory(acts, plan.alphas))
    # memory-mirror prefetch keeps the backward peak bounded by the forward
    assert max(r.peak_units_full) <= max(r.peak_units) * (1 + 1e-9)


def test_unhidden_d2h_stall_is_charged():
    """Fixed-full offload over a slow link stalls the compute lane; the
    sequence-aware alphas for the same link do not."""
    acts = [5.0, 4.0, 3.0, 2.0]
    times = [1.0] * 4
    slow = 0.5
    full = sim.simulate_schedule(times, pp=1, chunk_acts=acts,
                                 alphas=[1.0, 1.0, 1.0, 0.0], d2h_bw=slow)
    free = sim.simulate_schedule(times, pp=1)
    assert full.d2h_stall > 0.0
    assert full.total > free.total
    plan = ofl.sequence_aware_alphas(acts, [t / 3 for t in times], slow)
    adaptive = sim.simulate_schedule(times, pp=1, chunk_acts=acts,
                                     alphas=plan.alphas, d2h_bw=slow)
    assert adaptive.d2h_stall == pytest.approx(0.0)
    assert adaptive.total == pytest.approx(free.total)


def test_prefetch_sync_lane_exposes_reloads():
    """prefetch='sync' (autodiff placement) serializes every reload into
    its own backward: charged h2d_stall and total time are never below the
    memory-mirror 'ahead' mode, and with reloads that fit their hiding
    windows the gap is strict."""
    acts = [5.0, 4.0, 3.0, 2.0]
    times = [1.0] * 4
    plan = ofl.sequence_aware_alphas(acts, [t / 3 for t in times], 2.0)
    ahead = sim.simulate_schedule(times, pp=2, chunk_acts=acts,
                                  alphas=plan.alphas, d2h_bw=2.0)
    syncd = sim.simulate_schedule(times, pp=2, chunk_acts=acts,
                                  alphas=plan.alphas, d2h_bw=2.0,
                                  prefetch="sync")
    assert syncd.h2d_stall > ahead.h2d_stall
    assert syncd.total >= ahead.total
    # identical forward: the lane mode only moves backward reloads
    assert syncd.peak_units == ahead.peak_units
    with pytest.raises(AssertionError):
        sim.simulate_schedule(times, pp=2, prefetch="nope")


def test_backward_h2d_lane_waits_for_first_cotangent():
    """The reload lane of stage s < pp−1 opens at the arrival of its first
    backward cotangent (the runner's link_drain hand-off), not at the
    stage's own last forward: with the last chunk offloading
    (reserve_last=False territory), stage 0 must not pre-load during its
    drain bubble."""
    times = [1.0] * 4
    acts = [1.0] * 4
    r = sim.simulate_schedule(times, pp=2, chunk_acts=acts,
                              alphas=[0.5, 0.5, 0.5, 0.5], d2h_bw=100.0)
    h2d0 = [ev for ev in r.trace if ev.stage == 0 and ev.lane == sim.H2D]
    # stage 0's first cotangent needs stage 1's last forward AND its first
    # backward event; the old fwd_end[s][ne-1] init allowed reloads in the
    # drain bubble before either
    fwd1_end = max(ev.end for ev in r.trace
                   if ev.stage == 1 and ev.lane == sim.FWD)
    bwd1_first = min(ev.start for ev in r.trace
                     if ev.stage == 1 and ev.lane == sim.BWD)
    assert min(ev.start for ev in h2d0) >= fwd1_end
    assert min(ev.start for ev in h2d0) >= bwd1_first


def test_p2p_lane_delays_downstream_stages():
    costs = [1.0] * 4
    free = sim.simulate_schedule(costs, pp=2)
    slow = sim.simulate_schedule(costs, pp=2, p2p_bytes=[8.0] * 4,
                                 ici_bw=16.0)  # 0.5 s per hand-off
    assert slow.total > free.total
    assert slow.fill_bubble[1] == pytest.approx(free.fill_bubble[1] + 0.5)


# ---------------------------------------------------------------------------
# Solver contract: candidates are scored by the simulator, never the
# closed forms
# ---------------------------------------------------------------------------


def test_solver_path_never_calls_closed_forms(monkeypatch):
    def boom(*a, **k):  # pragma: no cover - failure path
        raise AssertionError("closed-form total_time on the solve path")

    monkeypatch.setattr(sched, "total_time", boom)
    monkeypatch.setattr(sched, "msp_total_time", boom)
    cfg = get_config("sppo-gpt-7b")
    res = solver.solve(cfg, seq_len=262144, batch=1,
                       n_params=6_700_000_000)
    assert res.n_chunks >= 1
    res_msp = solver.solve(cfg, seq_len=262144, batch=1,
                           n_params=6_700_000_000, msp=True)
    assert res_msp.n_chunks >= 1


def test_solver_msp_never_worse():
    cfg = get_config("sppo-gpt-7b")
    base = solver.solve(cfg, 524288, 1, 6_700_000_000)
    msp = solver.solve(cfg, 524288, 1, 6_700_000_000, msp=True)
    assert msp.est_time <= base.est_time * (1 + 1e-9)


# ---------------------------------------------------------------------------
# Runner contract: the SPMD tick loop executes the simulator's feed events
# ---------------------------------------------------------------------------


def test_runner_tick_trace_matches_simulator_feed_events():
    from repro.configs.base import ShapeConfig
    from repro.models.model_zoo import build_model
    from repro.parallel import runner

    cfg = get_config("qwen2-7b").reduced()
    mdef = build_model(cfg)
    cell = runner.resolve_cell(
        mdef, ShapeConfig("t", 256, 4, "train"), data_size=4, model_size=2,
        overrides=dict(pp=2, dp=2, n_chunks=4, msp=True, grad_accum=1,
                       partition="length"))
    events = runner.pipeline_feed_events(cell.plan, cell.sched.n)
    res = sim.simulate_schedule([1.0] * cell.sched.n, pp=cell.plan.pp,
                                msp=True, split=cell.plan.msp_split)
    assert tuple(events) == res.feed_events
    trace = runner.pipeline_tick_trace(cell)
    assert len(trace) == len(events) + cell.plan.pp - 1
    feeds = [tk["feed"] for tk in trace if tk["feed"] is not None]
    drains = [tk["drain"] for tk in trace if tk["drain"] is not None]
    assert feeds == list(events)
    assert drains == list(events)  # same order, offset by pp-1 ticks
    # every (chunk, sub) loss region drains exactly once
    regions = {(c, s) for c, s, _ in drains}
    split = cell.plan.msp_split
    ramp = min(cell.plan.pp - 1, cell.sched.n // 2)
    expect = {(c, 0) for c in range(cell.sched.n)}
    expect |= {(c, s) for s in range(split)
               for c in list(range(ramp))
               + list(range(cell.sched.n - ramp, cell.sched.n))}
    assert regions == expect

    plain_cell = runner.resolve_cell(
        mdef, ShapeConfig("t", 256, 4, "train"), data_size=4, model_size=2,
        overrides=dict(pp=2, dp=2, n_chunks=4, grad_accum=1,
                       partition="length"))
    plain_ev = runner.pipeline_feed_events(plain_cell.plan,
                                           plain_cell.sched.n)
    assert tuple(plain_ev) == sim.simulate_schedule(
        [1.0] * 4, pp=2).feed_events
