"""Deterministic fallback for `hypothesis` (used when the real package is
absent — e.g. a minimal container).  CI installs real hypothesis via the
pyproject `[test]` extra; this stub keeps the tier-1 suite collectable and
meaningful everywhere else by sampling each strategy pseudo-randomly from a
fixed seed (plus the interval endpoints for integer/float ranges).

Only the API surface the test-suite uses is implemented: `given`,
`settings`, and the `integers` / `floats` / `sampled_from` strategies.
"""
from __future__ import annotations

import random

MAX_EXAMPLES_CAP = 25  # keep the fallback suite fast; CI runs the real thing


class _Strategy:
    def __init__(self, sample, endpoints=()):
        self._sample = sample
        self.endpoints = tuple(endpoints)

    def sample(self, rng):
        return self._sample(rng)


def integers(lo, hi):
    return _Strategy(lambda rng: rng.randint(lo, hi), (lo, hi))


def floats(lo, hi, **_kw):
    return _Strategy(lambda rng: rng.uniform(lo, hi), (lo, hi))


def sampled_from(seq):
    seq = list(seq)
    return _Strategy(lambda rng: rng.choice(seq), (seq[0], seq[-1]))


def settings(max_examples=None, deadline=None, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*strategies):
    def deco(fn):
        n = min(getattr(fn, "_stub_max_examples", None) or MAX_EXAMPLES_CAP,
                MAX_EXAMPLES_CAP)

        def runner():
            rng = random.Random(0x5BB0)
            # endpoint cases first (all-lo, all-hi), then random samples
            cases = [[s.endpoints[0] for s in strategies],
                     [s.endpoints[-1] for s in strategies]]
            cases += [[s.sample(rng) for s in strategies]
                      for _ in range(max(0, n - 2))]
            for args in cases:
                fn(*args)

        # NOT functools.wraps: pytest must see a zero-arg signature, or it
        # would treat the wrapped function's parameters as fixtures
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        return runner
    return deco
