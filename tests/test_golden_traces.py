"""Golden schedule-trace snapshots: the solver/simulator event trace for
the two frozen configs must match tests/golden/ byte for byte.  Any change
to the cost model, offload-ratio solver, ramp schedule, or playout gating
moves these traces — that is allowed, but only as a reviewed regeneration
(`python -m benchmarks.golden_traces --write`), never silently."""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks import golden_traces as gt  # noqa: E402


@pytest.mark.parametrize("name,spec", gt.CONFIGS, ids=[n for n, _ in gt.CONFIGS])
def test_trace_matches_golden(name, spec):
    path = os.path.join(os.path.normpath(gt.GOLDEN_DIR), f"{name}.csv")
    assert os.path.exists(path), (
        f"missing golden trace {path}; generate with "
        "`python -m benchmarks.golden_traces --write`")
    got = "\n".join(gt.trace_lines(spec)) + "\n"
    want = open(path).read()
    assert got == want, (
        f"schedule trace drift for {name} — if intentional, regenerate "
        "with `python -m benchmarks.golden_traces --write` and review the "
        "diff")
