"""Ring-distributed chunked attention (DESIGN.md §15): the tentpole gate.

Executed law: ring_attention over a real shard_map mesh (sp in {2, 4})
computes the same loss AND gradients as the single-device dense oracle
(kernels/ref.mha_reference) to fp32 <= 1e-5 — both kernel backends, causal
and non-causal, packed-varlen (q_start segment window) included.  Priced
law: the simulator's ring lane and the per-stage memory model admit a
4M-token cell at attn_mode="ring" that attn_mode="local" cannot hold.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import ShapeConfig, get_config
from repro.core import costmodel as cm
from repro.core import simulate as sim
from repro.core import solver
from repro.kernels import ops as kops
from repro.kernels.ref import mha_reference
from repro.launch.mesh import compat_make_mesh
from repro.models.model_zoo import build_model
from repro.parallel import ring
from repro.parallel.ctx import SINGLE, Ctx
from repro.parallel.runner import (_in_specs_for_params, batch_struct,
                                   resolve_cell, run_pipeline, shard_map)

pytestmark = pytest.mark.ring


# ---------------------------------------------------------------------------
# executed ring vs the single-device dense oracle (loss + grads, <= 1e-5)
# ---------------------------------------------------------------------------

def _qkv(seed=0, B=2, T=64, H=4, Hkv=2, hd=16):
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(kq, (B, T, H, hd), jnp.float32)
    k = jax.random.normal(kk, (B, T, Hkv, hd), jnp.float32)
    v = jax.random.normal(kv, (B, T, Hkv, hd), jnp.float32)
    return q, k, v, jnp.arange(T, dtype=jnp.int32)


def _ring_value_and_grads(q, k, v, pos, sp, *, causal, q_start=None):
    """Scalar loss (psum of squared ring outputs) + grads on a (1, sp) mesh."""
    mesh = compat_make_mesh((1, sp), ("data", "model"))
    ctx = Ctx(model_axis="model", sp=sp)
    in_specs = [P(None, "model")] * 3 + [P("model")]
    args = [q, k, v, pos]
    if q_start is not None:
        in_specs.append(P("model"))
        args.append(q_start)

    def loss(q, k, v, pos, *rest):
        def body(q_l, k_l, v_l, p_l, *rest_l):
            qs_l = rest_l[0] if rest_l else None
            o = ring.ring_attention(q_l, k_l, v_l, p_l, p_l, ctx,
                                    causal=causal, q_start=qs_l)
            return jax.lax.psum((o.astype(jnp.float32) ** 2).sum(), "model")
        f = shard_map(body, mesh, in_specs=tuple(in_specs), out_specs=P())
        return f(q, k, v, pos, *rest)

    return jax.value_and_grad(loss, argnums=(0, 1, 2))(*args)


def _oracle_value_and_grads(q, k, v, pos, *, causal, q_start=None):
    def loss(q, k, v):
        o = mha_reference(q, k, v, pos, pos, causal=causal, q_start=q_start)
        return (o.astype(jnp.float32) ** 2).sum()
    return jax.value_and_grad(loss, argnums=(0, 1, 2))(q, k, v)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("sp", [2, 4])
@pytest.mark.parametrize("causal", [True, False])
def test_ring_matches_dense_oracle(backend, sp, causal, eight_devices):
    q, k, v, pos = _qkv()
    with kops.backend(backend):
        l1, g1 = _ring_value_and_grads(q, k, v, pos, sp, causal=causal)
    l0, g0 = _oracle_value_and_grads(q, k, v, pos, causal=causal)
    np.testing.assert_allclose(float(l1), float(l0), rtol=1e-5)
    for got, ref in zip(g1, g0):
        np.testing.assert_allclose(got, ref, atol=1e-5, rtol=0)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("sp", [2, 4])
def test_ring_packed_varlen_matches_oracle(backend, sp, eight_devices):
    """q_start segment windows (packed documents, DESIGN.md §13) survive the
    rotation: the window is query-side and never moves, while every arriving
    KV block is masked against it inside the kernels."""
    q, k, v, pos = _qkv(seed=3)
    T = pos.shape[0]
    # two packed documents: [0, 24) and [24, T) — queries never look across
    q_start = jnp.where(pos < 24, 0, 24).astype(jnp.int32)
    with kops.backend(backend):
        l1, g1 = _ring_value_and_grads(q, k, v, pos, sp, causal=True,
                                       q_start=q_start)
    l0, g0 = _oracle_value_and_grads(q, k, v, pos, causal=True,
                                     q_start=q_start)
    np.testing.assert_allclose(float(l1), float(l0), rtol=1e-5)
    for got, ref in zip(g1, g0):
        np.testing.assert_allclose(got, ref, atol=1e-5, rtol=0)
    assert T == 64  # the boundary at 24 is sp-misaligned on purpose for sp=4


def test_ring_sp1_degenerates_to_oracle():
    """At sp == 1 the ring is one partial + normalize — the self-oracle
    property every executed attention mode shares."""
    q, k, v, pos = _qkv(seed=5)
    o = ring.ring_attention(q, k, v, pos, pos, SINGLE, causal=True)
    ref = mha_reference(q, k, v, pos, pos, causal=True)
    np.testing.assert_allclose(o, ref, atol=1e-6, rtol=0)


# ---------------------------------------------------------------------------
# full-pipeline composition: ring under pp chunked scheduling + offload
# ---------------------------------------------------------------------------

def _single_loss(mdef, tokens, labels):
    shape = ShapeConfig("t", tokens.shape[1], tokens.shape[0], "train")
    cell = resolve_cell(mdef, shape, data_size=1, model_size=1,
                        overrides=dict(n_chunks=2, grad_accum=1,
                                       partition="length"))
    cell = dataclasses.replace(cell, dtype=jnp.float32)
    key = jax.random.PRNGKey(0)
    sp1 = mdef.init_stage_params(key, 0, 1, jnp.float32)
    g1 = mdef.init_globals(key, jnp.float32)

    def f(sp_, g_):
        out = run_pipeline(cell, SINGLE, sp_, g_, tokens, labels, None,
                           with_loss=True)
        return out["loss"] / jnp.maximum(out["denom"], 1.0)

    return float(jax.jit(f)(sp1, g1))


def _dist_loss(mdef, tokens, labels, *, pp, mesh_shape, extra_overrides):
    data_size, model_size = mesh_shape
    mesh = compat_make_mesh(mesh_shape, ("data", "model"))
    dp = data_size // pp
    B, S = tokens.shape
    overrides = dict(n_chunks=2, grad_accum=1, pp=pp, dp=dp,
                     partition="length")
    overrides.update(extra_overrides)
    cell = resolve_cell(mdef, ShapeConfig("t", S, B, "train"),
                        data_size=data_size, model_size=model_size,
                        overrides=overrides)
    cell = dataclasses.replace(cell, dtype=jnp.float32)
    key = jax.random.PRNGKey(0)
    stages = [mdef.init_stage_params(key, s, pp, jnp.float32)
              for s in range(pp)]
    g_stage = jax.tree_util.tree_map(
        lambda *ls: jnp.stack([ls[i % pp] for i in range(data_size)]),
        *stages)
    gl = mdef.init_globals(key, jnp.float32)
    b_loc = B // dp

    def lay(x):
        return jnp.stack([x[(i // pp) * b_loc:(i // pp + 1) * b_loc]
                          for i in range(data_size)])[None]

    batch = {"tokens": lay(tokens), "labels": lay(labels)}
    pspecs = _in_specs_for_params(cell)
    _, bspecs = batch_struct(cell)

    def body(stage_p, g, b):
        ctx = cell.ctx()
        assert ctx.attn_mode == overrides.get("attn_mode", ctx.attn_mode)
        stage_p = jax.tree_util.tree_map(lambda a: a.reshape(a.shape[1:]),
                                         stage_p)
        tok = b["tokens"].reshape(b["tokens"].shape[2:])
        lab = b["labels"].reshape(b["labels"].shape[2:])
        out = run_pipeline(cell, ctx, stage_p, g, tok, lab, None,
                           with_loss=True)
        num = ctx.psum_loss_all(out["loss"])
        den = ctx.psum_loss_all(out["denom"])
        return num / jnp.maximum(den, 1.0)

    fn = shard_map(body, mesh,
                   in_specs=(pspecs["stages"], pspecs["globals"], bspecs),
                   out_specs=P())
    return float(jax.jit(fn)(g_stage, gl, batch))


@pytest.mark.parametrize("mesh_shape,pp", [((4, 2), 2), ((2, 4), 2)])
def test_ring_pipeline_equals_single(mesh_shape, pp, eight_devices):
    """Ring attention composed with the chunked pipeline + executed offload
    (the default plan) reproduces the single-device loss at sp=2 and sp=4."""
    cfg = get_config("qwen2-7b").reduced()
    mdef = build_model(cfg)
    B, S = 4, 256
    key = jax.random.PRNGKey(7)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    labels = jnp.roll(tokens, -1, axis=1)
    ref = _single_loss(mdef, tokens, labels)
    got = _dist_loss(mdef, tokens, labels, pp=pp, mesh_shape=mesh_shape,
                     extra_overrides=dict(attn_mode="ring"))
    np.testing.assert_allclose(got, ref, rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------------------
# plan threading + validation
# ---------------------------------------------------------------------------

def test_plan_threads_ring_to_ctx():
    cfg = get_config("qwen2-7b").reduced()
    mdef = build_model(cfg)
    cell = resolve_cell(mdef, ShapeConfig("t", 256, 4, "train"),
                        data_size=4, model_size=2,
                        overrides=dict(pp=2, dp=2, n_chunks=2, grad_accum=1,
                                       partition="length", attn_mode="ring"))
    assert cell.plan.attn_mode == "ring"
    assert cell.ctx().attn_mode == "ring"


def test_plan_rejects_unknown_attn_mode():
    cfg = get_config("qwen2-7b").reduced()
    mdef = build_model(cfg)
    with pytest.raises(AssertionError, match="attn_mode"):
        resolve_cell(mdef, ShapeConfig("t", 256, 4, "train"),
                     data_size=1, model_size=1,
                     overrides=dict(n_chunks=2, grad_accum=1,
                                    partition="length",
                                    attn_mode="ring_zigzag"))


def test_plan_rejects_local_on_wide_mesh():
    cfg = get_config("qwen2-7b").reduced()
    mdef = build_model(cfg)
    with pytest.raises(AssertionError, match="local"):
        resolve_cell(mdef, ShapeConfig("t", 256, 4, "train"),
                     data_size=4, model_size=2,
                     overrides=dict(pp=2, dp=2, n_chunks=2, grad_accum=1,
                                    partition="length", attn_mode="local"))


# ---------------------------------------------------------------------------
# pricing: the ring lane, hop fractions, and the 4M admission artifact
# ---------------------------------------------------------------------------

def test_ring_overlap_recurrence():
    """Double-buffer recurrence: hop h+1's transfer is issued at hop h's
    compute start on a serialized link; exposure = arrival past compute."""
    wall, exposed, events = sim.ring_overlap([1.0, 1.0, 1.0],
                                             [0.0, 2.0, 2.0])
    assert (wall, exposed) == (5.0, 2.0)
    assert len([e for e in events if e[0] == "compute"]) == 3
    # fast link: everything hides, wall == pure compute
    wall, exposed, _ = sim.ring_overlap([1.0, 1.0, 1.0], [0.0, 0.1, 0.1])
    assert exposed == 0.0 and wall == 3.0


def test_ring_hop_fractions_causality_pricing():
    for sp in (2, 4, 16):
        block = cm.ring_hop_fractions(sp, layout="block")
        zig = cm.ring_hop_fractions(sp, layout="zigzag")
        assert sum(block) == sp  # late ranks serialize: no causal discount
        np.testing.assert_allclose(sum(zig), sp / 2 + 0.5 / sp)
        assert sum(cm.ring_hop_fractions(sp, causal=False)) == sp
    assert cm.ring_hop_fractions(1) == [1.0]


def test_simulated_ring_lane_prices_the_rotation():
    cfg = get_config("qwen2-7b")
    base_kw = dict(msp=False, offload=True)
    t0, _, r0 = solver.simulate_candidate(cfg, 524288, 1, 7_600_000_000,
                                          4, 8, 16, **base_kw)
    t1, _, r1 = solver.simulate_candidate(cfg, 524288, 1, 7_600_000_000,
                                          4, 8, 16, attn_mode="ring",
                                          **base_kw)
    assert any(ev.lane == sim.RING for ev in r1.trace)
    assert not any(ev.lane == sim.RING for ev in r0.trace)
    assert r1.ring_stall >= 0.0
    assert t1 >= t0  # the rotation can only add exposed time


def test_4m_cell_rejected_local_admitted_ring():
    """THE acceptance artifact: a simulated 4M-token qwen2-7b cell
    (batch=1, pp=4, sp=16) does not fit a 16 GiB stage at attn_mode="local"
    (full visible KV on every device) but is admitted at "ring" (one
    resident shard + two in-flight blocks)."""
    cfg = get_config("qwen2-7b")
    seq, n_params = 4 * 2 ** 20, 7_600_000_000
    adm = solver.admit_attn_mode(cfg, seq, 1, n_params, pp=4, sp=16)
    ok_local, d_local = adm["local"]
    ok_ring, d_ring = adm["ring"]
    assert not ok_local and d_local["total"] > cm.V5E.hbm_bytes
    assert ok_ring and d_ring["total"] <= cm.V5E.hbm_bytes
    # and the full chooser plays out the admitted mode end to end
    mode, report = solver.choose_attn_mode(cfg, seq, 1, n_params,
                                           pp=4, n=32, sp=16,
                                           modes=("local", "ring"))
    assert mode == "ring"
    assert report["local"]["admitted"] is False
    assert report["ring"]["admitted"] and report["ring"]["est_time"] > 0


def test_stage_attn_demand_scales_down_with_sp():
    cfg = get_config("qwen2-7b")
    kw = dict(seq_len=2 ** 20, batch=1, pp=4, n_params=7_600_000_000)
    ring16 = cm.stage_attn_demand(cfg, sp=16, mode="ring", **kw)
    ring8 = cm.stage_attn_demand(cfg, sp=8, mode="ring", **kw)
    local = cm.stage_attn_demand(cfg, sp=16, mode="local", **kw)
    assert ring16["kv_cache"] < ring8["kv_cache"]
    assert local["kv_cache"] == 16 * ring16["kv_cache"]
    gkv = cm.stage_attn_demand(cfg, sp=16, mode="gather_kv", **kw)
    assert gkv["attn_transient"] > ring16["attn_transient"]
