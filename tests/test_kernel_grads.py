"""Gradient conformance of the attention kernels (the training contract).

Three implementations must agree on dq/dk/dv:
  * the dense ``jnp.einsum`` oracle (`mha_reference`, plain autodiff),
  * the blockwise-jnp reference (`attention_partial_ref`, autodiff of the
    scan with the gradient-frozen max statistic),
  * the Pallas path (`flash_attention_partial`, fused backward kernels via
    custom_vjp, interpret mode on CPU).

Property-tested across causal/non-causal, GQA group sizes, decode (Tq=1),
ragged positions and PAD cache slots, fp32/bf16 — tolerance-tiered per
dtype.  Plus: gradients must flow through the partial-softmax *merge*
(`merge_partials`): the stop_gradient on the max statistic must not freeze
dq/dk for the winning block (finite-difference checked).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention import flash_attention_partial
from repro.kernels.ref import (PAD_POS, attention_partial_ref, merge_partials,
                               mha_reference, normalize)

# (Tq, S, n_pad_slots, q_off): ragged block shapes, decode, ragged offsets
SHAPES = [
    (16, 32, 0, 16),
    (17, 33, 5, 8),
    (1, 40, 8, 30),     # decode: Tq=1 padded to a block
    (8, 24, 3, 13),
]

TOL = {jnp.float32: 1e-4, jnp.bfloat16: 6e-2}


def _mk_case(shape_idx, G, Hkv, dtype, seed):
    Tq, S, n_pad, q_off = SHAPES[shape_idx % len(SHAPES)]
    H, hd, hv = G * Hkv, 16, 16
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (1, Tq, H, hd), dtype)
    k = jax.random.normal(ks[1], (1, S, Hkv, hd), dtype)
    v = jax.random.normal(ks[2], (1, S, Hkv, hv), dtype)
    w = jax.random.normal(ks[3], (1, Tq, H, hv), jnp.float32)
    q_pos = jnp.arange(Tq, dtype=jnp.int32) + q_off
    kv_pos = jnp.arange(S, dtype=jnp.int32)
    if n_pad:
        kv_pos = jnp.where(jnp.arange(S) < S - n_pad, kv_pos, PAD_POS)
    return q, k, v, w, q_pos, kv_pos


def _grads(loss, q, k, v):
    return jax.grad(loss, argnums=(0, 1, 2))(q, k, v)


@settings(max_examples=8, deadline=None)
@given(st.sampled_from([1, 4, 8]),          # GQA group size
       st.sampled_from([True, False]),       # causal
       st.sampled_from(["float32", "bfloat16"]),
       st.integers(0, 7))                    # shape pick + rng seed
def test_grad_conformance(G, causal, dtype_name, seed):
    dtype = jnp.dtype(dtype_name).type
    Hkv = 2 if G < 8 else 1
    q, k, v, w, q_pos, kv_pos = _mk_case(seed, G, Hkv, dtype, seed)

    def loss_pallas(q, k, v):
        o, _, l = flash_attention_partial(q, k, v, q_pos, kv_pos,
                                          causal=causal, block_q=16,
                                          block_k=16, interpret=True)
        return jnp.sum(normalize(o, l) * w)

    def loss_ref(q, k, v):
        o, _, l = attention_partial_ref(q, k, v, q_pos, kv_pos,
                                        causal=causal, block_k=16)
        return jnp.sum(normalize(o, l) * w)

    def loss_dense(q, k, v):
        return jnp.sum(mha_reference(q, k, v, q_pos, kv_pos,
                                     causal=causal) * w)

    gp = _grads(loss_pallas, q, k, v)
    gr = _grads(loss_ref, q, k, v)
    gd = _grads(loss_dense, q, k, v)
    tol = TOL[dtype]
    for name, a, b, c in zip("qkv", gp, gr, gd):
        a, b, c = (np.asarray(x, np.float32) for x in (a, b, c))
        np.testing.assert_allclose(a, b, rtol=tol, atol=tol,
                                   err_msg=f"d{name}: pallas vs ref")
        np.testing.assert_allclose(a, c, rtol=tol, atol=tol,
                                   err_msg=f"d{name}: pallas vs dense")


def test_grad_fully_masked_rows_are_zero():
    """Queries that can see no KV (all slots in the future / PAD) must get
    exactly zero gradient — not NaN from exp(NEG_INF - NEG_INF)."""
    q, k, v, w, q_pos, _ = _mk_case(0, 2, 2, jnp.float32, 1)
    kv_pos = jnp.arange(k.shape[1], dtype=jnp.int32) + 10_000

    for fn in (
        lambda q, k, v: flash_attention_partial(
            q, k, v, q_pos, kv_pos, block_q=16, block_k=16, interpret=True),
        lambda q, k, v: attention_partial_ref(
            q, k, v, q_pos, kv_pos, block_k=16),
    ):
        def loss(q, k, v, fn=fn):
            o, _, l = fn(q, k, v)
            return jnp.sum(normalize(o, l) * w)

        gq, gk, gv = _grads(loss, q, k, v)
        for g in (gq, gk, gv):
            assert not np.any(np.isnan(np.asarray(g)))
            np.testing.assert_allclose(np.asarray(g), 0.0)


def test_grad_decode_padded_block():
    """Decode (Tq=1, padded to a kernel block) backward matches dense."""
    q, k, v, w, _, kv_pos = _mk_case(2, 4, 2, jnp.float32, 3)
    q_pos = jnp.full((1,), 30, jnp.int32)

    def loss_pallas(q, k, v):
        o, _, l = flash_attention_partial(q, k, v, q_pos, kv_pos,
                                          block_q=16, block_k=16,
                                          interpret=True)
        return jnp.sum(normalize(o, l) * w)

    def loss_dense(q, k, v):
        return jnp.sum(mha_reference(q, k, v, q_pos, kv_pos) * w)

    gp = _grads(loss_pallas, q, k, v)
    gd = _grads(loss_dense, q, k, v)
    for name, a, b in zip("qkv", gp, gd):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4,
                                   err_msg=f"d{name}")


# ---------------------------------------------------------------------------
# Merge gradients: the stop_gradient on the max stat must not freeze anything
# ---------------------------------------------------------------------------


def _merge_setup():
    B, Tq, S, H, Hkv, hd = 1, 8, 32, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(11), 4)
    q = jax.random.normal(ks[0], (B, Tq, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), jnp.float32)
    # second shard's keys scaled up: its scores dominate, so *it* wins the
    # running max — the regression target for a frozen-winner bug
    k = k.at[:, S // 2:].multiply(3.0)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd), jnp.float32)
    w = jax.random.normal(ks[3], (B, Tq, H, hd), jnp.float32)
    q_pos = jnp.arange(Tq, dtype=jnp.int32) + (S - Tq)
    kv_pos = jnp.arange(S, dtype=jnp.int32)
    return q, k, v, w, q_pos, kv_pos, S // 2


def _merged_loss(q, k, v, w, q_pos, kv_pos, half):
    parts = [attention_partial_ref(q, k[:, sl], v[:, sl], q_pos, kv_pos[sl],
                                   block_k=8)
             for sl in (slice(0, half), slice(half, None))]
    o, _, l = merge_partials(parts)
    return jnp.sum(normalize(o, l) * w)


def test_merge_partials_grads_match_full_attention():
    """Sharded partials + merge must have the *same* gradients as full-KV
    attention — including dk of the shard that wins the max statistic."""
    q, k, v, w, q_pos, kv_pos, half = _merge_setup()

    def loss_merged(q, k, v):
        return _merged_loss(q, k, v, w, q_pos, kv_pos, half)

    def loss_full(q, k, v):
        return jnp.sum(mha_reference(q, k, v, q_pos, kv_pos) * w)

    gm = _grads(loss_merged, q, k, v)
    gf = _grads(loss_full, q, k, v)
    for name, a, b in zip("qkv", gm, gf):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4,
                                   err_msg=f"d{name}")
    # the winning (second) shard's dk is live, not frozen
    dk_win = np.asarray(gm[1])[:, half:]
    assert np.max(np.abs(dk_win)) > 1e-3


def test_merge_partials_grad_finite_difference():
    """Directional finite-difference check of dq through the merge: the
    stop_gradient on the max statistic is a *reparameterization*, not a
    truncation — the analytic derivative must match the numeric one."""
    q, k, v, w, q_pos, kv_pos, half = _merge_setup()

    def loss_q(q):
        return _merged_loss(q, k, v, w, q_pos, kv_pos, half)

    g = jax.grad(loss_q)(q)
    u = jax.random.normal(jax.random.PRNGKey(5), q.shape, jnp.float32)
    u = u / jnp.linalg.norm(u)
    eps = 3e-2
    num = (loss_q(q + eps * u) - loss_q(q - eps * u)) / (2 * eps)
    ana = jnp.sum(g * u)
    np.testing.assert_allclose(float(ana), float(num), rtol=2e-2, atol=2e-3)


@pytest.mark.ring
@settings(deadline=None)  # max_examples inherited: nightly raises it
@given(st.integers(2, 5),        # number of KV shards in the ring
       st.integers(0, 11))       # arrival order: rotation or a shuffle
def test_ring_fold_is_arrival_order_invariant(n_shards, order_seed):
    """The ring schedule's silent dependency (DESIGN.md §15): folding the
    same KV shards in *any* arrival order — each rank sees a different
    rotation of the ring — must give bit-identical (o, m, l) and, through
    them, bit-identical gradients.  fold_arrivals scatters every block into
    its canonical source slot before the single merge, so the merge graph
    never sees the arrival order; this property-checks exactly that."""
    from repro.parallel.ring import fold_arrivals

    B, H, Hkv, hd = 1, 4, 2, 16
    S = 8 * n_shards
    Tq = 8
    ks = jax.random.split(jax.random.PRNGKey(order_seed + 17 * n_shards), 4)
    q = jax.random.normal(ks[0], (B, Tq, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd), jnp.float32)
    w = jax.random.normal(ks[3], (B, Tq, H, hd), jnp.float32)
    q_pos = jnp.arange(Tq, dtype=jnp.int32) + S - Tq  # sees every shard
    kv_pos = jnp.arange(S, dtype=jnp.int32)

    canonical = list(range(n_shards))
    rot = order_seed % n_shards
    order = canonical[rot:] + canonical[:rot]
    if order_seed >= 6:  # beyond rotations: arbitrary permutations too
        rng = np.random.RandomState(order_seed)
        order = list(rng.permutation(n_shards))

    def fold(k, order):
        parts = []
        for s in order:
            sl = slice(s * 8, (s + 1) * 8)
            parts.append(attention_partial_ref(
                q, k[:, sl], v[:, sl], q_pos, kv_pos[sl], causal=True))
        return fold_arrivals(parts, order, n_blocks=n_shards)

    def loss(k, order):
        o, _, l = fold(k, order)
        return jnp.sum(normalize(o, l) * w)

    o_a, m_a, l_a = fold(k, canonical)
    o_b, m_b, l_b = fold(k, order)
    np.testing.assert_array_equal(np.asarray(o_a), np.asarray(o_b))
    np.testing.assert_array_equal(np.asarray(m_a), np.asarray(m_b))
    np.testing.assert_array_equal(np.asarray(l_a), np.asarray(l_b))
    g_a = jax.grad(loss)(k, canonical)
    g_b = jax.grad(loss)(k, order)
    np.testing.assert_array_equal(np.asarray(g_a), np.asarray(g_b))
