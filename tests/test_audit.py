"""Trace-time contract auditor suite (analysis/audit.py, DESIGN.md §17).

Two halves, both allocation-free (make_jaxpr / eval_shape only):

  * the seeded mutant corpus (tests/mutants/) — each case re-introduces a
    historical regression via a ``repro.core.mutation`` switch (or a
    known-bad plan) and the auditor MUST emit the documented finding id;
  * the clean sweep — every benchmarks/budgets.json cell, at its own pp
    and at pp=1, audits with zero findings, and the R1 counters agree
    with the runtime ledger's ``device_put_kinds`` on the same trace.

Marked ``audit`` and run in the audit-gate CI leg, not per kernel backend.
"""
import json
import os

import pytest

from mutants import MUTANTS

pytestmark = pytest.mark.audit

_BUDGETS = os.path.join(os.path.dirname(__file__), os.pardir,
                        "benchmarks", "budgets.json")


def _gates():
    with open(_BUDGETS) as f:
        return json.load(f)["gates"]


def _base_gate():
    return next(g for g in _gates() if g["name"] == "sppo-gpt-7b-reduced-pp2")


def _small_gate(**overrides):
    """The mutant-corpus cell: the base budget gate shrunk to trace fast."""
    g = dict(_base_gate(), seq=128, batch=2, data_size=2, model_size=2)
    g.update(overrides)
    return g


# ---------------------------------------------------------------------------
# Mutant corpus: every seeded regression must be flagged by its documented id
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("case", MUTANTS, ids=[c["name"] for c in MUTANTS])
def test_mutant_flagged(case):
    from repro.analysis import audit as aud
    from repro.core import mutation

    gate = _small_gate(**case["overrides"])
    if case["mutation"] is None:
        rep = aud.audit_gate(gate, pp=gate["pp"], prefetch=case["prefetch"])
    else:
        with mutation.seeded(case["mutation"]):
            rep = aud.audit_gate(gate, pp=gate["pp"],
                                 prefetch=case["prefetch"])
    assert rep.error is None, rep.error
    ids = rep.finding_ids()
    # the documented finding must be present; collateral findings may ride
    # along (e.g. sync reload also doubles the traced H2D count)
    assert case["expected_id"] in ids, (case["name"], ids)


def test_mutation_seeded_restores():
    from repro.core import mutation

    assert not mutation.active("double-d2h")
    with mutation.seeded("double-d2h"):
        assert mutation.active("double-d2h")
    assert not mutation.active("double-d2h")
    with pytest.raises(ValueError):
        mutation.enable("not-a-known-mutation")


# ---------------------------------------------------------------------------
# Clean sweep: every budget cell, pp grid, zero findings
# ---------------------------------------------------------------------------


def _sweep_params():
    params = []
    for g in _gates():
        if g.get("kind") == "serve":
            params.append(pytest.param(g, None, id=g["name"]))
            continue
        for pp in sorted({1, g["pp"]}):
            params.append(pytest.param(g, pp, id=f"{g['name']}@pp{pp}"))
    return params


@pytest.mark.parametrize("gate,pp", _sweep_params())
def test_budget_cell_clean(gate, pp):
    from repro.analysis import audit as aud

    rep = aud.audit_gate(gate, pp=pp)
    assert rep.error is None, rep.error
    assert rep.clean, [str(f) for f in rep.findings]
    if gate.get("kind") != "serve":
        # a clean train report must document the contract it proved
        assert rep.counters["train-grad.d2h"] == rep.counters["train-grad.h2d"]
        assert rep.counters["train-grad.offload_sites"] > 0


def test_small_cell_clean_both_pp():
    from repro.analysis import audit as aud

    for pp in (1, 2):
        rep = aud.audit_gate(_small_gate(), pp=pp)
        assert rep.error is None, rep.error
        assert rep.clean, (pp, [str(f) for f in rep.findings])


# ---------------------------------------------------------------------------
# R1 cross-check: auditor counters == runtime ledger's device_put census
# ---------------------------------------------------------------------------


def test_r1_counters_match_memledger():
    import jax

    from repro.analysis import audit as aud
    from repro.runtime import hostmem
    from repro.runtime import memledger as ml

    gate = _small_gate()
    cell, data_size, model_size = aud.resolve_gate_cell(gate, pp=2)
    rep = aud.audit_cell(cell, data_size=data_size, model_size=model_size,
                         name="crosscheck")
    assert rep.clean, [str(f) for f in rep.findings]

    fn = ml.step_fn(cell, data_size=data_size, model_size=model_size,
                    with_grad=True)
    import repro.parallel.specs as SP
    from repro.parallel import runner

    g_stage = SP.stage_struct(cell.mdef, cell.plan.pp, cell.data_size,
                              cell.dtype)
    gl = SP.globals_struct(cell.mdef, cell.dtype)
    bstruct, _ = runner.batch_struct(cell)
    cjx = jax.make_jaxpr(fn)(g_stage, gl, bstruct)
    kinds = ml.device_put_kinds(cjx)
    host = sum(n for k, n in kinds.items() if k != hostmem.DEVICE_KIND)
    assert host == rep.counters["train-grad.d2h"]
    assert kinds.get(hostmem.DEVICE_KIND, 0) == rep.counters["train-grad.h2d"]


# ---------------------------------------------------------------------------
# Wiring: the CLI and the train.py preflight
# ---------------------------------------------------------------------------


def test_cli_clean_cell_exits_zero(tmp_path, capsys):
    from repro.launch import audit as cli

    out = tmp_path / "report.json"
    rc = cli.main(["--cell", "sppo-gpt-7b-reduced-pp2", "--pp", "1",
                   "--out", str(out)])
    assert rc == 0
    blob = json.loads(out.read_text())
    assert blob["schema"] == "repro-audit-report/1"
    assert blob["clean"] is True
    assert len(blob["reports"]) == 1
    assert capsys.readouterr().out.count("ok —") == 1


def test_cli_sync_override_exits_nonzero(tmp_path):
    from repro.launch import audit as cli

    out = tmp_path / "report.json"
    rc = cli.main(["--cell", "sppo-gpt-7b-reduced-pp2", "--pp", "1",
                   "--prefetch", "sync", "--out", str(out)])
    assert rc == 1
    blob = json.loads(out.read_text())
    assert blob["clean"] is False
    ids = [f["id"] for r in blob["reports"] for f in r["findings"]]
    assert "R3-overlap-hazard" in ids


def test_train_audit_preflight_blocks_mutant():
    from repro.core import mutation
    from repro.launch import train

    argv = ["--arch", "sppo-gpt-7b", "--reduced", "--seq", "256",
            "--batch", "2", "--mesh", "1x1", "--n-chunks", "4",
            "--steps", "0", "--audit"]
    with mutation.seeded("double-d2h"):
        with pytest.raises(SystemExit) as exc:
            train.main(argv)
    assert exc.value.code == 2
