"""Compressed host residency tests (DESIGN.md §14).

The bf16/fp32 -> fp8_e4m3/int8 + per-row fp32 scale codec behind
``ParallelPlan.offload_dtype`` / ``moments_dtype`` is *lossy by design*, so
the on/off identity law of the raw offload channel
(tests/test_offload_exec.py, <= 1e-5) is replaced here by pinned drift
tolerances: the forward stays exact under the prefetch-'ahead' capture seam
(the tag is an identity; compression happens on the captured copy), the
backward replay reconstructs within the codec's resolution, and the ledger
accounts the raw device drain, the wire payload, and the device-resident
scales as three separate honest numbers."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.base import ShapeConfig, get_config
from repro.core import costmodel as cm
from repro.core import offload as ofl
from repro.models.model_zoo import build_model
from repro.parallel.ctx import SINGLE
from repro.parallel.runner import resolve_cell, run_pipeline
from repro.runtime import hostmem
from repro.runtime import memledger as ml

ALPHAS = (1.0, 0.7, 0.5, 0.0)   # full / fractional / fractional / reserved

# pinned codec resolutions: fp8_e4m3 has a 3-bit mantissa (worst-case
# relative rounding step 2^-4 per element), int8 symmetric rounds within
# 0.5/127 of the row amax — the row-level reconstruction bounds
ROW_TOL = {"fp8": 0.07, "int8": 0.01}
# one-step gradient drift of a compressed cell against raw residency
GRAD_TOL = {"fp8": 0.05, "int8": 0.03}


# ---------------------------------------------------------------------------
# codec primitives
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("codec", ["fp8", "int8"])
def test_codec_round_trip_within_row_resolution(codec):
    """Per-row reconstruction error stays within the codec's pinned
    resolution, across 6 decades of row magnitude (the per-row scale makes
    the error relative to each row's amax, not the tensor's)."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (24, 64), jnp.float32)
    x = x * (10.0 ** jnp.arange(-3, 3).repeat(4))[:, None]
    p, s = hostmem.quantize(x, codec)
    y = hostmem.dequantize(p, s, codec, jnp.float32)
    err = np.max(np.abs(np.asarray(x - y)), axis=-1)
    amax = np.max(np.abs(np.asarray(x)), axis=-1)
    assert np.all(err <= ROW_TOL[codec] * amax), (codec, err / amax)
    assert p.dtype == hostmem.codec_wire_dtype(codec)
    assert s.dtype == jnp.float32 and s.shape == (24, 1)


@settings(max_examples=20, deadline=None)
@given(st.sampled_from(["fp8", "int8"]),
       st.floats(-448.0, 448.0, width=32, allow_subnormal=False,
                 allow_nan=False),
       st.integers(1, 6))
def test_codec_degenerate_constant_rows(codec, val, rows):
    """Constant rows (including all-zero) survive the round trip: no
    NaN/inf from the zero-amax scale guard, zeros reconstruct exactly,
    constants within the codec resolution."""
    x = jnp.full((rows, 16), val, jnp.float32)
    v32 = float(x[0, 0])   # the fp32 value the codec actually sees
    p, s = hostmem.quantize(x, codec)
    y = np.asarray(hostmem.dequantize(p, s, codec, jnp.float32))
    assert np.all(np.isfinite(y))
    if v32 == 0.0:
        assert np.all(y == 0.0) and np.all(np.asarray(s) == 1.0)
    else:
        assert np.all(np.abs(y - v32) <= ROW_TOL[codec] * abs(v32))


def test_codec_zero_rows_exact_among_live_rows():
    """A mixed batch — some rows zero, some not — keeps the zero rows
    bitwise zero under both codecs (per-row scales don't couple rows)."""
    x = jnp.stack([jnp.zeros((8,)), jnp.ones((8,)) * 3.5,
                   jnp.zeros((8,)), jnp.linspace(-2.0, 2.0, 8)])
    for codec in ("fp8", "int8"):
        p, s = hostmem.quantize(x, codec)
        y = np.asarray(hostmem.dequantize(p, s, codec, jnp.float32))
        assert np.all(y[0] == 0.0) and np.all(y[2] == 0.0), codec
        assert np.any(y[1] != 0.0)


def test_int8_transport_bitcast_round_trips_bits():
    """The prefetch seam transports int8 payloads bitcast to the fp8 byte
    container (integer custom_vjp outputs get float0 tangents); the bitcast
    must be bit-exact both ways, and fp8 must pass through untouched."""
    p = jnp.arange(-128, 128, dtype=jnp.int8).reshape(16, 16)
    t = hostmem.to_transport(p, "int8")
    assert t.dtype == jnp.float8_e4m3fn and t.shape == p.shape
    back = hostmem.from_transport(t, "int8")
    assert back.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(back), np.asarray(p))
    f = jnp.ones((4,), jnp.float8_e4m3fn)
    assert hostmem.to_transport(f, "fp8") is f
    assert hostmem.from_transport(f, "fp8") is f


def test_unknown_codec_rejected():
    with pytest.raises(ValueError, match="unknown offload codec"):
        hostmem.codec_wire_dtype("fp4")


# ---------------------------------------------------------------------------
# sub-byte accounting (the int4 overcount regression)
# ---------------------------------------------------------------------------


def test_aval_bytes_sub_byte_dtypes_are_bit_exact():
    """numpy reports itemsize 1 for the sub-byte ml_dtypes, so the old
    elems*itemsize walk overcounted int4/fp4 tensors 2x; the bit-width
    table must report packed bytes, rounding odd element counts up."""
    def b(shape, dtype):
        return ml._aval_bytes(jax.ShapeDtypeStruct(shape, dtype))

    assert np.dtype(jnp.int4).itemsize == 1   # the trap this fixes
    assert b((4, 8), jnp.int4) == 16          # 32 elems * 4 bits
    assert b((4, 8), jnp.uint4) == 16
    assert b((3,), jnp.int4) == 2             # (3*4+7)//8: rounds up
    assert b((4, 8), jnp.int8) == 32
    assert b((4, 8), jnp.bfloat16) == 64
    assert b((4, 8), jnp.float8_e4m3fn) == 32
    assert b((), jnp.float32) == 4


def test_tagged_walk_counts_packed_int4_bytes():
    """The jaxpr name-walk behind the ledger inherits the bit-exact
    accounting: a named int4 tensor contributes its packed bytes plus the
    element count the raw-drain reconstruction needs."""
    from jax.ad_checkpoint import checkpoint_name

    def f(x):
        q = x.astype(jnp.int4)
        return checkpoint_name(q, ofl.OFF_NAME + "@c0")

    per = ml.tagged_bytes_from_jaxpr(
        jax.make_jaxpr(f)(jnp.zeros((4, 8), jnp.float32)))
    assert per["@c0"]["off"] == 16
    assert per["@c0"]["off_elems"] == 32


def test_tagged_walk_counts_codec_scale_names():
    """act_scale@… names land in the per-suffix "scale" bucket, next to
    the wire-payload "off" bytes they belong to."""
    from jax.ad_checkpoint import checkpoint_name

    name = ofl.OFF_NAME + "@c0"

    def f(x):
        p, s = hostmem.quantize(x, "fp8")
        p = checkpoint_name(p, name)
        s = checkpoint_name(s, ofl.scale_name_for(name))
        return hostmem.dequantize(p, s, "fp8", x.dtype)

    per = ml.tagged_bytes_from_jaxpr(
        jax.make_jaxpr(f)(jnp.zeros((4, 8), jnp.bfloat16)))
    assert per["@c0"]["off"] == 32         # 32 fp8 payload bytes
    assert per["@c0"]["off_elems"] == 32
    assert per["@c0"]["scale"] == 16       # 4 rows * fp32
    assert ofl.scale_name_for(name) == "act_scale@c0"


# ---------------------------------------------------------------------------
# executed equivalence: compressed vs raw residency, pinned drift
# ---------------------------------------------------------------------------


def _pp1_step(codec, *, prefetch=None, pb=None, doc_lens=None):
    """One pp=1 loss+grad step of the reduced cell under `codec` — uniform
    batch, or a packed variable-length batch when `pb` is given."""
    cfg = get_config("qwen2-7b").reduced()
    mdef = build_model(cfg)
    B = pb.tokens.shape[0] if pb is not None else 2
    over = dict(n_chunks=4, grad_accum=1, offload=True,
                partition="length", offload_dtype=codec)
    if prefetch:
        over["prefetch"] = prefetch
    cell = resolve_cell(mdef, ShapeConfig("q", 256, B, "train"),
                        data_size=1, model_size=1, overrides=over,
                        doc_lens=doc_lens)
    cell = dataclasses.replace(cell, dtype=jnp.float32,
                               alphas=ALPHAS[:cell.sched.n])
    key = jax.random.PRNGKey(0)
    sp = mdef.init_stage_params(key, 0, 1, jnp.float32)
    g = mdef.init_globals(key, jnp.float32)
    if pb is not None:
        tokens, labels = jnp.asarray(pb.tokens), jnp.asarray(pb.labels)
        ds = jnp.asarray(pb.doc_start)
    else:
        tokens = jax.random.randint(key, (2, 256), 0, cfg.vocab_size)
        labels = jnp.roll(tokens, -1, axis=1)
        ds = None

    def loss(sp_, g_):
        out = run_pipeline(cell, SINGLE, sp_, g_, tokens, labels, None,
                           with_loss=True, doc_start=ds)
        return out["loss"] / jnp.maximum(out["denom"], 1.0)

    l, gr = jax.jit(jax.value_and_grad(loss, argnums=(0, 1)))(sp, g)
    flat = np.concatenate([np.asarray(x, np.float64).ravel()
                           for x in jax.tree_util.tree_leaves(gr)])
    return float(l), flat


def _drift(a, b):
    loss = abs(a[0] - b[0]) / max(abs(b[0]), 1e-9)
    grad = float(np.linalg.norm(a[1] - b[1])) / max(
        float(np.linalg.norm(b[1])), 1e-12)
    return loss, grad


@pytest.mark.parametrize("codec", ["fp8", "int8"])
def test_pp1_compressed_drift_within_pinned_tolerance(codec):
    """pp=1 chunk loop, alphas covering {0, frac, 1}: the 'ahead' capture
    forward is an identity (loss exact to fp32 noise), the compressed
    backward replay drifts but stays within the pinned bound — and it must
    drift (a zero-drift codec run means the codec never engaged)."""
    comp, raw = _pp1_step(codec), _pp1_step("none")
    loss_d, grad_d = _drift(comp, raw)
    assert loss_d <= 1e-5, (codec, loss_d)
    assert 1e-7 < grad_d <= GRAD_TOL[codec], (codec, grad_d)


def test_pp1_sync_prefetch_compressed_drift():
    """Under prefetch='sync' the quantized reconstruction IS the primal
    (host_round_trip substitutes the dequantized rows), so the loss itself
    drifts — within the codec resolution — and grads stay bounded, though
    looser than the 'ahead' seam (every downstream consumer of the
    reconstruction drifts too; measured ~7e-2 vs ~8e-3 ahead)."""
    comp = _pp1_step("fp8", prefetch="sync")
    raw = _pp1_step("none", prefetch="sync")
    loss_d, grad_d = _drift(comp, raw)
    assert loss_d <= 2e-2, loss_d
    assert 1e-7 < grad_d <= 0.1, grad_d


def test_pp1_varlen_packed_compressed_drift():
    """The packed variable-length cell (DESIGN.md §13) composes with the
    codec: segment-windowed attention over packed rows, compressed
    residency on the offloaded row splits."""
    from repro.data import pipeline as dpipe

    cfg = get_config("qwen2-7b").reduced()
    docs = dpipe.sample_corpus(8, vocab_size=cfg.vocab_size, seed=0,
                               dist="zipf", mean_len=48, max_len=192)
    lens = [len(d) for d in docs]
    pb = dpipe.pack_documents(docs, 256)
    comp = _pp1_step("fp8", pb=pb, doc_lens=lens)
    raw = _pp1_step("none", pb=pb, doc_lens=lens)
    loss_d, grad_d = _drift(comp, raw)
    assert loss_d <= 1e-5, loss_d
    assert 1e-7 < grad_d <= GRAD_TOL["fp8"], grad_d


def _mk_pp2_cell(mdef, codec, *, data_size=4, model_size=2):
    shape = ShapeConfig("q", 256, 4, "train")
    cell = resolve_cell(
        mdef, shape, data_size=data_size, model_size=model_size,
        overrides=dict(pp=2, dp=data_size // 2, n_chunks=len(ALPHAS),
                       grad_accum=1, partition="length", offload=True,
                       offload_dtype=codec))
    return dataclasses.replace(cell, dtype=jnp.float32, alphas=ALPHAS)


@pytest.mark.parametrize("codec", ["fp8", "int8"])
def test_pp2_compressed_drift_within_pinned_tolerance(codec, eight_devices):
    """Same law on the pp=2 tick loop (the prefetch seam transports the
    payload — int8 rides the fp8 bitcast container across the custom_vjp
    cotangent channel)."""
    cfg = get_config("qwen2-7b").reduced()
    mdef = build_model(cfg)
    key = jax.random.PRNGKey(7)
    tokens = jax.random.randint(key, (4, 256), 0, cfg.vocab_size)
    labels = jnp.roll(tokens, -1, axis=1)

    def step(c):
        fn, args = ml.build_step(c, data_size=4, model_size=2,
                                 tokens=tokens, labels=labels)
        l, gr = jax.jit(fn)(*args)
        flat = np.concatenate([np.asarray(x, np.float64).ravel()
                               for x in jax.tree_util.tree_leaves(gr)])
        return float(l), flat

    comp = step(_mk_pp2_cell(mdef, codec))
    raw = step(_mk_pp2_cell(mdef, "none"))
    loss_d, grad_d = _drift(comp, raw)
    assert loss_d <= 1e-5, (codec, loss_d)
    assert 1e-7 < grad_d <= GRAD_TOL[codec], (codec, grad_d)


# ---------------------------------------------------------------------------
# ledger: raw drain vs wire bytes vs scales, CSV round trip
# ---------------------------------------------------------------------------


def test_compressed_ledger_accounting_and_csv(eight_devices, tmp_path):
    """The measured ledger of a compressed pp=2 cell keeps three honest
    numbers per tick: off_bytes (raw device drain — still satisfies the
    alpha row-split law), off_wire_bytes (the 1-byte payload, itemsize-fold
    smaller), scale_bytes (device-resident fp32 scales); the peak stays
    bracketed by the compression-aware prediction, and everything round
    trips through the CSV."""
    cfg = get_config("qwen2-7b").reduced()
    mdef = build_model(cfg)
    cell = _mk_pp2_cell(mdef, "fp8")
    led = ml.measure(cell, data_size=4, model_size=2, baseline=False)
    assert led.offload_codec == "fp8"
    itemsize = jnp.dtype(cell.dtype).itemsize
    saw_off = False
    for r in led.ticks:
        acts = r.mat_bytes - r.scale_bytes
        frac = r.off_bytes / acts
        assert abs(frac - r.alpha) < 0.1, (r.tick, frac, r.alpha)
        if r.off_bytes:
            saw_off = True
            # fp32 activations on a 1-byte wire: exactly itemsize-fold
            assert r.off_wire_bytes * itemsize == r.off_bytes, vars(r)
            assert r.scale_bytes > 0
        else:
            assert r.off_wire_bytes == 0 and r.scale_bytes == 0
    assert saw_off
    assert led.off_wire_bytes_total * itemsize == led.off_bytes_total
    assert led.host_bytes == led.off_wire_bytes_total
    predicted = ml.predicted_spmd_peak(cell)
    assert led.peak_bytes <= 1.1 * predicted, (led.peak_bytes, predicted)
    assert led.peak_bytes >= 0.8 * predicted, (led.peak_bytes, predicted)
    # compression strictly cuts the priced reload lane at fixed alphas
    bw = cm.V5E.d2h_bw
    cell_raw = dataclasses.replace(
        cell, plan=dataclasses.replace(cell.plan, offload_dtype="none"))
    led_raw = ml.measure(cell_raw, data_size=4, model_size=2,
                         baseline=False)
    assert led.off_bytes_total == led_raw.off_bytes_total
    assert led.off_wire_bytes_total < led_raw.off_wire_bytes_total
    assert led.price_h2d(bw=bw, prefetch="sync") < led_raw.price_h2d(
        bw=bw, prefetch="sync")

    path = tmp_path / "quant.csv"
    led.to_csv(str(path))
    back = ml.read_csv(str(path))
    assert back["summary"]["offload_codec"] == "fp8"
    assert back["summary"]["off_bytes_total"] == led.off_bytes_total
    assert back["summary"]["off_wire_bytes_total"] == \
        led.off_wire_bytes_total
    assert back["summary"]["scale_bytes_total"] == led.scale_bytes_total
    assert back["summary"]["host_bytes"] == led.host_bytes
    for row, r in zip(back["rows"], led.ticks):
        assert row["off_bytes"] == r.off_bytes
        assert row["off_wire_bytes"] == r.off_wire_bytes
        assert row["scale_bytes"] == r.scale_bytes


def test_uncompressed_ledger_wire_equals_raw(eight_devices, tmp_path):
    """With codec 'none' the wire view collapses onto the raw bytes and the
    scale column is zero — the compressed-channel fields add no drift to
    the existing accounting."""
    cfg = get_config("qwen2-7b").reduced()
    mdef = build_model(cfg)
    cell = _mk_pp2_cell(mdef, "none")
    led = ml.measure(cell, data_size=4, model_size=2, baseline=False)
    assert led.offload_codec == "none"
    for r in led.ticks:
        assert r.off_wire_bytes == r.off_bytes
        assert r.scale_bytes == 0
    path = tmp_path / "raw.csv"
    led.to_csv(str(path))
    back = ml.read_csv(str(path))
    assert back["summary"]["offload_codec"] == "none"
    assert back["summary"]["off_wire_bytes_total"] == led.off_bytes_total


# ---------------------------------------------------------------------------
# compressed moments residency
# ---------------------------------------------------------------------------


def _tiny_params(key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w": jax.random.normal(k1, (16, 32), jnp.float32) * 0.1,
            "o": jax.random.normal(k2, (32, 16), jnp.float32) * 0.1,
            "b": jax.random.normal(k3, (32,), jnp.float32) * 0.1}


@pytest.mark.optstate
@pytest.mark.parametrize("codec,tol", [("fp8", 1e-2), ("int8", 3e-2)])
def test_compressed_moments_residency_and_drift(codec, tol):
    """moments_dtype residency: host leaves are (payload, scale) pairs in
    the wire dtype, step 1 matches raw exactly (zero moments dequantize to
    zero), and the step-2 parameters — the first step that reads back
    quantized moments — stay within the codec-resolution drift bound
    (measured ~3e-3 fp8 / ~1.3e-2 int8: int8 is coarser than fp8 for the
    *second* moment, whose wide dynamic range favors the float codec)."""
    from repro.optim import adamw

    key = jax.random.PRNGKey(3)
    params = _tiny_params(key)
    grads = jax.tree_util.tree_map(
        lambda p: jax.random.normal(jax.random.PRNGKey(9), p.shape,
                                    jnp.float32), params)

    def run(moments_dtype, steps=2):
        state = adamw.init_state(params, jnp.float32, offload_moments=True,
                                 moments_dtype=moments_dtype)
        p = params
        outs = []
        for _ in range(steps):
            p, state, _ = adamw.apply_update(
                p, grads, state, lr=1e-2, offload_moments=True,
                moments_mode="explicit", moments_dtype=moments_dtype)
            outs.append(p)
        return outs, state

    (p1_c, p2_c), state_c = run(codec)
    (p1_r, p2_r), _ = run("none")
    for a, b in zip(jax.tree_util.tree_leaves(p1_c),
                    jax.tree_util.tree_leaves(p1_r)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0, atol=1e-6)
    flat_c = np.concatenate([np.asarray(l, np.float64).ravel()
                             for l in jax.tree_util.tree_leaves(p2_c)])
    flat_r = np.concatenate([np.asarray(l, np.float64).ravel()
                             for l in jax.tree_util.tree_leaves(p2_r)])
    drift = np.linalg.norm(flat_c - flat_r) / np.linalg.norm(flat_r)
    assert 0.0 < drift <= tol, (codec, drift)
    # residency shape: every param leaf became a (payload, scale) pair
    wire = hostmem.codec_wire_dtype(codec)
    n_param_leaves = len(jax.tree_util.tree_leaves(params))
    leaves_m = jax.tree_util.tree_leaves(state_c.m)
    assert len(leaves_m) == 2 * n_param_leaves
    payloads = [l for l in leaves_m if l.dtype == wire]
    scales = [l for l in leaves_m if l.dtype == jnp.float32]
    assert len(payloads) == n_param_leaves == len(scales)


@pytest.mark.optstate
def test_compressed_moments_init_with_last_axis_sharded_params(eight_devices):
    """Regression: a model-sharded (rows, d) param must not hand its
    last-axis partition to the (rows, 1) scale buffer — the singleton axis
    cannot divide by the mesh's model size (train.py --moments-dtype hit
    this at init).  The payload keeps the param's sharding; the scale gets
    it with the trailing axis unpartitioned (hostmem.row_scale_sharding)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import make_test_mesh
    from repro.optim import adamw

    kind = hostmem.host_memory_kind()
    if kind is None:
        pytest.skip("backend has no host memory kind")
    mesh = make_test_mesh(4, 2)
    # transfer-lint: ok (test fixture, device placement only)
    p = jax.device_put(jnp.ones((64, 32), jnp.float32),
                       NamedSharding(mesh, P(None, "model")))
    state = adamw.init_state({"w": p}, jnp.float32, offload_moments=True,
                             moments_dtype="fp8")
    payload, scale = state.m["w"]
    assert payload.shape == (64, 32) and scale.shape == (64, 1)
    assert hostmem.memory_kind_of(payload) == kind
    assert hostmem.memory_kind_of(scale) == kind
    assert payload.sharding.spec == P(None, "model")
    assert scale.sharding.spec[-1] is None


@pytest.mark.optstate
def test_compressed_moment_bytes_match_closed_form():
    """Measured host-resident moment bytes (payload + scales) equal the
    cost model's compressed closed form over the same shapes."""
    from repro.optim import adamw

    params = _tiny_params(jax.random.PRNGKey(0))
    state = adamw.init_state(params, jnp.float32, offload_moments=True,
                             moments_dtype="fp8")
    measured = sum(int(l.nbytes)
                   for l in jax.tree_util.tree_leaves(state.m)) + \
        sum(int(l.nbytes) for l in jax.tree_util.tree_leaves(state.v))
    shapes = [tuple(l.shape)
              for l in jax.tree_util.tree_leaves(params)]
    assert measured == cm.moment_bytes_from_shapes(shapes, "float32", "fp8")


@pytest.mark.optstate
def test_moments_dtype_requires_explicit_offload():
    from repro.optim import adamw

    params = _tiny_params(jax.random.PRNGKey(0))
    with pytest.raises(AssertionError, match="offload_moments"):
        adamw.init_state(params, jnp.float32, offload_moments=False,
                         moments_dtype="fp8")
    state = adamw.init_state(params, jnp.float32, offload_moments=True,
                             moments_dtype="fp8")
    with pytest.raises(AssertionError, match="explicit"):
        adamw.apply_update(params, params, state, lr=1e-3,
                           offload_moments=True, moments_mode="xla",
                           moments_dtype="fp8")


# ---------------------------------------------------------------------------
# analytic side: wire ratio and scale terms
# ---------------------------------------------------------------------------


def test_wire_ratio_and_scale_terms():
    assert cm.offload_wire_ratio("none") == 1.0
    assert cm.offload_wire_ratio("fp8") == 0.5   # 1 byte vs bf16
    assert cm.offload_wire_ratio("int8") == 0.5
    cfg = get_config("qwen2-7b").reduced()
    lens = [64, 64]
    zero = cm.chunk_scale_bytes(cfg, lens, batch=2, pp=1, sp=1)
    assert all(z == 0.0 for z in zero)
    sb = cm.chunk_scale_bytes(cfg, lens, batch=2, pp=1, sp=1,
                              offload_dtype="fp8")
    assert all(b > 0 for b in sb)
    # scales are fp32 per trailing-axis row: strictly smaller than the
    # payload they describe
    acts = cm.chunk_act_bytes(cfg, lens, batch=2, pp=1, sp=1)
    assert all(s < a for s, a in zip(sb, acts))


def test_solver_alpha_grows_under_compression():
    """The alpha planner sees the link at its effective raw-bytes rate
    (wire_ratio halves the bytes per offloaded row), so compressed plans
    offload at least as much as raw plans on every chunk."""
    from repro.core import solver

    cfg = get_config("qwen2-7b")
    _, a_raw, _ = solver.simulate_candidate(
        cfg, 65536, 1, 7_000_000_000, 2, 8, 16)
    _, a_c, _ = solver.simulate_candidate(
        cfg, 65536, 1, 7_000_000_000, 2, 8, 16, offload_dtype="fp8")
    assert all(c >= r for c, r in zip(a_c, a_raw)), (a_c, a_raw)
    assert sum(a_c) >= sum(a_raw)
