"""Serving-path tests: the static lock-step fixes and the paged-pool
continuous-batching engine (DESIGN.md §16).

Covers the silent-corruption bugs this area shipped with:
  * decode budget overrun — ``make_serve_step`` must reject a decode run
    the striped cache cannot absorb (the clamped write used to wrap onto
    the last slot silently);
  * the token demux — ``gather_decode_tokens`` must be shape-exact (the
    old ``[:batch]`` slice dropped or duplicated requests when the batch
    did not match the shard layout);
  * the prefill→decode cache-geometry contract — a prefill-built cache
    must decode bit-identically to a longer prefill (pp drain ticks used
    to clobber every non-last stage's cache with zeros);
and the pool engine's core invariants: continuous-mode token streams equal
static lock-step and solo runs bitwise, and freed blocks are recycled.

Engine tests are marked ``serving`` and run in the serve-gate CI leg.
"""
import functools

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.base import ShapeConfig, get_config
from repro.launch.serve import gather_decode_tokens, shard_rows
from repro.models.model_zoo import build_model
from repro.parallel.runner import (DECODE_BUDGET, make_serve_step,
                                   max_decode_steps, resolve_cell)
from repro.runtime import kvpool


def _decode_cell(data_size=1, model_size=1, seq=64, batch=2, **overrides):
    cfg = get_config("qwen2-7b").reduced()
    mdef = build_model(cfg)
    shape = ShapeConfig("t_dec", seq, batch, "decode")
    return resolve_cell(mdef, shape, data_size=data_size,
                        model_size=model_size,
                        overrides=dict(pp=1, dp=data_size, **overrides))


# ---------------------------------------------------------------------------
# Satellite 1: decode budget guard
# ---------------------------------------------------------------------------


def test_decode_budget_guard():
    """A decode run longer than the cache's striped budget is rejected at
    construction (the raise happens before any tracing, so no mesh work)."""
    from repro.launch.mesh import make_test_mesh

    cell = _decode_cell(model_size=2)
    mesh = make_test_mesh(1, 2)
    assert max_decode_steps(cell) == DECODE_BUDGET * cell.plan.sp
    with pytest.raises(ValueError, match="decode budget"):
        make_serve_step(cell, mesh, decode_steps=max_decode_steps(cell) + 1)
    # at the budget exactly: allowed
    make_serve_step(cell, mesh, decode_steps=max_decode_steps(cell))


# ---------------------------------------------------------------------------
# Satellite 2: shape-exact token demux
# ---------------------------------------------------------------------------


@given(st.integers(1, 4), st.integers(1, 3), st.integers(1, 4))
@settings(max_examples=20, deadline=None)
def test_shard_rows_gather_roundtrip(dp, pp, b_loc):
    batch = dp * b_loc
    prompts = np.arange(batch * 5, dtype=np.int32).reshape(batch, 5)
    rows = shard_rows(prompts, dp, pp)
    assert rows.shape == (1, dp * pp, b_loc, 5)
    # every stage row of a dp group carries the group's shard
    for g in range(dp):
        for s in range(pp):
            np.testing.assert_array_equal(
                rows[0, g * pp + s], prompts[g * b_loc:(g + 1) * b_loc])
    # a decode step emits [dp*pp, b_loc, 1]; the gather is the exact inverse
    nxt = rows[0, :, :, :1]
    out = gather_decode_tokens(nxt, dp, pp, batch)
    np.testing.assert_array_equal(out, prompts[:, 0])


def test_shard_rows_rejects_indivisible_batch():
    with pytest.raises(ValueError, match="does not divide"):
        shard_rows(np.zeros((3, 4), np.int32), dp=2, pp=1)


def test_gather_rejects_wrong_shapes():
    nxt = np.zeros((4, 2, 1), np.int32)
    with pytest.raises(ValueError, match="data rows"):
        gather_decode_tokens(nxt, dp=3, pp=1, batch=6)
    with pytest.raises(ValueError, match="caller expects"):
        gather_decode_tokens(nxt, dp=2, pp=2, batch=8)


def test_serve_cli_rejects_indivisible_batch():
    """The CLI validates batch % dp before building any params."""
    from repro.launch import serve

    with pytest.raises(ValueError, match="does not divide"):
        serve.main(["--arch", "qwen2-7b", "--reduced", "--mesh", "2x1",
                    "--prompt-len", "64", "--batch", "3",
                    "--decode-steps", "2"])


# ---------------------------------------------------------------------------
# Satellite 3: prefill -> decode cache-geometry contract
# ---------------------------------------------------------------------------


@pytest.mark.serving
@pytest.mark.parametrize("pp", [1, 2])
def test_prefill_decode_cache_contract(pp):
    """A cache built by prefill(S) plus one decode step of the last prompt
    token equals prefill(S+1) of the prompt with that token appended —
    bit-exact, including the pp>1 tick pipeline (whose drain ticks used to
    zero every non-last stage's cache)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding

    from repro.launch.mesh import make_test_mesh
    from repro.launch.train import build_params
    from repro.parallel.runner import batch_struct, make_prefill_step

    cfg = get_config("qwen2-7b").reduced()
    mdef = build_model(cfg)
    S, B = 63, 2
    data_size, model_size = pp, 1
    mesh = make_test_mesh(data_size, model_size)
    ovr = dict(pp=pp, dp=1, n_chunks=1, offload=False, remat="none")
    cell_s = resolve_cell(mdef, ShapeConfig("c_pre", S, B, "prefill"),
                          data_size=data_size, model_size=model_size,
                          overrides=dict(ovr))
    cell_s1 = resolve_cell(mdef, ShapeConfig("c_pre1", S + 1, B, "prefill"),
                           data_size=data_size, model_size=model_size,
                           overrides=dict(ovr))
    cell_d = resolve_cell(mdef, ShapeConfig("c_dec", S, B, "decode"),
                          data_size=data_size, model_size=model_size,
                          overrides=dict(pp=pp, dp=1))
    params, _, _ = build_params(cell_s, mesh)
    rng = np.random.default_rng(0)
    prompts = rng.integers(2, cfg.vocab_size, size=(B, S)).astype(np.int32)
    ext = np.concatenate([prompts, prompts[:, -1:]], axis=1)

    def run_prefill(cell, toks):
        fn, _, _ = make_prefill_step(cell, mesh)
        _, bspecs = batch_struct(cell)
        tok = np.stack([toks] * data_size)[None]
        batch = {"tokens": jnp.asarray(tok), "labels": jnp.asarray(tok)}
        # transfer-lint: ok (test input staging onto the mesh)
        batch = {k: jax.device_put(v, NamedSharding(mesh, bspecs[k]))
                 for k, v in batch.items() if k in bspecs}
        return jax.jit(fn)(params, batch)

    state_s, _ = run_prefill(cell_s, prompts)
    state_s1, _ = run_prefill(cell_s1, ext)
    serve_fn, _, _ = make_serve_step(cell_d, mesh)
    dbatch = {"tokens": jnp.asarray(
        np.stack([prompts[:, -1:]] * data_size)[None]),
        "pos": jnp.int32(S)}
    state_d, _ = jax.jit(serve_fn)(params, state_s, dbatch)

    for name in ("k", "v", "pos"):
        got = np.asarray(getattr(state_d["kv"], name))
        want = np.asarray(getattr(state_s1["kv"], name))
        # caches may differ in decode budget; compare the written extent
        # (cache slots are axis 3 on k/v [data, slot, B, S_loc, Hkv, hd]
        # and the last axis on pos [data, slot, S_loc])
        ax = 3 if name != "pos" else got.ndim - 1
        np.testing.assert_array_equal(
            np.take(got, np.arange(S + 1), axis=ax),
            np.take(want, np.arange(S + 1), axis=ax),
            err_msg=f"cache {name} (pp={pp})")


# ---------------------------------------------------------------------------
# Satellite 4: continuous == static == solo, and block recycling
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=1)
def _engine():
    """One jit-compiled engine shared by the scheduling tests (the stub
    hypothesis runner has a zero-arg signature, so a pytest fixture cannot
    reach the property test — a memoised builder serves both)."""
    from repro.launch.mesh import make_test_mesh
    from repro.launch.serve import ServeEngine

    mesh = make_test_mesh(1, 2)
    return ServeEngine("qwen2-7b", mesh, s_bucket=32, slots=2, max_new=4,
                       block_tokens=4, admit_min_free=1, reduced=True)


def _trace(engine, seed, n=5):
    from repro.launch.serve import Request

    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(4, engine.geo.s_bucket + 1))
        reqs.append(Request(
            rid=i,
            prompt=rng.integers(2, engine.cfg.vocab_size,
                                size=plen).astype(np.int32),
            max_new=int(rng.integers(1, engine.geo.max_new + 1)),
            arrival=int(rng.integers(0, 5))))
    return reqs


@pytest.mark.serving
@given(st.integers(0, 10_000))
@settings(max_examples=3, deadline=None)
def test_continuous_equals_static_and_solo(seed):
    """Per-request token streams are bitwise identical whether a request is
    decoded continuously, in lock-step waves, or entirely alone — the pool
    rows are independent, so scheduling must not leak into the samples."""
    engine = _engine()
    reqs = _trace(engine, seed)
    cont, _ = engine.run(reqs, mode="continuous")
    stat, _ = engine.run(reqs, mode="static")
    for r in reqs:
        np.testing.assert_array_equal(cont[r.rid], stat[r.rid],
                                      err_msg=f"rid {r.rid} cont vs static")
    # solo: each request through an otherwise-empty engine
    from repro.launch.serve import Request

    for r in reqs:
        solo, _ = engine.run(
            [Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new)],
            mode="static")
        np.testing.assert_array_equal(cont[r.rid], solo[r.rid],
                                      err_msg=f"rid {r.rid} cont vs solo")


@pytest.mark.serving
def test_pool_blocks_recycled():
    """Over a trace longer than the pool, lifetime allocations exceed the
    physical block count while the peak stays within the analytic
    concurrency bound — freed blocks really are reused."""
    from repro.launch.serve import Request

    engine = _engine()
    rng = np.random.default_rng(3)
    reqs = [Request(rid=i,
                    prompt=rng.integers(2, engine.cfg.vocab_size,
                                        size=8).astype(np.int32),
                    max_new=4, arrival=i)
            for i in range(8)]
    toks, stats = engine.run(reqs, mode="continuous")
    geo = engine.geo
    # analytic bound: blocks_for(max_new) per request over its [admit, done)
    bound = kvpool.concurrent_peak(
        [(s, e, geo.blocks_for(4)) for (s, e) in stats.spans.values()])
    assert stats.peak_blocks[0] <= bound <= geo.n_blocks
    assert stats.total_blocks[0] > geo.n_blocks, (
        "trace too short to prove recycling")
    assert all(len(toks[r.rid]) == r.max_new for r in reqs)


def test_block_pool_allocator_invariants():
    pool = kvpool.BlockPool(4)
    a = pool.alloc(3)
    assert pool.used == 3 and pool.free_blocks == 1
    with pytest.raises(MemoryError):
        pool.alloc(2)
    pool.free(a[:2])
    b = pool.alloc(2)
    assert set(b) <= set(range(4))
    assert pool.peak_used == 3
    assert pool.total_allocated == 5


def test_concurrent_peak_sweep():
    # [0,4)x2, [2,6)x3, [6,8)x4 -> peak 5 inside [2,4)
    assert kvpool.concurrent_peak([(0, 4, 2), (2, 6, 3), (6, 8, 4)]) == 5
    assert kvpool.concurrent_peak([]) == 0


# ---------------------------------------------------------------------------
# Type-0 ledger channel round-trip
# ---------------------------------------------------------------------------


def test_pool_channel_csv_roundtrip(tmp_path):
    from repro.runtime.memledger import MemLedger, PoolChannel

    led = MemLedger(pool=PoolChannel(
        n_blocks=18, block_tokens=8, n_layers=2,
        measured_bytes=18432, predicted_bytes=18432,
        peak_blocks=18, total_blocks=54))
    path = tmp_path / "pool.csv"
    led.to_csv(str(path))
    from repro.runtime.memledger import read_csv

    summary = read_csv(str(path))["summary"]
    assert summary["kv_pool_bytes"] == 18432
    assert summary["kv_pool_predicted_bytes"] == 18432
    assert summary["kv_pool_blocks"] == 18
    assert summary["kv_pool_block_tokens"] == 8
    assert summary["kv_pool_peak_blocks"] == 18
    assert summary["kv_pool_total_blocks"] == 54
    assert led.pool.ratio == 1.0
