"""Packed variable-length scheduling (DESIGN.md §13).

Four layers of law:

  1. partition boundary hygiene — `partition_length` / `partition_flops` /
     `partition_profile` neither drop nor duplicate tokens for ANY
     (seq_len, n, multiple), including n*multiple > seq_len and
     seq_len % multiple != 0 (hypothesis);
  2. the packer is a permutation-free partition — every document lands
     contiguously in exactly one row, the token multiset is preserved, and
     the q_start window mask equals the seg-id mask (documents never attend
     across boundaries); `shard_batch` round-trips the packed layout;
  3. kernel parity — the Pallas flash kernel and the blockwise-jnp
     reference agree on the q_start segment window, forward and grads,
     including fully-padded (dead) query rows;
  4. oracle equality — packed loss AND grads match the pad-to-max oracle
     (one doc per row at its packed offsets: bit-identical positions) at
     pp=1 and pp=2, fp32 <= 1e-5; and the varlen budget cell's measured
     ledger peak is bracketed by the simulator's prediction.
"""
import dataclasses
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import ShapeConfig, get_config
from repro.core import partition as part
from repro.data import pipeline as dpipe
from repro.models.model_zoo import build_model
from repro.parallel.ctx import SINGLE
from repro.parallel.runner import resolve_cell, run_pipeline


# ---------------------------------------------------------------------------
# 1. partition boundary hygiene (the satellite bugfix pin)
# ---------------------------------------------------------------------------


@settings(max_examples=120, deadline=None)
@given(st.integers(1, 4096), st.integers(1, 32),
       st.sampled_from([1, 2, 8, 16, 128]))
def test_partition_length_never_drops_tokens(seq_len, n, multiple):
    sched = part.partition_length(seq_len, n, multiple)
    assert sum(sched.lengths) == seq_len
    assert all(l > 0 for l in sched.lengths)
    assert sched.offsets == tuple(
        sum(sched.lengths[:i]) for i in range(sched.n))
    # feasibility clamp: never more chunks than multiple-sized slots
    assert sched.n <= max(1, min(n, seq_len // multiple))
    if sched.n > 1:
        # every chunk except the remainder-absorbing last is aligned
        assert all(l % multiple == 0 for l in sched.lengths[:-1])


@settings(max_examples=120, deadline=None)
@given(st.integers(1, 4096), st.integers(1, 32),
       st.sampled_from([1, 2, 8, 16, 128]),
       st.floats(0.001, 2.0))
def test_partition_flops_never_drops_tokens(seq_len, n, multiple, r):
    sched = part.partition_flops(seq_len, n, r, multiple)
    assert sum(sched.lengths) == seq_len
    assert all(l > 0 for l in sched.lengths)
    if sched.n > 1:
        # interior boundaries are multiple-aligned (sequence-shard
        # divisibility); the last chunk absorbs the remainder
        for off in sched.offsets[1:]:
            assert off % multiple == 0


@settings(max_examples=60, deadline=None)
@given(st.integers(8, 1024), st.integers(1, 16),
       st.sampled_from([1, 2, 8]), st.floats(0.0, 1.0))
def test_partition_profile_never_drops_tokens(seq_len, n, multiple, r):
    rng = np.random.default_rng(seq_len * 31 + n)
    profile = 1.0 + r * rng.random(seq_len)
    sched = part.partition_profile(profile, n, multiple)
    assert sum(sched.lengths) == seq_len
    assert all(l > 0 for l in sched.lengths)
    for off in sched.offsets[1:]:
        assert off % multiple == 0


def test_partition_profile_snaps_to_doc_bounds():
    # uniform profile balances at multiples of 64; a doc boundary 6 tokens
    # off must win (it costs bounded imbalance, saves a split document)
    profile = np.ones(256)
    sched = part.partition_profile(profile, 4, 2, doc_bounds=[58, 198])
    assert 58 in sched.offsets
    # far-away doc bounds (outside the window) are NOT taken
    sched2 = part.partition_profile(profile, 4, 2, doc_bounds=[10])
    assert 10 not in sched2.offsets


def test_profile_chunk_costs_cover_profile():
    prof = np.arange(1, 65, dtype=np.float64)
    sched = part.partition_profile(prof, 4, 1)
    costs = part.profile_chunk_costs(prof, sched)
    np.testing.assert_allclose(sum(costs), prof.sum())


# ---------------------------------------------------------------------------
# 2. the packer is a permutation-free partition
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 40), st.sampled_from([64, 96, 256]),
       st.sampled_from(["zipf", "lognormal"]), st.integers(0, 5))
def test_packer_preserves_token_multiset(n_docs, seq_len, dist, seed):
    docs = dpipe.sample_corpus(n_docs, vocab_size=97, seed=seed, dist=dist,
                               mean_len=24, max_len=seq_len)
    pb = dpipe.pack_documents(docs, seq_len)
    # every doc contiguous in exactly one row, bytes equal
    assert sorted(di for (_, _, _, di) in pb.spans) == list(range(n_docs))
    for row, s, e, di in pb.spans:
        np.testing.assert_array_equal(pb.tokens[row, s:e], docs[di])
        assert (pb.seg_ids[row, s:e] == di).all()
        assert (pb.doc_start[row, s:e] == s).all()
    # token multiset preserved: nothing dropped, nothing duplicated
    got = Counter(pb.tokens[pb.seg_ids >= 0].tolist())
    want = Counter(np.concatenate(docs).tolist())
    assert got == want
    # padding slots carry the sentinels
    pad = pb.seg_ids < 0
    assert (pb.doc_start[pad] == dpipe.PAD_START).all()
    assert (pb.labels[pad] == dpipe.IGNORE_LABEL).all()
    # labels: in-document shift; each doc's last token is ignored
    for row, s, e, di in pb.spans:
        np.testing.assert_array_equal(pb.labels[row, s:e - 1], docs[di][1:])
        assert pb.labels[row, e - 1] == dpipe.IGNORE_LABEL


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 24), st.integers(0, 3))
def test_qstart_window_equals_segment_mask(n_docs, seed):
    """The q_start window (what attention executes) and the seg-id equality
    mask (the definition) select identical visibility: packed documents
    never attend across boundaries, padding attends to nothing."""
    S = 128
    docs = dpipe.sample_corpus(n_docs, vocab_size=97, seed=seed,
                               mean_len=24, max_len=S)
    pb = dpipe.pack_documents(docs, S)
    pos = np.arange(S)
    for b in range(pb.tokens.shape[0]):
        seg = pb.seg_ids[b]
        # definition: same document, causal
        mask_seg = ((seg[:, None] == seg[None, :])
                    & (seg[:, None] >= 0)
                    & (pos[:, None] >= pos[None, :]))
        # executed: causal AND kv position inside the query's window
        mask_win = ((pos[:, None] >= pos[None, :])
                    & (pos[None, :] >= pb.doc_start[b][:, None])
                    & (seg[None, :] >= 0).repeat(S, 0))
        np.testing.assert_array_equal(mask_win, mask_seg)


def test_shard_batch_roundtrips_packed_layout():
    docs = dpipe.sample_corpus(10, vocab_size=97, seed=1, mean_len=24,
                               max_len=128)
    pb = dpipe.pack_documents(docs, 128, rows=8)
    batch = dpipe.shard_batch(pb.tokens, pb.labels, pods=2, data_size=4,
                              pp=2, doc_start=pb.doc_start)
    assert set(batch) == {"tokens", "labels", "doc_start"}
    dp, b_loc = 2, 8 // (2 * 2)
    for key, src in (("tokens", pb.tokens), ("labels", pb.labels),
                     ("doc_start", pb.doc_start)):
        assert batch[key].shape == (2, 4, b_loc, 128)
        for p in range(2):
            for i in range(4):
                lo = (p * dp + i // 2) * b_loc
                np.testing.assert_array_equal(batch[key][p, i],
                                              src[lo:lo + b_loc])


def test_pack_lengths_rejects_oversized_docs():
    with pytest.raises(AssertionError):
        part.pack_lengths([4, 300], 256)


# ---------------------------------------------------------------------------
# 3. kernel parity: ref vs pallas on the q_start segment window
# ---------------------------------------------------------------------------


def _varlen_attn_case(seed=0):
    """[B=2, Tq=S=32] self-attention chunk with two docs in row 0 and one
    doc + dead padding tail in row 1."""
    from repro.kernels.ref import PAD_POS

    key = jax.random.PRNGKey(seed)
    B, T, H, Hkv, hd = 2, 32, 4, 2, 16
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, T, H, hd), jnp.float32)
    k = jax.random.normal(kk, (B, T, Hkv, hd), jnp.float32)
    v = jax.random.normal(kv, (B, T, Hkv, hd), jnp.float32)
    q_pos = jnp.arange(T, dtype=jnp.int32)
    kv_pos = jnp.arange(T, dtype=jnp.int32)
    q_start = np.zeros((B, T), np.int32)
    q_start[0, 20:] = 20          # row 0: docs [0,20) and [20,32)
    q_start[1, 24:] = int(PAD_POS)  # row 1: doc [0,24), dead padding tail
    return q, k, v, q_pos, kv_pos, jnp.asarray(q_start)


def test_qstart_ref_matches_dense_oracle():
    from repro.kernels.ref import (attention_partial_ref, mha_reference,
                                   normalize)

    q, k, v, q_pos, kv_pos, q_start = _varlen_attn_case()
    o, m, l = attention_partial_ref(q, k, v, q_pos, kv_pos, q_start=q_start)
    got = normalize(o, l)
    want = mha_reference(q, k, v, q_pos, kv_pos, q_start=q_start)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=0)
    # dead rows (fully masked) produce exactly zero output
    assert (np.asarray(got)[1, 24:] == 0.0).all()


def test_qstart_pallas_matches_ref_fwd_and_grads():
    from repro.kernels.flash_attention import flash_attention_partial
    from repro.kernels.ref import attention_partial_ref, normalize

    q, k, v, q_pos, kv_pos, q_start = _varlen_attn_case()
    w = jax.random.normal(jax.random.PRNGKey(9), q.shape[:3] + (16,),
                          jnp.float32)

    def run(fn):
        def loss(q, k, v):
            o, m, l = fn(q, k, v)
            return jnp.sum(normalize(o, l) * w), (o, m, l)

        (val, oml), grads = jax.value_and_grad(
            loss, argnums=(0, 1, 2), has_aux=True)(q, k, v)
        return val, oml, grads

    v_ref, (o_r, m_r, l_r), g_ref = run(
        lambda q, k, v: attention_partial_ref(q, k, v, q_pos, kv_pos,
                                              q_start=q_start))
    v_pl, (o_p, m_p, l_p), g_pl = run(
        lambda q, k, v: flash_attention_partial(q, k, v, q_pos, kv_pos,
                                                q_start=q_start,
                                                interpret=True))
    np.testing.assert_allclose(float(v_pl), float(v_ref), atol=1e-5, rtol=0)
    np.testing.assert_allclose(o_p, o_r, atol=1e-5, rtol=0)
    np.testing.assert_allclose(l_p, l_r, atol=1e-5, rtol=0)
    for gp, gr in zip(g_pl, g_ref):
        np.testing.assert_allclose(gp, gr, atol=1e-5, rtol=0)
        assert np.isfinite(np.asarray(gp)).all()
    # dead-row queries get exactly zero gradient on both backends
    assert (np.asarray(g_pl[0])[1, 24:] == 0.0).all()
    assert (np.asarray(g_ref[0])[1, 24:] == 0.0).all()


def test_qstart_none_is_identity():
    """Threading q_start=None (every non-packed call site) is numerically
    identical to the pre-varlen kernels — zero-window == no window."""
    from repro.kernels.flash_attention import flash_attention_partial
    from repro.kernels.ref import attention_partial_ref

    q, k, v, q_pos, kv_pos, _ = _varlen_attn_case()
    zeros = jnp.zeros((q.shape[0], q.shape[1]), jnp.int32)
    for fn in (attention_partial_ref,
               lambda *a, **kw: flash_attention_partial(*a, interpret=True,
                                                        **kw)):
        o0, m0, l0 = fn(q, k, v, q_pos, kv_pos, q_start=None)
        o1, m1, l1 = fn(q, k, v, q_pos, kv_pos, q_start=zeros)
        np.testing.assert_array_equal(np.asarray(o0), np.asarray(o1))
        np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))


# ---------------------------------------------------------------------------
# 4. oracle equality + the varlen budget cell
# ---------------------------------------------------------------------------


def _corpus(cfg, n_docs=10, seed=3):
    docs = dpipe.sample_corpus(n_docs, vocab_size=cfg.vocab_size, seed=seed,
                               dist="zipf", mean_len=48, max_len=200)
    return docs, [len(d) for d in docs]


def _pp1_loss_grads(mdef, pb, doc_lens, backend="jnp"):
    from repro.kernels import ops as kops

    B = pb.tokens.shape[0]
    shape = ShapeConfig("t", pb.tokens.shape[1], B, "train")
    cell = resolve_cell(mdef, shape, data_size=1, model_size=1,
                        overrides=dict(n_chunks=4, grad_accum=1,
                                       partition="flops"),
                        doc_lens=doc_lens)
    cell = dataclasses.replace(cell, dtype=jnp.float32)
    key = jax.random.PRNGKey(0)
    sp1 = mdef.init_stage_params(key, 0, 1, jnp.float32)
    g1 = mdef.init_globals(key, jnp.float32)
    tok, lab = jnp.asarray(pb.tokens), jnp.asarray(pb.labels)
    ds = jnp.asarray(pb.doc_start)

    def f(sp_, g_):
        out = run_pipeline(cell, SINGLE, sp_, g_, tok, lab, None,
                           with_loss=True, doc_start=ds)
        return out["loss"] / jnp.maximum(out["denom"], 1.0)

    with kops.backend(backend):
        return jax.jit(jax.value_and_grad(f, argnums=(0, 1)))(sp1, g1)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_packed_equals_pad_to_max_oracle_pp1(backend):
    """Tentpole law at pp=1: packed loss and grads match the per-sequence
    pad-to-max oracle (docs at their packed offsets — positions, RoPE
    angles and causal windows bit-identical) to fp32 <= 1e-5."""
    cfg = get_config("qwen2-7b").reduced()
    mdef = build_model(cfg)
    docs, lens = _corpus(cfg)
    packed = dpipe.pack_documents(docs, 256)
    oracle = dpipe.pad_to_max(docs, 256, at_packed_offsets=packed)
    l_p, g_p = _pp1_loss_grads(mdef, packed, lens, backend)
    l_o, g_o = _pp1_loss_grads(mdef, oracle, lens, backend)
    np.testing.assert_allclose(float(l_p), float(l_o), atol=1e-5, rtol=0)
    for a, b in zip(jax.tree_util.tree_leaves(g_p),
                    jax.tree_util.tree_leaves(g_o)):
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=0)


def _pp2_loss(mdef, cell, pb):
    from repro.runtime import memledger as ml

    fn, args = ml.build_step(cell, data_size=4, model_size=2,
                             tokens=jnp.asarray(pb.tokens),
                             labels=jnp.asarray(pb.labels),
                             doc_start=jnp.asarray(pb.doc_start),
                             with_grad=True)
    loss, _ = jax.jit(fn)(*args)
    return float(loss)


def _pp2_cell(mdef, S, B, doc_lens):
    shape = ShapeConfig("t", S, B, "train")
    cell = resolve_cell(mdef, shape, data_size=4, model_size=2,
                        overrides=dict(pp=2, dp=2, n_chunks=4, grad_accum=1,
                                       partition="length"),
                        doc_lens=doc_lens)
    return dataclasses.replace(cell, dtype=jnp.float32)


def test_packed_equals_pad_to_max_oracle_pp2(eight_devices):
    """Tentpole law at pp=2: same equality through the lock-step tick loop,
    the drain masks, and the explicit-offload prefetch seam."""
    cfg = get_config("qwen2-7b").reduced()
    mdef = build_model(cfg)
    docs, lens = _corpus(cfg)
    packed = dpipe.pack_documents(docs, 256, rows=4)
    oracle = dpipe.pad_to_max(docs, 256, at_packed_offsets=packed, rows=12)
    l_p = _pp2_loss(mdef, _pp2_cell(mdef, 256, 4, lens), packed)
    l_o = _pp2_loss(mdef, _pp2_cell(mdef, 256, 12, lens), oracle)
    np.testing.assert_allclose(l_p, l_o, atol=1e-5, rtol=0)


def test_varlen_cell_profile_drives_schedule():
    """A packed cell's chunk boundaries and alphas come from the measured
    profile: heavily skewed packing shifts the chunk costs away from the
    uniform triangle, and resolve_cell records the histogram on the cell."""
    cfg = get_config("qwen2-7b").reduced()
    mdef = build_model(cfg)
    docs, lens = _corpus(cfg)
    shape = ShapeConfig("t", 256, 4, "train")
    cell = resolve_cell(mdef, shape, data_size=1, model_size=1,
                        overrides=dict(n_chunks=2, grad_accum=1,
                                       partition="flops"), doc_lens=lens)
    assert cell.varlen and cell.doc_lens == tuple(lens)
    assert sum(cell.sched.lengths) == 256
    uni = resolve_cell(mdef, shape, data_size=1, model_size=1,
                       overrides=dict(n_chunks=2, grad_accum=1,
                                      partition="flops"))
    assert not uni.varlen and uni.doc_lens == ()


def test_varlen_budget_cell_bracket(eight_devices):
    """The simulator's predicted peak brackets the measured ledger peak on
    the varlen budget cell (the honesty gate's new cell, max_ratio 1.1)."""
    from repro.runtime import memledger as ml

    cfg = get_config("sppo-gpt-7b").reduced()
    mdef = build_model(cfg)
    doc_lens = [int(x) for x in dpipe.sample_doc_lengths(
        n_docs=16, seed=0, dist="zipf", mean_len=48, max_len=192)]
    shape = ShapeConfig("varlen", 256, 4, "train")
    cell = resolve_cell(mdef, shape, data_size=4, model_size=2,
                        overrides=dict(pp=2, dp=2, n_chunks=4, grad_accum=1,
                                       partition="length", offload=True),
                        doc_lens=doc_lens)
    led = ml.measure(cell, data_size=4, model_size=2, baseline=False)
    predicted = ml.predicted_spmd_peak(cell)
    assert led.peak_bytes <= 1.1 * predicted, (
        f"measured {led.peak_bytes} B vs predicted {predicted:.0f} B")
    assert led.runtime_coverage_ok()


def test_solver_varlen_candidate_prices_packed_profile():
    """simulate_candidate(doc_lens=...) runs the packed profile (different
    boundaries/alphas than the uniform triangle) and the uniform path is
    untouched by the refactor (golden traces pin it byte-exactly)."""
    from repro.core import solver

    cfg = get_config("sppo-gpt-7b").reduced()
    doc_lens = [int(x) for x in dpipe.sample_doc_lengths(
        n_docs=16, seed=0, dist="zipf", mean_len=48, max_len=192)]
    t_u, a_u, res_u = solver.simulate_candidate(
        cfg, 256, 4, 10_000_000, 2, 4, 2)
    t_v, a_v, res_v = solver.simulate_candidate(
        cfg, 256, 4, 10_000_000, 2, 4, 2, doc_lens=doc_lens)
    assert t_u > 0 and t_v > 0
    assert len(a_v) == 4 and all(0.0 <= a <= 1.0 for a in a_v)
    # the skewed histogram moves the attention fraction and the chunk
    # boundaries off the uniform triangle, so the playout timeline differs
    assert ([e.end for e in res_v.trace] != [e.end for e in res_u.trace]
            or tuple(a_v) != tuple(a_u))
