"""Executed offloading honesty tests (DESIGN.md §10).

The offload plan must be *executable end to end*: with ``plan.offload`` the
pp>1 tick loop actually routes the act_off row splits through host memory
(memory-kind device_puts, or the staged-copy emulation on backends without
host memory kinds), the tag is numerically an identity (offload on/off
losses and grads agree to fp32 tolerance), the measured per-tick ledger
follows the §5.2 recurrence M_t = M_{t-1} + A_t − α_{t-1}A_{t-1}, and the
simulator's predicted peak brackets the measured ledger peak."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig, get_config
from repro.core import offload as ofl
from repro.models.model_zoo import build_model
from repro.parallel.ctx import SINGLE
from repro.parallel.runner import resolve_cell, run_pipeline
from repro.runtime import memledger as ml

ALPHAS = (1.0, 0.7, 0.5, 0.0)   # full / fractional / fractional / reserved


def _mk_cell(mdef, *, pp, data_size=4, model_size=2, offload=True,
             offload_mode="explicit", alphas=ALPHAS, seq=256, batch=4):
    shape = ShapeConfig("t", seq, batch, "train")
    cell = resolve_cell(
        mdef, shape, data_size=data_size, model_size=model_size,
        overrides=dict(pp=pp, dp=data_size // pp, n_chunks=len(ALPHAS),
                       grad_accum=1, partition="length", offload=offload,
                       offload_mode=offload_mode))
    cell = dataclasses.replace(cell, dtype=jnp.float32)
    if offload and alphas is not None:
        cell = dataclasses.replace(cell, alphas=tuple(alphas))
    return cell


def _loss_and_grads(cell, tokens, labels, *, data_size=4, model_size=2):
    """shard_map'd value_and_grad of the tick-loop pipeline — the shared
    scaffold from runtime/memledger.build_step, so the tests assert on the
    same program the memory-gate measures."""
    fn, args = ml.build_step(cell, data_size=data_size,
                             model_size=model_size, tokens=tokens,
                             labels=labels)
    loss, grads = jax.jit(fn)(*args)
    return float(loss), grads


def _tokens(cfg, B=4, S=256):
    key = jax.random.PRNGKey(7)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return tokens, jnp.roll(tokens, -1, axis=1)


# ---------------------------------------------------------------------------
# (a) numerics: offload on == offload off
# ---------------------------------------------------------------------------


def test_pp2_offload_on_off_grads_match(eight_devices):
    """The executed tag is slice + concat + host copies — an identity.
    Loss and every stage gradient must agree to <= 1e-5 fp32 between
    offload on (forced fractional alphas) and offload off."""
    cfg = get_config("qwen2-7b").reduced()
    mdef = build_model(cfg)
    tokens, labels = _tokens(cfg)
    on = _mk_cell(mdef, pp=2, offload=True)
    off = _mk_cell(mdef, pp=2, offload=False)
    l_on, g_on = _loss_and_grads(on, tokens, labels)
    l_off, g_off = _loss_and_grads(off, tokens, labels)
    np.testing.assert_allclose(l_on, l_off, rtol=0, atol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(g_on),
                    jax.tree_util.tree_leaves(g_off)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0, atol=1e-5)


def test_pp1_offload_on_off_loss_and_grads_match():
    """Same identity law on the pp == 1 FLOPs-balanced chunk loop."""
    cfg = get_config("qwen2-7b").reduced()
    mdef = build_model(cfg)
    tokens, labels = _tokens(cfg, B=2)
    key = jax.random.PRNGKey(0)
    sp = mdef.init_stage_params(key, 0, 1, jnp.float32)
    g = mdef.init_globals(key, jnp.float32)

    def grads_for(offload):
        cell = resolve_cell(
            mdef, ShapeConfig("t", 256, 2, "train"), data_size=1,
            model_size=1,
            overrides=dict(n_chunks=4, grad_accum=1, offload=offload,
                           partition="length"))
        cell = dataclasses.replace(cell, dtype=jnp.float32)
        if offload:
            cell = dataclasses.replace(cell, alphas=ALPHAS)

        def loss(sp_, g_):
            out = run_pipeline(cell, SINGLE, sp_, g_, tokens, labels, None,
                               with_loss=True)
            return out["loss"] / jnp.maximum(out["denom"], 1.0)

        return jax.jit(jax.value_and_grad(loss))(sp, g)

    (l_on, g_on), (l_off, g_off) = grads_for(True), grads_for(False)
    np.testing.assert_allclose(float(l_on), float(l_off), rtol=0, atol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(g_on),
                    jax.tree_util.tree_leaves(g_off)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0, atol=1e-5)


# ---------------------------------------------------------------------------
# the act_off rows really leave device memory space
# ---------------------------------------------------------------------------


def test_exec_path_emits_host_memory_transfers(eight_devices):
    """The differentiated pp>1 program contains memory-kind device_puts
    into a host space for every offloading tick, and none with offload
    disabled.  (On backends without memory kinds the staged-copy emulation
    has no such markers — skip there.)"""
    if ofl.host_memory_kind() is None:
        pytest.skip("backend has no host memory kind (emulation path)")
    cfg = get_config("qwen2-7b").reduced()
    mdef = build_model(cfg)
    tokens, labels = _tokens(cfg)

    def markers(offload):
        cell = _mk_cell(mdef, pp=2, offload=offload)
        fn, args = ml.build_step(cell, data_size=4, model_size=2,
                                 tokens=tokens, labels=labels)
        txt = str(jax.make_jaxpr(fn)(*args))
        kind = ofl.host_memory_kind()
        return txt.count(kind) + txt.count("<host>")

    assert markers(True) >= 10
    assert markers(False) == 0


# ---------------------------------------------------------------------------
# (b) measured ledger follows the §5.2 recurrence
# ---------------------------------------------------------------------------


def test_measured_ledger_follows_recurrence(eight_devices):
    cfg = get_config("qwen2-7b").reduced()
    mdef = build_model(cfg)
    cell = _mk_cell(mdef, pp=2)
    led = ml.measure(cell, data_size=4, model_size=2, baseline=False)
    assert led.ticks, "ledger recorded no ticks"
    # every tick materialized the same tagged volume (equal-length chunks)
    mats = {r.mat_bytes for r in led.ticks}
    assert len(mats) == 1 and led.ticks[0].mat_bytes > 0
    # off split matches the deployed alpha up to the row-split rounding
    for r in led.ticks:
        frac = r.off_bytes / r.mat_bytes
        assert abs(frac - r.alpha) < 0.1, (r.tick, frac, r.alpha)
    # independent §5.2 replay over the measured bytes
    m, prev_off = 0, 0
    for r in led.ticks:
        m += r.mat_bytes
        assert r.resident == m, f"tick {r.tick}: {r.resident} != {m}"
        m -= prev_off
        prev_off = r.off_bytes
    assert led.peak_bytes == max(r.resident for r in led.ticks)
    # runtime probes saw every tick's forward and backward execute
    assert led.runtime_coverage_ok()


# ---------------------------------------------------------------------------
# (c) the simulator's prediction brackets the measurement
# ---------------------------------------------------------------------------


def test_sim_predicted_peak_brackets_measured(eight_devices):
    """Analytic prediction (costmodel tagged bytes -> simulate.spmd_tick_peak)
    vs measured ledger peak: the CI memory-gate contract, asserted at test
    scale.  The two must agree within the gate's 10% tolerance on the upper
    side and may not overclaim by more than 20% on the lower side."""
    cfg = get_config("qwen2-7b").reduced()
    mdef = build_model(cfg)
    cell = _mk_cell(mdef, pp=2)
    led = ml.measure(cell, data_size=4, model_size=2, baseline=False)
    predicted = ml.predicted_spmd_peak(cell)
    assert led.peak_bytes <= 1.1 * predicted, (led.peak_bytes, predicted)
    assert led.peak_bytes >= 0.8 * predicted, (led.peak_bytes, predicted)
    # the shared predictor is dtype-aware: the same cell in bf16 predicts
    # half the fp32 bytes (the estimate is priced in bf16)
    bf16 = dataclasses.replace(cell, dtype=jnp.bfloat16)
    assert ml.predicted_spmd_peak(bf16) == pytest.approx(predicted / 2)


@pytest.mark.optstate
def test_sim_predicted_combined_brackets_measured_with_moments(eight_devices):
    """The same honesty contract extended to the moments channel
    (DESIGN.md §11): measured *combined* activations+moments device peak
    brackets the analytic prediction, moment offload strictly reduces the
    measured combined peak vs the same cell with device-resident moments,
    and the ledger's coverage check demands the update-phase probe."""
    cfg = get_config("qwen2-7b").reduced()
    mdef = build_model(cfg)
    cell = _mk_cell(mdef, pp=2)
    cell = dataclasses.replace(
        cell, plan=dataclasses.replace(cell.plan, offload_moments=True))
    led = ml.measure(cell, data_size=4, model_size=2, baseline=False,
                     opt=True)
    assert led.moments is not None and led.moments.offloaded
    assert led.runtime_coverage_ok()      # fwd + bwd + update evidence
    predicted = ml.predicted_combined_peak(cell, data_size=4)
    got = led.combined_peak_bytes
    assert got <= 1.1 * predicted, (got, predicted)
    assert got >= 0.8 * predicted, (got, predicted)
    # executed moment offload must pay off against the resident baseline
    cell_res = dataclasses.replace(
        cell, plan=dataclasses.replace(cell.plan, offload_moments=False))
    led_res = ml.measure(cell_res, data_size=4, model_size=2,
                         baseline=False, opt=True)
    assert got < led_res.combined_peak_bytes, (
        got, led_res.combined_peak_bytes)
    assert led_res.combined_peak_bytes <= 1.1 * ml.predicted_combined_peak(
        cell_res, data_size=4)


# ---------------------------------------------------------------------------
# decode consumes the plan; offloading a decode step is rejected
# ---------------------------------------------------------------------------


def test_decode_plans_never_offload():
    cfg = get_config("qwen2-7b").reduced()
    mdef = build_model(cfg)
    shape = ShapeConfig("d", 256, 8, "decode")
    cell = resolve_cell(mdef, shape, data_size=4, model_size=2)
    assert cell.plan.offload is False and cell.plan.remat == "none"
    with pytest.raises(AssertionError, match="decode plans must not offload"):
        resolve_cell(mdef, shape, data_size=4, model_size=2,
                     overrides=dict(offload=True))


def test_decode_plans_reject_compressed_residency():
    """Compressed residency rides the offload channels (DESIGN.md §14);
    a decode plan has neither, so requesting a codec must be rejected just
    like requesting offload itself."""
    cfg = get_config("qwen2-7b").reduced()
    mdef = build_model(cfg)
    shape = ShapeConfig("d", 256, 8, "decode")
    with pytest.raises(AssertionError, match="compressed residency"):
        resolve_cell(mdef, shape, data_size=4, model_size=2,
                     overrides=dict(offload_dtype="fp8"))
    # an otherwise-valid compressed-moments plan is still a decode error
    with pytest.raises(AssertionError, match="compressed residency"):
        resolve_cell(mdef, shape, data_size=4, model_size=2,
                     overrides=dict(moments_dtype="int8",
                                    offload_moments=True,
                                    moments_mode="explicit"))
    # without its prerequisites the moments codec fails plan validation
    with pytest.raises(AssertionError, match="moments_dtype"):
        resolve_cell(mdef, shape, data_size=4, model_size=2,
                     overrides=dict(moments_dtype="int8"))
