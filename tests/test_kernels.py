"""Pallas kernel vs pure-jnp oracle: shape/dtype sweeps + merge properties,
plus the end-to-end training contract: a full train step (loss + grads)
under ``REPRO_USE_PALLAS=1`` interpret mode must match the jnp backend
per-parameter — single-device and through the pp>1 tick loop."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.kernels.ref import (attention_partial_ref, merge_partials,
                               mha_reference, normalize)
from repro.kernels.flash_attention import flash_attention_partial


def _mk(B, Tq, S, H, Hkv, hd, hv, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, Tq, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, hv), dtype)
    return q, k, v


SWEEP = [
    # B, Tq,  S,   H, Hkv, hd, hv, causal, q_off, dtype
    (1, 16, 16, 4, 4, 32, 32, True, 0, jnp.float32),
    (2, 32, 64, 4, 2, 16, 16, True, 32, jnp.float32),
    (1, 8, 128, 8, 1, 64, 32, True, 120, jnp.float32),   # MLA-like hv != hd
    (2, 17, 33, 6, 2, 16, 16, True, 16, jnp.float32),    # ragged sizes
    (1, 16, 48, 4, 4, 32, 32, False, 0, jnp.float32),    # bidirectional
    (1, 1, 64, 4, 2, 32, 32, True, 63, jnp.float32),     # decode: Tq=1
    (1, 32, 32, 4, 4, 32, 32, True, 0, jnp.bfloat16),
]


@pytest.mark.parametrize("B,Tq,S,H,Hkv,hd,hv,causal,qoff,dtype", SWEEP)
def test_ref_blockwise_matches_naive(B, Tq, S, H, Hkv, hd, hv, causal, qoff, dtype):
    q, k, v = _mk(B, Tq, S, H, Hkv, hd, hv, dtype)
    q_pos = jnp.arange(Tq, dtype=jnp.int32) + qoff
    kv_pos = jnp.arange(S, dtype=jnp.int32)
    o, m, l = attention_partial_ref(q, k, v, q_pos, kv_pos, causal=causal,
                                    block_k=16)
    got = normalize(o, l)
    want = mha_reference(q, k, v, q_pos, kv_pos, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("B,Tq,S,H,Hkv,hd,hv,causal,qoff,dtype", SWEEP)
def test_pallas_matches_ref(B, Tq, S, H, Hkv, hd, hv, causal, qoff, dtype):
    q, k, v = _mk(B, Tq, S, H, Hkv, hd, hv, dtype)
    q_pos = jnp.arange(Tq, dtype=jnp.int32) + qoff
    kv_pos = jnp.arange(S, dtype=jnp.int32)
    o1, m1, l1 = attention_partial_ref(q, k, v, q_pos, kv_pos, causal=causal,
                                       block_k=16)
    o2, m2, l2 = flash_attention_partial(q, k, v, q_pos, kv_pos,
                                         causal=causal, block_q=16,
                                         block_k=16, interpret=True)
    got = np.asarray(normalize(o2, l2))
    want = np.asarray(normalize(o1, l1))
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_partial_merge_equals_full():
    """Sharded-KV partials merged == full-KV attention (the psum-merge law)."""
    B, Tq, S, H, Hkv, hd = 2, 16, 64, 4, 2, 32
    q, k, v = _mk(B, Tq, S, H, Hkv, hd, hd, jnp.float32, seed=3)
    q_pos = jnp.arange(Tq, dtype=jnp.int32) + (S - Tq)
    kv_pos = jnp.arange(S, dtype=jnp.int32)
    full = mha_reference(q, k, v, q_pos, kv_pos)
    parts = []
    for r in range(4):
        sl = slice(r * 16, (r + 1) * 16)
        parts.append(attention_partial_ref(q, k[:, sl], v[:, sl], q_pos,
                                           kv_pos[sl], block_k=8))
    o, m, l = merge_partials(parts)
    np.testing.assert_allclose(np.asarray(normalize(o, l)), np.asarray(full),
                               rtol=1e-5, atol=1e-5)


def test_empty_kv_rows_are_zero():
    """Fully-masked rows (no visible kv) come back 0, not NaN."""
    B, Tq, S = 1, 4, 8
    q, k, v = _mk(B, Tq, S, 2, 2, 16, 16, jnp.float32)
    q_pos = jnp.arange(Tq, dtype=jnp.int32)          # positions 0..3
    kv_pos = jnp.arange(S, dtype=jnp.int32) + 100    # all in the future
    o, m, l = attention_partial_ref(q, k, v, q_pos, kv_pos, block_k=8)
    out = normalize(o, l)
    assert not np.any(np.isnan(np.asarray(out)))
    np.testing.assert_allclose(np.asarray(out), 0.0)


# ---------------------------------------------------------------------------
# End-to-end training contract: REPRO_USE_PALLAS=1 == jnp backend, grads too
# ---------------------------------------------------------------------------


def _make_model():
    from repro.configs.base import get_config
    from repro.models.model_zoo import build_model

    cfg = get_config("qwen2-7b").reduced()
    return cfg, build_model(cfg)


def _single_loss_grads(mdef, tokens, labels):
    """launch/train.py's single-device path: run_pipeline + value_and_grad."""
    from repro.configs.base import ShapeConfig
    from repro.parallel.ctx import SINGLE
    from repro.parallel.runner import resolve_cell, run_pipeline

    B, S = tokens.shape
    cell = resolve_cell(mdef, ShapeConfig("t", S, B, "train"), data_size=1,
                        model_size=1, overrides=dict(n_chunks=2, grad_accum=1,
                                                     partition="length"))
    cell = dataclasses.replace(cell, dtype=jnp.float32)
    key = jax.random.PRNGKey(0)
    sp1 = mdef.init_stage_params(key, 0, 1, jnp.float32)
    g1 = mdef.init_globals(key, jnp.float32)

    def f(sp_, g_):
        out = run_pipeline(cell, SINGLE, sp_, g_, tokens, labels, None,
                           with_loss=True)
        return out["loss"] / jnp.maximum(out["denom"], 1.0)

    loss, grads = jax.jit(jax.value_and_grad(f, argnums=(0, 1)))(sp1, g1)
    return float(loss), grads


def _dist_loss_grads(mdef, tokens, labels, *, pp=2, mesh_shape=(2, 2),
                     extra_overrides=None):
    """The pp>1 tick loop, grads computed exactly as make_train_step does:
    value_and_grad inside shard_map, stage/global psums."""
    from repro.configs.base import ShapeConfig
    from repro.launch.mesh import compat_make_mesh
    from repro.parallel.runner import (_in_specs_for_params, batch_struct,
                                       resolve_cell, run_pipeline, shard_map)

    data_size, model_size = mesh_shape
    mesh = compat_make_mesh(mesh_shape, ("data", "model"))
    dp = data_size // pp
    B, S = tokens.shape
    overrides = dict(n_chunks=2, grad_accum=1, pp=pp, dp=dp,
                     partition="length")
    overrides.update(extra_overrides or {})
    cell = resolve_cell(mdef, ShapeConfig("t", S, B, "train"),
                        data_size=data_size, model_size=model_size,
                        overrides=overrides)
    cell = dataclasses.replace(cell, dtype=jnp.float32)
    key = jax.random.PRNGKey(0)
    stages = [mdef.init_stage_params(key, s, pp, jnp.float32)
              for s in range(pp)]
    g_stage = jax.tree_util.tree_map(
        lambda *ls: jnp.stack([ls[i % pp] for i in range(data_size)]),
        *stages)
    gl = mdef.init_globals(key, jnp.float32)
    b_loc = B // dp

    def lay(x):
        return jnp.stack([x[(i // pp) * b_loc:(i // pp + 1) * b_loc]
                          for i in range(data_size)])[None]

    batch = {"tokens": lay(tokens), "labels": lay(labels)}
    pspecs = _in_specs_for_params(cell)
    _, bspecs = batch_struct(cell)

    def body(stage_p, g, b):
        ctx = cell.ctx()
        stage_p = jax.tree_util.tree_map(lambda a: a.reshape(a.shape[1:]),
                                         stage_p)
        tok = b["tokens"].reshape(b["tokens"].shape[2:])
        lab = b["labels"].reshape(b["labels"].shape[2:])

        def loss_fn(stage_p, g):
            out = run_pipeline(cell, ctx, stage_p, g, tok, lab, None,
                               with_loss=True)
            num = ctx.psum_loss_all(out["loss"])
            den = ctx.psum_loss_all(out["denom"])
            return num / jnp.maximum(den, 1.0)

        loss, grads = jax.value_and_grad(loss_fn, argnums=(0, 1))(stage_p, g)
        g_st = jax.tree_util.tree_map(lambda a: a[None],
                                      ctx.psum_grads(grads[0]))
        return loss, g_st, ctx.psum_globals(grads[1])

    fn = shard_map(body, mesh,
                   in_specs=(pspecs["stages"], pspecs["globals"], bspecs),
                   out_specs=(P(), pspecs["stages"], pspecs["globals"]))
    loss, gs, gg = jax.jit(fn)(g_stage, gl, batch)
    return float(loss), (gs, gg)


def _max_abs_diff(ta, tb):
    leaves_a = jax.tree_util.tree_leaves(ta)
    leaves_b = jax.tree_util.tree_leaves(tb)
    assert len(leaves_a) == len(leaves_b) and leaves_a
    return max(float(jnp.max(jnp.abs(a - b)))
               for a, b in zip(leaves_a, leaves_b))


def test_train_step_grads_pallas_equals_jnp_single(kernel_backend):
    """Acceptance: fp32 single-device train step, per-parameter gradients of
    the Pallas (interpret) backend match the jnp backend to <= 1e-4."""
    cfg, mdef = _make_model()
    key = jax.random.PRNGKey(7)
    tokens = jax.random.randint(key, (2, 64), 0, cfg.vocab_size)
    labels = jnp.roll(tokens, -1, axis=1)
    with kernel_backend("jnp"):
        loss_j, grads_j = _single_loss_grads(mdef, tokens, labels)
    with kernel_backend("pallas"):
        loss_p, grads_p = _single_loss_grads(mdef, tokens, labels)
    assert abs(loss_p - loss_j) <= 1e-4
    assert _max_abs_diff(grads_p, grads_j) <= 1e-4


def test_train_py_runs_on_pallas_backend(kernel_backend):
    """launch/train.py end-to-end (driver, optimizer, metering) on the
    Pallas backend: two steps must run and agree with the jnp backend on
    the step-0 loss (bf16 model dtype, so a loose tolerance)."""
    from repro.launch.train import main

    args = ["--arch", "qwen2-7b", "--reduced", "--steps", "2",
            "--seq", "64", "--batch", "2", "--mesh", "1x1"]
    with kernel_backend("jnp"):
        hist_j = main(args)
    with kernel_backend("pallas"):
        hist_p = main(args)
    assert np.isfinite(hist_p[-1]["loss"])
    np.testing.assert_allclose(hist_p[0]["loss"], hist_j[0]["loss"],
                               rtol=2e-2, atol=2e-2)


def test_train_step_grads_pallas_equals_jnp_pp2(kernel_backend, eight_devices):
    """Acceptance: the pp>1 tick loop (dp x pp x sp shard_map, psum-merged
    partial softmax) trains identically on the Pallas backend."""
    cfg, mdef = _make_model()
    key = jax.random.PRNGKey(7)
    tokens = jax.random.randint(key, (2, 64), 0, cfg.vocab_size)
    labels = jnp.roll(tokens, -1, axis=1)
    with kernel_backend("jnp"):
        loss_j, grads_j = _dist_loss_grads(mdef, tokens, labels)
    with kernel_backend("pallas"):
        loss_p, grads_p = _dist_loss_grads(mdef, tokens, labels)
    assert abs(loss_p - loss_j) <= 1e-4
    assert _max_abs_diff(grads_p, grads_j) <= 1e-4


def test_train_step_grads_pallas_equals_jnp_gather_kv(kernel_backend, eight_devices):
    """The merge-free gather_kv attention mode (KV all-gather, local
    softmax, zero merge collectives) must also train identically — its
    backward reduce-scatters dk/dv through the all_gather transpose."""
    cfg, mdef = _make_model()
    key = jax.random.PRNGKey(7)
    tokens = jax.random.randint(key, (2, 64), 0, cfg.vocab_size)
    labels = jnp.roll(tokens, -1, axis=1)
    ov = dict(attn_mode="gather_kv")
    with kernel_backend("jnp"):
        loss_j, grads_j = _dist_loss_grads(mdef, tokens, labels,
                                           extra_overrides=ov)
    with kernel_backend("pallas"):
        loss_p, grads_p = _dist_loss_grads(mdef, tokens, labels,
                                           extra_overrides=ov)
    assert abs(loss_p - loss_j) <= 1e-4
    assert _max_abs_diff(grads_p, grads_j) <= 1e-4
