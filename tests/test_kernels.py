"""Pallas kernel vs pure-jnp oracle: shape/dtype sweeps + merge properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ref import (attention_partial_ref, merge_partials,
                               mha_reference, normalize)
from repro.kernels.flash_attention import flash_attention_partial


def _mk(B, Tq, S, H, Hkv, hd, hv, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, Tq, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, hv), dtype)
    return q, k, v


SWEEP = [
    # B, Tq,  S,   H, Hkv, hd, hv, causal, q_off, dtype
    (1, 16, 16, 4, 4, 32, 32, True, 0, jnp.float32),
    (2, 32, 64, 4, 2, 16, 16, True, 32, jnp.float32),
    (1, 8, 128, 8, 1, 64, 32, True, 120, jnp.float32),   # MLA-like hv != hd
    (2, 17, 33, 6, 2, 16, 16, True, 16, jnp.float32),    # ragged sizes
    (1, 16, 48, 4, 4, 32, 32, False, 0, jnp.float32),    # bidirectional
    (1, 1, 64, 4, 2, 32, 32, True, 63, jnp.float32),     # decode: Tq=1
    (1, 32, 32, 4, 4, 32, 32, True, 0, jnp.bfloat16),
]


@pytest.mark.parametrize("B,Tq,S,H,Hkv,hd,hv,causal,qoff,dtype", SWEEP)
def test_ref_blockwise_matches_naive(B, Tq, S, H, Hkv, hd, hv, causal, qoff, dtype):
    q, k, v = _mk(B, Tq, S, H, Hkv, hd, hv, dtype)
    q_pos = jnp.arange(Tq, dtype=jnp.int32) + qoff
    kv_pos = jnp.arange(S, dtype=jnp.int32)
    o, m, l = attention_partial_ref(q, k, v, q_pos, kv_pos, causal=causal,
                                    block_k=16)
    got = normalize(o, l)
    want = mha_reference(q, k, v, q_pos, kv_pos, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("B,Tq,S,H,Hkv,hd,hv,causal,qoff,dtype", SWEEP)
def test_pallas_matches_ref(B, Tq, S, H, Hkv, hd, hv, causal, qoff, dtype):
    q, k, v = _mk(B, Tq, S, H, Hkv, hd, hv, dtype)
    q_pos = jnp.arange(Tq, dtype=jnp.int32) + qoff
    kv_pos = jnp.arange(S, dtype=jnp.int32)
    o1, m1, l1 = attention_partial_ref(q, k, v, q_pos, kv_pos, causal=causal,
                                       block_k=16)
    o2, m2, l2 = flash_attention_partial(q, k, v, q_pos, kv_pos,
                                         causal=causal, block_q=16,
                                         block_k=16, interpret=True)
    got = np.asarray(normalize(o2, l2))
    want = np.asarray(normalize(o1, l1))
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_partial_merge_equals_full():
    """Sharded-KV partials merged == full-KV attention (the psum-merge law)."""
    B, Tq, S, H, Hkv, hd = 2, 16, 64, 4, 2, 32
    q, k, v = _mk(B, Tq, S, H, Hkv, hd, hd, jnp.float32, seed=3)
    q_pos = jnp.arange(Tq, dtype=jnp.int32) + (S - Tq)
    kv_pos = jnp.arange(S, dtype=jnp.int32)
    full = mha_reference(q, k, v, q_pos, kv_pos)
    parts = []
    for r in range(4):
        sl = slice(r * 16, (r + 1) * 16)
        parts.append(attention_partial_ref(q, k[:, sl], v[:, sl], q_pos,
                                           kv_pos[sl], block_k=8))
    o, m, l = merge_partials(parts)
    np.testing.assert_allclose(np.asarray(normalize(o, l)), np.asarray(full),
                               rtol=1e-5, atol=1e-5)


def test_empty_kv_rows_are_zero():
    """Fully-masked rows (no visible kv) come back 0, not NaN."""
    B, Tq, S = 1, 4, 8
    q, k, v = _mk(B, Tq, S, 2, 2, 16, 16, jnp.float32)
    q_pos = jnp.arange(Tq, dtype=jnp.int32)          # positions 0..3
    kv_pos = jnp.arange(S, dtype=jnp.int32) + 100    # all in the future
    o, m, l = attention_partial_ref(q, k, v, q_pos, kv_pos, block_k=8)
    out = normalize(o, l)
    assert not np.any(np.isnan(np.asarray(out)))
    np.testing.assert_allclose(np.asarray(out), 0.0)
