"""MoE block vs a dense loop-over-experts oracle (no-drop regime)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.moe import moe_block
from repro.parallel.ctx import SINGLE


def _oracle(x, p, cfg):
    moe = cfg.moe
    B, T, d = x.shape
    xt = x.reshape(-1, d)
    logits = (xt @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, moe.top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    y = jnp.zeros((xt.shape[0], d), jnp.float32)
    for e in range(moe.num_experts):
        h = jax.nn.silu(xt @ p["w1"][e]) * (xt @ p["w3"][e])
        out = (h @ p["w2"][e]).astype(jnp.float32)
        w = jnp.sum(jnp.where(top_e == e, top_p, 0.0), axis=-1)
        y = y + out * w[:, None]
    if moe.n_shared_experts:
        hs = jax.nn.silu(xt @ p["ws1"]) * (xt @ p["ws3"])
        y = y + (hs @ p["ws2"]).astype(jnp.float32)
    return y.reshape(B, T, d).astype(x.dtype)


@pytest.mark.parametrize("arch", ["granite-moe-1b-a400m", "deepseek-v3-671b"])
def test_moe_matches_dense_oracle(arch):
    cfg = get_config(arch).reduced()
    # crank capacity so nothing drops -> exact equality regime
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    from repro.models.model_zoo import _moe
    key = jax.random.PRNGKey(0)
    p = _moe(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model),
                          jnp.float32) * 0.3
    got, aux = moe_block(x, p, cfg, SINGLE)
    want = _oracle(x, p, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    assert np.isfinite(float(aux)) and float(aux) > 0


def test_moe_capacity_drops_bounded():
    """With cf=1.0 some tokens drop, but outputs stay finite and the drop
    only *removes* expert contributions (never adds)."""
    cfg = get_config("granite-moe-1b-a400m").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.5))
    from repro.models.model_zoo import _moe
    p = _moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model),
                          jnp.float32) * 0.3
    got, _ = moe_block(x, p, cfg, SINGLE)
    assert bool(jnp.all(jnp.isfinite(got)))
