"""Unit + hypothesis property tests for the paper's core math:
partitioning (§3.2), sequence-aware offloading (§5.2), pipeline schedule &
MSP (§3.3/§6), heuristic solver (§6.1)."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import get_config
from repro.core import offload as ofl
from repro.core import partition as part
from repro.core import schedule as sched
from repro.core import solver


# ---------------------------------------------------------------------------
# Partitioning
# ---------------------------------------------------------------------------


@given(st.integers(3, 9), st.integers(1, 16),
       st.floats(1e-6, 1e-2))
@settings(max_examples=60, deadline=None)
def test_partition_flops_properties(log_seq, n, r):
    seq = 1 << (log_seq + 5)  # 256..16K
    n = min(n, seq // 16)
    s = part.partition_flops(seq, n, r, multiple=16)
    assert sum(s.lengths) == seq
    assert all(l > 0 and l % 16 == 0 for l in s.lengths)
    assert s.offsets[0] == 0
    assert all(s.offsets[i + 1] == s.offsets[i] + s.lengths[i]
               for i in range(n - 1))


def test_flops_balance_beats_length_balance():
    """The FLOPs-balanced partition equalizes chunk compute (Fig. 4)."""
    cfg = get_config("sppo-gpt-7b")
    r = part.flops_per_token_ratio(cfg)
    seq, n = 131072, 16
    fl = part.partition(seq, n, cfg, "flops", multiple=16)
    ln = part.partition(seq, n, cfg, "length", multiple=16)
    imb_f = part.imbalance(part.chunk_costs(fl, r))
    imb_l = part.imbalance(part.chunk_costs(ln, r))
    assert imb_f < 1.05            # balanced within 5%
    assert imb_l > 1.5             # length-based is badly imbalanced
    # earlier chunks are longer (activation imbalance, Fig. 5)
    assert fl.lengths[0] > fl.lengths[-1]


def test_linear_profile_degenerates_to_length():
    cfg = get_config("rwkv6-3b")  # attention-free
    assert part.flops_per_token_ratio(cfg) == 0.0
    s = part.partition(4096, 8, cfg, "flops", multiple=16)
    assert s.policy == "length"
    assert len(set(s.lengths)) == 1


# ---------------------------------------------------------------------------
# Sequence-aware offloading (§5.2)
# ---------------------------------------------------------------------------


def _flops_balanced_case(n=8, seq=131072):
    cfg = get_config("sppo-gpt-7b")
    r = part.flops_per_token_ratio(cfg)
    s = part.partition(seq, n, cfg, "flops", multiple=16)
    costs = part.chunk_costs(s, r)
    t_unit = 1e-3 / max(costs)
    times = [c * t_unit for c in costs]
    acts = [l * 1e4 for l in s.lengths]  # bytes ∝ tokens
    return acts, times


def test_alpha_invariant_flops_balanced():
    """Paper invariant (§5.2): under FLOPs-balanced chunks the offloaded
    volume is constant — α_{i-1}A_{i-1} = α_iA_i = M_threshold — wherever
    α < 1, and α orders *inversely* to activation size (the paper writes
    s_0 ≤ s_1 ≤ … paired with α_0 ≥ α_1 ≥ …: the smallest chunk offloads
    the largest fraction).  In time order, causal FLOPs balance makes
    earlier chunks longer, so α grows along the sequence."""
    acts, times = _flops_balanced_case()
    bw = 0.3 * acts[0] / times[1]  # partial-offload regime
    plan = ofl.sequence_aware_alphas(acts, times, bw)
    prods = [a * al for a, al in zip(acts, plan.alphas)]
    interior = [p for p, al in zip(prods[:-1], plan.alphas[:-1]) if al < 1.0]
    assert max(interior) - min(interior) < 0.05 * max(interior)
    # inverse ordering vs activation volume (excluding the forced-0 tail)
    pairs = sorted(zip(acts[:-1], plan.alphas[:-1]))
    assert all(pairs[i][1] >= pairs[i + 1][1] - 1e-9
               for i in range(len(pairs) - 1))
    assert plan.alphas[-1] == 0.0  # last chunk never offloads


@given(st.integers(2, 24), st.floats(1e4, 1e9), st.floats(0.1, 10.0))
@settings(max_examples=60, deadline=None)
def test_alpha_bounds_and_peak(n, bw, scale):
    acts = [(n - i) * 1e5 * scale for i in range(n)]
    times = [1e-3] * n
    plan = ofl.sequence_aware_alphas(acts, times, bw)
    assert all(0.0 <= a <= 1.0 for a in plan.alphas)
    # peak memory is never worse than keeping everything resident
    assert plan.peak_units <= sum(acts) + 1e-6
    # ... and full offload (bw -> inf) approaches the two-chunk bound
    full = ofl.peak_memory(acts, [1.0] * n)
    assert full <= max(acts[i] + acts[i + 1] for i in range(n - 1)) + 1e-6


def test_reserve_last_false_window_is_first_backward():
    """With reserve_last=False the last chunk's round trip is exposed (its
    own backward consumes the reload), so α is sized against the *first
    backward event's* duration — comp_times[-1] · bwd_over_fwd — as the
    exposure budget, not the (already-spent) forward time."""
    acts, times = [10.0] * 3, [1.0] * 3
    plan = ofl.sequence_aware_alphas(acts, times, 2.0, reserve_last=False)
    # interior: BW·T_next/A = 2·1/10; last: BW·(T·2)/A = 2·2/10
    assert plan.alphas == pytest.approx((0.2, 0.2, 0.4))
    plan3 = ofl.sequence_aware_alphas(acts, times, 2.0, reserve_last=False,
                                      bwd_over_fwd=3.0)
    assert plan3.alphas[-1] == pytest.approx(0.6)
    assert plan3.alphas[:-1] == plan.alphas[:-1]
    # the default still reserves the last chunk
    assert ofl.sequence_aware_alphas(acts, times, 2.0).alphas[-1] == 0.0
    # and the ratio stays clipped to [0, 1] in the saturated regime
    sat = ofl.sequence_aware_alphas(acts, times, 1e9, reserve_last=False)
    assert sat.alphas == (1.0, 1.0, 1.0)


@given(st.integers(1, 512), st.floats(0.0, 1.0))
@settings(max_examples=60, deadline=None)
def test_split_rows_quantization(rows, alpha):
    """split_rows rounds to the nearest row with no forced minimum — the
    deployed ratio quantized_alpha is within half a row of the continuous
    α, and the old `max(1, ...)` bias on small α is gone."""
    k = ofl.split_rows(rows, alpha)
    assert 0 <= k <= rows
    assert abs(k - rows * alpha) <= 0.5 + 1e-9
    assert ofl.quantized_alpha(rows, alpha) == k / rows
    if alpha * rows < 0.5 - 1e-9:
        assert k == 0


def test_memory_recurrence_matches_paper():
    """M_i = M_{i-1} + A_i − α_{i-1}A_{i-1} — explicit small case."""
    acts = [4.0, 3.0, 2.0, 1.0]
    alphas = [1.0, 1.0, 0.5, 0.0]
    # manual recurrence: peaks at 4; 4-4+3=3; 3-3+2=2; 2-1+1=2 ...
    peak = ofl.peak_memory(acts, alphas)
    m, prev, expect_peak = 0.0, 0.0, 0.0
    for a, al in zip(acts, alphas):
        m += a
        expect_peak = max(expect_peak, m)
        m -= prev
        prev = al * a
    assert peak == expect_peak


# ---------------------------------------------------------------------------
# Pipeline schedule + MSP (§3.3, §6.2)
# ---------------------------------------------------------------------------


def test_bubble_formula():
    # paper's example: p=4, N=16 -> ratio 3/16
    assert sched.bubble_ratio(4, 16) == pytest.approx(3 / 16)
    f_n = 1.0
    assert sched.total_time(4, 16, f_n) == pytest.approx((3 + 16) / 16)


def test_msp_table_3():
    """Reproduce the paper's Table 3 (PP=4, N=8) exactly."""
    t = sched.msp_phase_table(4, 8)
    assert t[0]["left"] == {0, 1, 2}
    assert t[1]["left"] == {0, 1}
    assert t[2]["left"] == {0}
    assert t[3]["left"] == set()
    assert t[0]["steady"] == {3, 4, 5, 6, 7}
    assert t[1]["steady"] == {2, 3, 4, 5, 6}
    assert t[3]["steady"] == {0, 1, 2, 3, 4}
    assert t[1]["right"] == {7}
    assert t[2]["right"] == {6, 7}
    assert t[3]["right"] == {5, 6, 7}
    assert t[0]["left_sp_range"] == {0, 1, 2, 3}
    assert t[1]["left_sp_range"] == {1, 2, 3}
    assert t[2]["left_sp_range"] == {2, 3}
    assert t[3]["left_sp_range"] == set()
    assert t[1]["right_sp_range"] == {0, 1}
    assert t[2]["right_sp_range"] == {0, 1, 2}
    assert t[3]["right_sp_range"] == {0, 1, 2, 3}


@given(st.integers(2, 8), st.integers(2, 64))
@settings(max_examples=80, deadline=None)
def test_msp_phases_partition_chunks(pp, n):
    if n < pp:
        return
    for s in range(pp):
        left = sched.left_sp_ids(pp, n, s)
        steady = sched.steady_ids(pp, n, s)
        right = sched.right_sp_ids(pp, n, s)
        assert left | steady | right == set(range(n))
        assert not (left & steady) and not (steady & right) \
            and not (left & right)
        assert len(steady) == n - (pp - 1)


@given(st.integers(2, 8), st.integers(4, 64), st.integers(2, 4))
@settings(max_examples=60, deadline=None)
def test_msp_reduces_total_time(pp, n, split):
    if n < 2 * pp:
        return
    f_n = 1.0
    base = sched.total_time(pp, n, f_n)
    msp = sched.msp_total_time(pp, n, f_n, split)
    assert msp < base
    # work conserved: only the bubble shrinks
    assert msp >= f_n


def test_msp_ramp_schedule_events():
    ev = sched.msp_ramp_schedule(8, 4, split=2)
    # first/last 3 chunks split in 2, middle 2 whole: 3*2 + 2 + 3*2 = 14
    assert len(ev) == 14
    assert [e[0] for e in ev[:2]] == [0, 0]
    covered = {}
    for c, s, ns in ev:
        covered.setdefault(c, []).append((s, ns))
    assert set(covered) == set(range(8))
    for c, subs in covered.items():
        ns = subs[0][1]
        assert [x[0] for x in subs] == list(range(ns))


# ---------------------------------------------------------------------------
# Heuristic solver (§6.1)
# ---------------------------------------------------------------------------


def test_solver_feasible_and_bubble_sane():
    cfg = get_config("sppo-gpt-7b")
    res = solver.solve(cfg, seq_len=524288, batch=1, n_params=6_700_000_000)
    assert 16 % res.pp == 0
    assert res.n_chunks >= res.pp or res.pp == 1
    assert 0 <= res.bubble_ratio < 1
    assert len(res.alphas) == res.n_chunks
    # candidates must include the chosen point
    assert any(pp == res.pp and n == res.n_chunks
               for pp, n, _ in res.candidates)


def test_solver_prefers_more_chunks_for_longer_sequences():
    cfg = get_config("sppo-gpt-7b")
    short = solver.solve(cfg, 65536, 1, 6_700_000_000)
    long = solver.solve(cfg, 1048576, 1, 6_700_000_000)
    assert long.n_chunks >= short.n_chunks
