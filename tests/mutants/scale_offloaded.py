"""Dequantize scale offloaded to host alongside the payload it scales.

The per-row fp32 scales must stay device-side: they are a few KB, and
the backward needs them immediately at dequantize time — pushing them
through the host channel adds a blocking reload to the critical path for
zero memory win.  This mutant (switch in ``runner.prefetch_chunk``) runs
``hostmem.to_host`` on the scale rows before naming them; the auditor's
R2 placement rule sees an ``act_scale@`` name whose producer is a
host-kind ``device_put`` and flags it (R1-d2h-count fires alongside —
the extra host puts also break the one-copy pairing count).
"""
CASE = dict(
    name="scale-offloaded",
    mutation="scale-offloaded",
    overrides={"offload_dtype": "fp8"},
    prefetch=None,
    expected_id="R2-scale-placement",
)
