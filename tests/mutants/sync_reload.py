"""The synchronous-reload exposure (plan-level mutant, no code switch).

``prefetch="sync"`` leaves every H2D reload where autodiff places it: at
the consuming chunk's own backward, inside the remat scope — the copy
serializes with the compute it feeds instead of overlapping the previous
chunk (the stall SPPO's one-chunk-ahead seam exists to remove).  The
auditor flags every such in-scope H2D as R3-overlap-hazard; R1-h2d-count
fires alongside, because remat replays the reload equations (2x H2D per
offload site in the trace).
"""
CASE = dict(
    name="sync-reload",
    mutation=None,
    overrides={},
    prefetch="sync",
    expected_id="R3-overlap-hazard",
)
