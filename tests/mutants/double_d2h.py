"""Duplicate offload copy: the one-copy D2H contract broken.

The capture seam must issue exactly one host ``device_put`` per tagged
offload site per step.  This mutant (switch in ``runner.prefetch_chunk``'s
capture) re-runs ``hostmem.to_host`` on the already-offloaded rows,
doubling the D2H equation count — the auditor's R1 rule compares host-kind
puts against the capture-pair count and flags the mismatch.
"""
CASE = dict(
    name="double-d2h",
    mutation="double-d2h",
    overrides={},
    prefetch=None,
    expected_id="R1-d2h-count",
)
