"""Codec scale dropped from the residual naming (the PR 7 NaN trap class).

On a quantized offload plan every fp8 payload needs its fp32 per-row
scale reachable in the trace under ``act_scale@<site>`` — lose the scale
and the dequantize multiplies by garbage (historically: silent NaNs a
thousand steps in).  This mutant (switch in ``runner.prefetch_chunk``)
skips the ``checkpoint_name`` on the scale rows, so the payload pairing
has no named scale — the auditor's R5-codec-pairing rule flags the
orphaned ``act_off@`` site.
"""
CASE = dict(
    name="unnamed-scale",
    mutation="unnamed-scale",
    overrides={"offload_dtype": "fp8"},
    prefetch=None,
    expected_id="R5-codec-pairing",
)
