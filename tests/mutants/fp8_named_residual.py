"""Raw inexact wire dtype named as a residual inside the remat scope.

The quantized host channel transports fp8 payloads bitcast to an int8
byte container; naming the raw float8 array as an ``act_off@`` residual
inside a sequential scope means autodiff saves an inexact-dtype value
whose gradient path XLA may silently decompose (the PR 7 trap in its
other costume).  This mutant (switch in ``offload.host_round_trip``)
skips the bitcast; combined with ``prefetch="sync"`` the named fp8
payload lands inside the remat scope where R5-inexact-residual looks.
"""
CASE = dict(
    name="fp8-named-residual",
    mutation="fp8-named-residual",
    overrides={"offload_dtype": "fp8"},
    prefetch="sync",
    expected_id="R5-inexact-residual",
)
