"""Seeded mutant corpus for the trace-time contract auditor.

Each module re-introduces one historical regression class (or a known-bad
plan configuration) and names the finding id the auditor MUST emit for it.
The actual code mutations live behind ``repro.core.mutation`` switches at
the exact seams the original bugs occupied; plan-level mutants (sync
reload) need no code switch — the bad configuration IS the mutant.

tests/test_audit.py parametrizes over ``MUTANTS``: for every case it audits
the small pp=2 cell with the mutation seeded and asserts the expected
finding id is present (other findings may legitimately ride along — e.g.
the sync mutant also breaks the R1 H2D count, because remat replays the
reload equations).
"""
from mutants import (
    double_d2h,
    drain_tick_write,
    fp8_named_residual,
    scale_offloaded,
    sync_reload,
    unnamed_scale,
)

MUTANTS = [
    drain_tick_write.CASE,
    sync_reload.CASE,
    double_d2h.CASE,
    unnamed_scale.CASE,
    fp8_named_residual.CASE,
    scale_offloaded.CASE,
]
