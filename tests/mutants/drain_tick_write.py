"""The pp>1 drain-tick clobber (the prefill KV-cache corruption bug).

The lock-step pp schedule runs warmup/drain ticks whose outputs are
garbage for some stages; the fix guards every pipeline-state carry with
``jnp.where(valid, new, old)`` on the tick-validity predicate.  This
mutant (``repro.core.mutation`` switch in ``runner.prefill``'s tick loop)
drops that select, re-introducing the raw overwrite — the auditor's R4
walk finds the state outvar produced by a non-select equation.
"""
CASE = dict(
    name="drain-tick-write",
    mutation="drain-tick-write",
    overrides={},
    prefetch=None,
    expected_id="R4-unmasked-state",
)
