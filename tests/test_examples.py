"""Smoke tests for the runnable examples (argv-driven --fast mode), so the
examples can't rot silently.  Each main() returns its result object, which
the tests assert on — a crash or a NaN loss fails tier-1, not just the
reader's afternoon."""
import math
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "examples"))


def test_offload_ablation_fast(eight_devices, capsys):
    import offload_ablation

    led = offload_ablation.main(["--fast"])
    assert led.peak_bytes > 0
    assert led.runtime_coverage_ok()
    out = capsys.readouterr().out
    for variant in ("sppo_executed", "sppo_xla_policy", "no_offload",
                    "full_recompute"):
        assert variant in out
    assert "memledger" in out


def test_long_context_training_fast(eight_devices):
    import long_context_training

    history = long_context_training.main(["--fast"])
    assert len(history) == 3
    losses = [h["loss"] for h in history]
    assert all(math.isfinite(l) for l in losses)


@pytest.mark.skipif(os.environ.get("REPRO_USE_PALLAS") == "1",
                    reason="quickstart is covered by the jnp leg")
def test_examples_are_argv_driven():
    """Both examples accept argv lists (the CI smoke contract)."""
    import long_context_training
    import offload_ablation

    for mod in (offload_ablation, long_context_training):
        assert mod.main.__code__.co_argcount >= 1
