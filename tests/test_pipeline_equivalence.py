"""THE integration law: the distributed SPPO pipeline (dp x pp x sp over a
real shard_map mesh) computes the same loss as the single-device reference —
same weights, same tokens, fp32."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import ShapeConfig, get_config
from repro.launch.mesh import compat_make_mesh
from repro.models.model_zoo import build_model
from repro.parallel.ctx import SINGLE
from repro.parallel.runner import (_in_specs_for_params, batch_struct,
                                   resolve_cell, run_pipeline, shard_map)


def _single_loss(mdef, cfg, tokens, labels, context):
    shape = ShapeConfig("t", tokens.shape[1], tokens.shape[0], "train")
    cell = resolve_cell(mdef, shape, data_size=1, model_size=1,
                        overrides=dict(n_chunks=2, grad_accum=1,
                                       partition="length"))
    cell = dataclasses.replace(cell, dtype=jnp.float32)
    key = jax.random.PRNGKey(0)
    sp1 = mdef.init_stage_params(key, 0, 1, jnp.float32)
    g1 = mdef.init_globals(key, jnp.float32)

    def f(sp_, g_):
        out = run_pipeline(cell, SINGLE, sp_, g_, tokens, labels, context,
                           with_loss=True)
        return out["loss"] / jnp.maximum(out["denom"], 1.0)

    return float(jax.jit(f)(sp1, g1))


def _dist_loss(mdef, cfg, tokens, labels, context, *, pp, mesh_shape=(4, 2),
               extra_overrides=None):
    data_size, model_size = mesh_shape
    mesh = compat_make_mesh(mesh_shape, ("data", "model"))
    dp = data_size // pp
    B, S = tokens.shape
    shape = ShapeConfig("t", S, B, "train")
    overrides = dict(n_chunks=2, grad_accum=1, pp=pp, dp=dp,
                     partition="length")
    overrides.update(extra_overrides or {})
    cell = resolve_cell(mdef, shape, data_size=data_size,
                        model_size=model_size, overrides=overrides)
    cell = dataclasses.replace(cell, dtype=jnp.float32)
    key = jax.random.PRNGKey(0)
    stages = [mdef.init_stage_params(key, s, pp, jnp.float32)
              for s in range(pp)]
    g_stage = jax.tree_util.tree_map(
        lambda *ls: jnp.stack([ls[i % pp] for i in range(data_size)]),
        *stages)
    gl = mdef.init_globals(key, jnp.float32)
    b_loc = B // dp

    def lay(x):
        return jnp.stack([x[(i // pp) * b_loc:(i // pp + 1) * b_loc]
                          for i in range(data_size)])[None]

    batch = {"tokens": lay(tokens), "labels": lay(labels)}
    if context is not None:
        batch["context"] = lay(context)

    pspecs = _in_specs_for_params(cell)
    _, bspecs = batch_struct(cell)

    def body(stage_p, g, b):
        ctx = cell.ctx()
        stage_p = jax.tree_util.tree_map(lambda a: a.reshape(a.shape[1:]),
                                         stage_p)
        tok = b["tokens"].reshape(b["tokens"].shape[2:])
        lab = b["labels"].reshape(b["labels"].shape[2:])
        cx = (b["context"].reshape(b["context"].shape[2:])
              if "context" in b else None)
        out = run_pipeline(cell, ctx, stage_p, g, tok, lab, cx,
                           with_loss=True)
        num = ctx.psum_loss_all(out["loss"])
        den = ctx.psum_loss_all(out["denom"])
        return num / jnp.maximum(den, 1.0)

    fn = shard_map(body, mesh,
                   in_specs=(pspecs["stages"], pspecs["globals"], bspecs),
                   out_specs=P())
    return float(jax.jit(fn)(g_stage, gl, batch))


CASES = [
    ("qwen2-7b", 2), ("qwen2-7b", 4),
    ("granite-moe-1b-a400m", 2),
    ("zamba2-7b", 2),
    ("whisper-tiny", 1),
    ("rwkv6-3b", 2),
]


def test_optimized_attention_modes_match(eight_devices):
    """§Perf modes (gather_kv auto-switch + bf16 grad reduce-scatter) keep
    the forward loss identical to the paper-faithful gather_q baseline."""
    cfg = get_config("qwen2-7b").reduced()
    mdef = build_model(cfg)
    B, S = 4, 256
    key = jax.random.PRNGKey(7)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    labels = jnp.roll(tokens, -1, axis=1)
    ref = _single_loss(mdef, cfg, tokens, labels, None)
    got = _dist_loss(mdef, cfg, tokens, labels, None, pp=2,
                     extra_overrides=dict(attn_mode="auto",
                                          grad_compress=True))
    np.testing.assert_allclose(got, ref, rtol=3e-4, atol=3e-4)


def test_msp_rejects_stateful_recurrence_archs():
    """MSP's full-chunk recompute is idempotent for the position-tagged KV
    cache but would advance SSM/RWKV recurrent state `split` times —
    resolve_cell must refuse (DESIGN.md §2)."""
    cfg = get_config("rwkv6-3b").reduced()
    mdef = build_model(cfg)
    with pytest.raises(AssertionError, match="msp unsupported"):
        resolve_cell(mdef, ShapeConfig("t", 256, 4, "train"), data_size=4,
                     model_size=2,
                     overrides=dict(pp=2, dp=2, n_chunks=4, msp=True,
                                    grad_accum=1, partition="length"))


def test_msp_pipeline_equals_single(eight_devices):
    """Executable MSP (§6.2 ramp schedule in the SPMD tick loop) computes
    the same loss as the single-device reference: the ramp sub-events'
    full-chunk recompute is idempotent and the loss masks tile the chunk."""
    cfg = get_config("qwen2-7b").reduced()
    mdef = build_model(cfg)
    B, S = 4, 256
    key = jax.random.PRNGKey(7)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    labels = jnp.roll(tokens, -1, axis=1)
    ref = _single_loss(mdef, cfg, tokens, labels, None)
    got2 = _dist_loss(mdef, cfg, tokens, labels, None, pp=2,
                      extra_overrides=dict(msp=True))
    np.testing.assert_allclose(got2, ref, rtol=3e-4, atol=3e-4)
    got4 = _dist_loss(mdef, cfg, tokens, labels, None, pp=4,
                      extra_overrides=dict(msp=True, n_chunks=4))
    np.testing.assert_allclose(got4, ref, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("arch,pp", CASES)
def test_distributed_equals_single(arch, pp, eight_devices):
    cfg = get_config(arch).reduced()
    if cfg.moe is not None:  # avoid EP-width-dependent capacity drops
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    mdef = build_model(cfg)
    B, S = 4, 256
    key = jax.random.PRNGKey(7)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    labels = jnp.roll(tokens, -1, axis=1)
    context = None
    if cfg.cross_attn is not None:
        nctx = (cfg.n_frames if cfg.encoder_layers
                else cfg.cross_attn.n_context_tokens)
        npad = -(-nctx // 2) * 2
        context = jax.random.normal(jax.random.PRNGKey(9),
                                    (B, npad, cfg.d_model), jnp.float32)
    ref = _single_loss(mdef, cfg, tokens, labels, context)
    got = _dist_loss(mdef, cfg, tokens, labels, context, pp=pp)
    np.testing.assert_allclose(got, ref, rtol=3e-4, atol=3e-4)
