"""Batched serving: prefill a prompt batch, then decode greedily.

  PYTHONPATH=src python examples/serve_decode.py

Shows: chunked prefill filling the position-tagged sequence-sharded cache,
then single-token decode steps appending striped slots — the same
serve_step the decode_32k / long_500k dry-run cells lower.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

from repro.launch import serve


def main():
    out = serve.main([
        "--arch", "qwen2-7b", "--reduced",
        "--mesh", "2x2", "--prompt-len", "128",
        "--batch", "4", "--decode-steps", "12",
    ])
    print(f"\nserved {out.shape[0]} sequences x {out.shape[1]} new tokens")


if __name__ == "__main__":
    main()
