"""Batched serving: prefill a prompt batch, then decode greedily.

  PYTHONPATH=src python examples/serve_decode.py

Shows both decode engines over the same step functions (DESIGN.md §16):
the static lock-step path — chunked prefill filling the position-tagged
sequence-sharded cache, then single-token decode steps appending striped
slots — and the paged-pool continuous-batching engine, which admits
requests into freed slots mid-flight and shares device memory through
per-request block tables.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

from repro.launch import serve


def main():
    out = serve.main([
        "--arch", "qwen2-7b", "--reduced",
        "--mesh", "2x2", "--prompt-len", "128",
        "--batch", "4", "--decode-steps", "12",
    ])
    print(f"\nstatic: served {out.shape[0]} sequences x {out.shape[1]} "
          "new tokens")

    out = serve.main([
        "--arch", "qwen2-7b", "--reduced",
        "--mesh", "2x1", "--prompt-len", "64",
        "--batch", "4", "--decode-steps", "8", "--continuous",
    ])
    print(f"continuous: served {out.shape[0]} sequences x {out.shape[1]} "
          "new tokens through the paged pool")


if __name__ == "__main__":
    main()
