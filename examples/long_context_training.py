"""Long-sequence training with the full SPPO pipeline on a fake 8-device
mesh: dp=2 x pp=2 x sp=2, FLOPs-balanced chunks... this is the paper's
scenario (long sequence, few devices) at CPU-debuggable scale.

  PYTHONPATH=src python examples/long_context_training.py

Shows: subsequence pipeline over pp=2 stages (ppermute hand-offs),
sequence-sharded KV cache, two-level activation management with per-chunk
offload ratios, gradient flow through the whole thing.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

from repro.launch import train


def main():
    history = train.main([
        "--arch", "glm4-9b", "--reduced",
        "--steps", "20", "--seq", "2048", "--batch", "4",
        "--mesh", "4x2", "--pp", "2", "--n-chunks", "4",
        "--log-every", "5",
    ])
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"\nlong-context: loss {first:.3f} -> {last:.3f} over "
          f"{len(history)} steps on a 4x2 mesh (pp=2)")


if __name__ == "__main__":
    main()
