"""Long-sequence training with the full SPPO pipeline on a fake 8-device
mesh: dp=2 x pp=2 x sp=2, FLOPs-balanced chunks... this is the paper's
scenario (long sequence, few devices) at CPU-debuggable scale.

  PYTHONPATH=src python examples/long_context_training.py [--fast]

Shows: subsequence pipeline over pp=2 stages (ppermute hand-offs),
sequence-sharded KV cache, two-level activation management with per-chunk
offload ratios executed through host memory (DESIGN.md §10), gradient flow
through the whole thing.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse

from repro.launch import train


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="3 steps on a short sequence (smoke-test mode)")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    args = ap.parse_args(argv)
    steps = args.steps or (3 if args.fast else 20)
    seq = args.seq or (512 if args.fast else 2048)

    history = train.main([
        "--arch", "glm4-9b", "--reduced",
        "--steps", str(steps), "--seq", str(seq), "--batch", "4",
        "--mesh", "4x2", "--pp", "2", "--n-chunks", "4",
        "--log-every", "1" if args.fast else "5",
    ])
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"\nlong-context: loss {first:.3f} -> {last:.3f} over "
          f"{len(history)} steps on a 4x2 mesh (pp=2)")
    return history


if __name__ == "__main__":
    main()
