"""SPPO ablation at example scale: executed adaptive offload vs the XLA
policy path vs no offload vs full recompute — the Fig. 11 axes, runnable
on CPU.

  PYTHONPATH=src python examples/offload_ablation.py [--fast]

For each variant this prints the compiled memory footprint, step time and
deployed alphas; for the executed variant it also runs the memory ledger
(runtime/memledger.py) and reports the measured per-tick peak next to the
simulator's §5.2 prediction — the same comparison CI's memory-gate
enforces.  On the TPU target the offloaded variants move the tagged
residuals to pinned_host; the CPU backend folds host into device, so the
jaxpr markers and the ledger are the honest evidence here.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ShapeConfig, get_config
from repro.models.model_zoo import build_model
from repro.parallel.ctx import SINGLE
from repro.parallel.runner import resolve_cell, run_pipeline
from repro.runtime import memledger as ml

VARIANTS = {
    "sppo_executed": dict(offload=True, remat="sppo",
                          offload_mode="explicit"),   # prefetch="ahead"
    "sppo_sync_reload": dict(offload=True, remat="sppo",
                             offload_mode="explicit", prefetch="sync"),
    "sppo_xla_policy": dict(offload=True, remat="sppo", offload_mode="xla"),
    "no_offload": dict(offload=False, remat="sppo"),
    "full_recompute": dict(offload=False, remat="full"),
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller model/sequence for smoke runs")
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--layers", type=int, default=None)
    args = ap.parse_args(argv)
    seq = args.seq or (256 if args.fast else 1024)
    layers = args.layers or (2 if args.fast else 4)
    reps = 1 if args.fast else 3

    cfg = get_config("qwen2-7b").reduced(n_layers=layers)
    mdef = build_model(cfg)
    shape = ShapeConfig("abl", seq, args.batch, "train")
    key = jax.random.PRNGKey(0)
    sp = mdef.init_stage_params(key, 0, 1, jnp.bfloat16)
    g = mdef.init_globals(key, jnp.bfloat16)
    toks = jax.random.randint(key, (args.batch, seq), 0, cfg.vocab_size)

    results = {}
    for name, ov in VARIANTS.items():
        cell = resolve_cell(mdef, shape, data_size=1, model_size=1,
                            overrides=dict(n_chunks=4, grad_accum=1, **ov))

        def loss(sp_, g_):
            out = run_pipeline(cell, SINGLE, sp_, g_, toks, toks, None,
                               with_loss=True)
            return out["loss"] / jnp.maximum(out["denom"], 1.0)

        comp = jax.jit(jax.grad(loss)).lower(sp, g).compile()
        ma = comp.memory_analysis()
        f = jax.jit(jax.grad(loss))
        jax.block_until_ready(f(sp, g))
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(f(sp, g))
        dt = (time.perf_counter() - t0) / reps
        results[name] = cell
        print(f"{name:16s} temp {ma.temp_size_in_bytes/2**20:8.1f} MiB  "
              f"step {dt*1e3:7.1f} ms  alphas "
              f"{['%.2f' % a for a in cell.alphas]}")

    # measured ledger vs §5.2 prediction for the executed variant
    cell = results["sppo_executed"]
    led = ml.measure(cell, data_size=1, model_size=1, baseline=True)
    predicted = ml.predicted_spmd_peak(cell)
    exposed = led.exposed_transfer_s or 0.0
    print(f"\nmemledger: measured peak {led.peak_bytes/2**20:.2f} MiB  "
          f"predicted {predicted/2**20:.2f} MiB  "
          f"ratio {led.peak_bytes/max(predicted,1):.4f}  "
          f"host bytes {led.host_bytes/2**20:.2f} MiB  "
          f"exposed transfer {exposed*1e3:.1f} ms")
    # priced exposed-H2D under both reload placements (DESIGN.md §12):
    # same measured bytes/windows, only the lane rule differs
    from repro.core import costmodel as cm
    ahead_exp = led.h2d_exposed_s or 0.0
    sync_exp = led.price_h2d(bw=cm.V5E.d2h_bw, prefetch="sync")
    print(f"exposed h2d: {ahead_exp*1e6:.2f} us prefetch=ahead  vs  "
          f"{sync_exp*1e6:.2f} us prefetch=sync")

    # optimizer-state offload (DESIGN.md §11): combined activations+moments
    # device peak, host-resident vs device-resident AdamW moments.  Skipped
    # under --fast: the CI smoke (test_examples) runs in both backend-matrix
    # legs, and the opt-state measurement already runs once in the
    # memory-gate job (memgate + the optstate suite).
    import dataclasses
    for mom in () if args.fast else (True, False):
        c = dataclasses.replace(
            cell, plan=dataclasses.replace(cell.plan, offload_moments=mom))
        led_m = ml.measure(c, data_size=1, model_size=1, baseline=False,
                           opt=True)
        tag = "host-resident" if mom else "device-resident"
        print(f"moments {tag:15s} combined peak "
              f"{led_m.combined_peak_bytes/2**20:.2f} MiB  "
              f"(moments on host {led_m.moments.host_bytes/2**20:.2f} MiB, "
              f"H2D copies/step {led_m.moments.h2d_count})")
    return led


if __name__ == "__main__":
    main()
