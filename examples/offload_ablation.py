"""SPPO ablation at example scale: adaptive offload vs no offload vs full
recompute — the Fig. 11 axes, runnable on CPU.

  PYTHONPATH=src python examples/offload_ablation.py

Prints the compiled memory footprint and step time for each variant; on the
TPU target the offloaded variant moves the tagged residuals to pinned_host
(verified at the jaxpr level here — the CPU backend folds host into device).
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import time

import jax
import jax.numpy as jnp

from repro.configs.base import ShapeConfig, get_config
from repro.models.model_zoo import build_model
from repro.parallel.ctx import SINGLE
from repro.parallel.runner import resolve_cell, run_pipeline


def main():
    cfg = get_config("qwen2-7b").reduced(n_layers=4)
    mdef = build_model(cfg)
    shape = ShapeConfig("abl", 1024, 4, "train")
    key = jax.random.PRNGKey(0)
    sp = mdef.init_stage_params(key, 0, 1, jnp.bfloat16)
    g = mdef.init_globals(key, jnp.bfloat16)
    toks = jax.random.randint(key, (4, 1024), 0, cfg.vocab_size)

    variants = {
        "sppo_adaptive": dict(offload=True, remat="sppo"),
        "no_offload": dict(offload=False, remat="sppo"),
        "full_recompute": dict(offload=False, remat="full"),
    }
    for name, ov in variants.items():
        cell = resolve_cell(mdef, shape, data_size=1, model_size=1,
                            overrides=dict(n_chunks=4, grad_accum=1, **ov))

        def loss(sp_, g_):
            out = run_pipeline(cell, SINGLE, sp_, g_, toks, toks, None,
                               with_loss=True)
            return out["loss"] / jnp.maximum(out["denom"], 1.0)

        comp = jax.jit(jax.grad(loss)).lower(sp, g).compile()
        ma = comp.memory_analysis()
        f = jax.jit(jax.grad(loss))
        jax.block_until_ready(f(sp, g))
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(f(sp, g))
        dt = (time.perf_counter() - t0) / 3
        print(f"{name:16s} temp {ma.temp_size_in_bytes/2**20:8.1f} MiB  "
              f"step {dt*1e3:7.1f} ms  alphas "
              f"{['%.2f' % a for a in cell.alphas]}")


if __name__ == "__main__":
    main()
