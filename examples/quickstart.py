"""Quickstart: train a reduced Qwen2 with the SPPO chunked pipeline on CPU.

  PYTHONPATH=src python examples/quickstart.py

What this shows (in ~2 minutes on a laptop):
  * FLOPs-balanced sequence partitioning into subsequences (§3.2),
  * per-chunk adaptive offload ratios from the §5.2 solver,
  * a real training loop (AdamW, bf16) whose loss drops from ~ln(V).
"""
import sys

from repro.launch import train


def main():
    history = train.main([
        "--arch", "qwen2-7b", "--reduced",
        "--steps", "40", "--seq", "512", "--batch", "8",
        "--mesh", "1x1", "--n-chunks", "4",
        "--log-every", "10",
    ])
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"\nquickstart: loss {first:.3f} -> {last:.3f} "
          f"({'OK' if last < first else 'NOT LEARNING'})")


if __name__ == "__main__":
    main()
