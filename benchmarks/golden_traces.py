"""Golden schedule-trace snapshots: freeze the solver/simulator event trace
for fixed configs and fail CI on silent schedule drift.

  PYTHONPATH=src python -m benchmarks.golden_traces --check --out regen/
  PYTHONPATH=src python -m benchmarks.golden_traces --write

The traces are the event-driven simulator's full lane timeline
(core/simulate.py) for the solver's candidate profile of two fixed SPPO
configs — exactly what the solver scores and what the runner's feed-event
contract executes.  Any change to the cost model, the offload-ratio
solver, the ramp schedule, or the playout's gating rules moves these
files; tests/test_golden_traces.py diffs them so the change must be a
reviewed regeneration (--write), never an accident.
"""
from __future__ import annotations

import argparse
import os
import sys

from repro.configs.base import get_config
from repro.core import simulate as sim
from repro.core import solver

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "..", "tests", "golden")

# (name, solver.simulate_candidate kwargs) — fixed forever; add new entries
# rather than editing these.  "reduced": True swaps in the CPU smoke config
# and (with n_params=None) derives the parameter count from its structs;
# offload_moments prices the §11 optimizer-state epilogue.
CONFIGS = [
    ("gpt7b_seq512k_pp4_n8_plain",
     dict(arch="sppo-gpt-7b", seq_len=524288, batch=1,
          n_params=6_700_000_000, pp=4, n=8, sp=16, msp=False)),
    ("gpt7b_seq512k_pp4_n8_msp2",
     dict(arch="sppo-gpt-7b", seq_len=524288, batch=1,
          n_params=6_700_000_000, pp=4, n=8, sp=16, msp=True, msp_split=2)),
    ("gpt7b_reduced_pp2_optoff_plain",
     dict(arch="sppo-gpt-7b", reduced=True, seq_len=256, batch=4,
          n_params=None, pp=2, n=4, sp=2, msp=False,
          offload_moments=True)),
    ("gpt7b_reduced_pp2_optoff_msp2",
     dict(arch="sppo-gpt-7b", reduced=True, seq_len=256, batch=4,
          n_params=None, pp=2, n=4, sp=2, msp=True, msp_split=2,
          offload_moments=True)),
    # prefetch="sync" lane mode (DESIGN.md §12): the autodiff reload
    # placement, priced — pins the exposed-H2D gap vs the "ahead" traces
    ("gpt7b_seq512k_pp4_n8_plain_syncpf",
     dict(arch="sppo-gpt-7b", seq_len=524288, batch=1,
          n_params=6_700_000_000, pp=4, n=8, sp=16, msp=False,
          prefetch="sync")),
    ("gpt7b_reduced_pp2_syncpf",
     dict(arch="sppo-gpt-7b", reduced=True, seq_len=256, batch=4,
          n_params=None, pp=2, n=4, sp=2, msp=False, prefetch="sync")),
    # packed variable-length workload cells (DESIGN.md §13): doc_lens specs
    # resolve through data.pipeline.sample_doc_lengths (seeded histogram),
    # the candidate runs the packed cost profile instead of the uniform
    # triangle — freezing the profile-balanced boundaries and the
    # per-batch sequence-aware alphas they induce
    ("gpt7b_seq512k_pp4_n8_varlen",
     dict(arch="sppo-gpt-7b", seq_len=524288, batch=4,
          n_params=6_700_000_000, pp=4, n=8, sp=16, msp=False,
          doc_lens=dict(n_docs=24, seed=0, dist="zipf", mean_len=49152,
                        max_len=393216))),
    ("gpt7b_reduced_pp2_varlen",
     dict(arch="sppo-gpt-7b", reduced=True, seq_len=256, batch=4,
          n_params=None, pp=2, n=4, sp=2, msp=False,
          doc_lens=dict(n_docs=16, seed=0, dist="zipf", mean_len=48,
                        max_len=192))),
    # ring-distributed attention lane (DESIGN.md §15): the sp-hop KV
    # rotation priced per chunk — freezes the zig-zag hop fractions, the
    # per-hop overlap recurrence, and the ring_stall the playout exposes
    ("gpt7b_seq512k_pp4_n8_ring",
     dict(arch="sppo-gpt-7b", seq_len=524288, batch=1,
          n_params=6_700_000_000, pp=4, n=8, sp=16, msp=False,
          attn_mode="ring")),
    ("gpt7b_reduced_pp2_ring",
     dict(arch="sppo-gpt-7b", reduced=True, seq_len=256, batch=4,
          n_params=None, pp=2, n=4, sp=2, msp=False, attn_mode="ring")),
]


def trace_lines(spec: dict) -> list:
    """Deterministic text form of one config's simulated trace."""
    spec = dict(spec)
    cfg = get_config(spec.pop("arch"))
    if spec.pop("reduced", False):
        cfg = cfg.reduced()
    if spec.get("n_params") is None:
        from repro.models.model_zoo import build_model
        from repro.parallel import specs as SP
        spec["n_params"] = SP.count_active_params(
            build_model(cfg), spec["pp"], spec["pp"])
    if isinstance(spec.get("doc_lens"), dict):
        # seeded histogram spec -> concrete document lengths (§13)
        from repro.data import pipeline as dpipe
        spec["doc_lens"] = [int(x) for x in
                            dpipe.sample_doc_lengths(**spec["doc_lens"])]
    total, alphas, res = solver.simulate_candidate(cfg, **spec)
    lines = [
        "# golden schedule trace — regenerate with "
        "`python -m benchmarks.golden_traces --write`",
        f"total_s,{total:.9e}",
        f"alphas,{':'.join(f'{a:.6f}' for a in alphas)}",
        f"d2h_stall_s,{res.d2h_stall:.9e}",
        f"h2d_stall_s,{res.h2d_stall:.9e}",
        f"p2p_stall_s,{res.p2p_stall:.9e}",
    ]
    if any(ev.lane == sim.RING for ev in res.trace):
        # emitted only for ring-priced configs so the pre-ring golden
        # files stay byte-identical
        lines.append(f"ring_stall_s,{res.ring_stall:.9e}")
    lines += [
        f"peak_units,{':'.join(f'{p:.6e}' for p in res.peak_units)}",
        "stage,lane,chunk,sub,n_sub,start_s,end_s",
    ]
    for ev in res.trace:
        lines.append(f"{ev.stage},{ev.lane},{ev.chunk},{ev.sub},{ev.n_sub},"
                     f"{ev.start:.9e},{ev.end:.9e}")
    return lines


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--write", action="store_true",
                    help="regenerate tests/golden/ in place")
    ap.add_argument("--check", action="store_true",
                    help="diff regenerated traces against tests/golden/")
    ap.add_argument("--out", default=None,
                    help="also write regenerated traces to this directory")
    args = ap.parse_args(argv)

    golden = os.path.normpath(GOLDEN_DIR)
    os.makedirs(golden, exist_ok=True)
    if args.out:
        os.makedirs(args.out, exist_ok=True)

    drift = []
    for name, spec in CONFIGS:
        lines = trace_lines(spec)
        text = "\n".join(lines) + "\n"
        path = os.path.join(golden, f"{name}.csv")
        if args.out:
            with open(os.path.join(args.out, f"{name}.csv"), "w") as f:
                f.write(text)
        if args.write:
            with open(path, "w") as f:
                f.write(text)
            print(f"wrote {path} ({len(lines)} lines)")
        elif args.check:
            want = open(path).read() if os.path.exists(path) else ""
            if text != want:
                got_l, want_l = text.splitlines(), want.splitlines()
                diffs = [i for i, (a, b) in enumerate(
                    zip(got_l, want_l)) if a != b]
                extra = abs(len(got_l) - len(want_l))
                drift.append(f"{name}: {len(diffs)} changed lines, "
                             f"{extra} added/removed "
                             f"(first: {got_l[diffs[0]] if diffs else '<tail>'!r})")
            else:
                print(f"{name}: OK ({len(lines)} lines)")
    if drift:
        print("\nSCHEDULE TRACE DRIFT (if intentional, regenerate with "
              "`python -m benchmarks.golden_traces --write`):",
              file=sys.stderr)
        for msg in drift:
            print(f"  - {msg}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
