"""Packed-vs-padded throughput gate (DESIGN.md §13).

Builds one seeded skewed-length corpus (the Zipf histogram real pretraining
mixes look like), lays it out two ways —

  * ``packed``  — greedy first-fit-decreasing packing into rows of
    ``seq_len`` with the per-query segment window (``doc_start``) keeping
    documents from attending across boundaries, profile-balanced chunks;
  * ``padded``  — the pad-to-max baseline: one document per row, every row
    padded to the full ``seq_len``;

and times one real train step (loss + grads through the SPPO pp=1 chunk
loop) for each.  Both layouts compute the loss over exactly the same real
tokens (the label sentinel zero-weights padding), so tokens/sec over real
tokens is an apples-to-apples throughput.  The gate fails unless packed
beats padded by ``--factor`` (the packing removes ~Nx redundant padding
rows, so the margin is structural, not a timing accident).

``--fast`` skips the wall-clock measurement and gates on the analytic cost
ratio from the packed cost profile (the same sawtooth the partitioner
balances) — the mode ``benchmarks.run`` registers.

  PYTHONPATH=src python -m benchmarks.bench_varlen \
      [--fast] [--factor 1.5] [--csv varlen.csv]
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from typing import List, Tuple

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.base import ShapeConfig, get_config
from repro.core import partition as part
from repro.data import pipeline as dpipe
from repro.models.model_zoo import build_model
from repro.parallel.ctx import SINGLE
from repro.parallel.runner import resolve_cell, run_pipeline

ARCH = "qwen2-7b"
SEQ_LEN = 256
N_DOCS = 12
MEAN_LEN = 48
MAX_LEN = 224
SEED = 0
DEFAULT_FACTOR = 1.5


def _build_corpus():
    cfg = get_config(ARCH).reduced()
    docs = dpipe.sample_corpus(N_DOCS, vocab_size=cfg.vocab_size, seed=SEED,
                               dist="zipf", mean_len=MEAN_LEN,
                               max_len=MAX_LEN)
    return cfg, docs


def _step_time(mdef, cell, batch, reps: int = 3) -> float:
    """Best-of-N wall time of one jitted loss+grad step (seconds)."""
    import dataclasses

    cell = dataclasses.replace(cell, dtype=jnp.float32)
    key = jax.random.PRNGKey(0)
    sp1 = mdef.init_stage_params(key, 0, 1, jnp.float32)
    g1 = mdef.init_globals(key, jnp.float32)
    tok = jnp.asarray(batch.tokens)
    lab = jnp.asarray(batch.labels)
    ds = jnp.asarray(batch.doc_start) if cell.varlen else None

    def loss(sp_, g_):
        out = run_pipeline(cell, SINGLE, sp_, g_, tok, lab, None,
                           with_loss=True, doc_start=ds)
        return out["loss"] / jnp.maximum(out["denom"], 1.0)

    step = jax.jit(jax.value_and_grad(loss, argnums=(0, 1)))
    jax.block_until_ready(step(sp1, g1))  # compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(step(sp1, g1))
        best = min(best, time.perf_counter() - t0)
    return best


def _analytic_cost(row_lens, r: float) -> float:
    """Total relative step cost of a layout: sum of its packed profile."""
    return float(part.packed_cost_profile(row_lens, SEQ_LEN, r).sum())


def bench_varlen(measure: bool = True, factor: float = DEFAULT_FACTOR,
                 csv_path: str | None = None) -> Tuple[List, str, bool]:
    """Returns (csv_rows, text, gate_ok)."""
    cfg, docs = _build_corpus()
    mdef = build_model(cfg)
    lens = [len(d) for d in docs]
    real_tokens = sum(lens)
    r = part.flops_per_token_ratio(cfg)

    packed = dpipe.pack_documents(docs, SEQ_LEN)
    padded = dpipe.pad_to_max(docs, SEQ_LEN)
    rows_packed = part.pack_lengths(lens, SEQ_LEN)
    packed_rl = [[lens[i] for i in row] for row in rows_packed]
    padded_rl = [[ln] for ln in lens]

    cells = []
    for name, batch, doc_lens in (("packed", packed, lens),
                                  ("padded", padded, None)):
        B = batch.tokens.shape[0]
        shape = ShapeConfig(f"varlen-{name}", SEQ_LEN, B, "train")
        cell = resolve_cell(mdef, shape, data_size=1, model_size=1,
                            overrides=dict(n_chunks=4, grad_accum=1,
                                           partition="flops", offload=False),
                            doc_lens=doc_lens)
        cells.append((name, batch, cell))

    analytic = {"packed": _analytic_cost(packed_rl, r),
                "padded": _analytic_cost(padded_rl, r)}
    times = {}
    if measure:
        for name, batch, cell in cells:
            times[name] = _step_time(mdef, cell, batch)

    ratio_analytic = analytic["padded"] / analytic["packed"]
    ratio = (times["padded"] / times["packed"]) if measure else ratio_analytic
    ok = ratio >= factor

    csv_rows = []
    lines = [f"== Packed vs pad-to-max throughput ({ARCH} reduced, "
             f"S={SEQ_LEN}, {N_DOCS} zipf docs, {real_tokens} real "
             "tokens) =="]
    for name, batch, cell in cells:
        B = batch.tokens.shape[0]
        pad_frac = 1.0 - real_tokens / (B * SEQ_LEN)
        t = times.get(name)
        tput = real_tokens / t if t else 0.0
        csv_rows.append((f"varlen_{name}",
                         f"{t * 1e6:.0f}" if t else "",
                         f"{analytic[name]:.0f}"))
        lines.append(
            f"{name:8s} rows {B:3d}  pad {pad_frac:6.1%}  "
            f"chunks {cell.sched.lengths}  "
            + (f"step {t * 1e3:8.1f} ms  {tput:9.0f} tok/s"
               if t else f"analytic cost {analytic[name]:.0f}"))
    lines.append(
        f"speedup packed/padded: "
        + (f"{ratio:.2f}x measured, " if measure else "")
        + f"{ratio_analytic:.2f}x analytic "
        f"(gate: >= {factor:.2f}x -> {'OK' if ok else 'FAIL'})")
    csv_rows.append(("varlen_speedup",
                     f"{ratio:.3f}" if measure else "",
                     f"{ratio_analytic:.3f}"))

    if csv_path:
        import csv as _csv

        with open(csv_path, "w", newline="") as f:
            w = _csv.writer(f)
            w.writerow(["cell", "rows", "real_tokens", "pad_frac",
                        "step_s", "tok_per_s", "analytic_cost"])
            for name, batch, cell in cells:
                B = batch.tokens.shape[0]
                t = times.get(name)
                w.writerow([name, B, real_tokens,
                            f"{1.0 - real_tokens / (B * SEQ_LEN):.4f}",
                            f"{t:.6f}" if t else "",
                            f"{real_tokens / t:.1f}" if t else "",
                            f"{analytic[name]:.1f}"])
            w.writerow([])
            w.writerow(["speedup_measured", f"{ratio:.4f}" if measure
                        else ""])
            w.writerow(["speedup_analytic", f"{ratio_analytic:.4f}"])
            w.writerow(["factor", f"{factor:.2f}"])
            w.writerow(["gate_ok", int(ok)])
    return csv_rows, "\n".join(lines), ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="gate on the analytic cost ratio (no wall clock)")
    ap.add_argument("--factor", type=float, default=DEFAULT_FACTOR)
    ap.add_argument("--csv", default=None)
    args = ap.parse_args(argv)
    rows, text, ok = bench_varlen(measure=not args.fast,
                                  factor=args.factor, csv_path=args.csv)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us},{derived}")
    print()
    print(text)
    if not ok:
        print("\nVARLEN GATE FAILED: packed layout did not clear the "
              f"pinned {args.factor:.2f}x margin", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
