"""One benchmark per paper table/figure (DESIGN.md §6 index).

Each function returns a list of CSV rows (name, us_per_call, derived) plus a
human-readable table string.  'us_per_call' is a real CPU measurement where
one exists (micro-benches), otherwise 0 with the derived analytic value in
'derived' (the container has no TPU — DESIGN.md §9 honesty ledger).
"""
from __future__ import annotations

import time
from typing import List, Tuple

from repro.configs.base import get_config
from repro.core import costmodel as cm
from repro.core import partition as part
from repro.core import schedule as sched
from repro.core import solver
from benchmarks.models import (Workload, ds_ulysses_iter_time, max_seq_len,
                               megatron_iter_time, sppo_iter_time)

GPT = {
    "gpt-7b": Workload("gpt-7b", 6_700_000_000, 32, 4096, 0, sp=8, pp=4),
    "gpt-13b": Workload("gpt-13b", 13_000_000_000, 40, 5120, 0, sp=8, pp=8),
    "gpt-65b": Workload("gpt-65b", 65_000_000_000, 80, 8192, 0, sp=16, pp=8),
}


def bench_partition() -> Tuple[List, str]:
    """Fig. 4/5: compute & memory imbalance of the two fixed policies."""
    cfg = get_config("sppo-gpt-7b")
    r = part.flops_per_token_ratio(cfg)
    rows, lines = [], ["== Fig 4/5: partition imbalance (seq=128K) =="]
    for n in (8, 16):
        fl = part.partition(131072, n, cfg, "flops", multiple=16)
        ln = part.partition(131072, n, cfg, "length", multiple=16)
        ci_f = part.imbalance(part.chunk_costs(fl, r))
        ci_l = part.imbalance(part.chunk_costs(ln, r))
        act_spread = max(fl.lengths) / min(fl.lengths)
        rows.append((f"partition_flops_n{n}_compute_imb", 0, round(ci_f, 3)))
        rows.append((f"partition_length_n{n}_compute_imb", 0, round(ci_l, 3)))
        rows.append((f"partition_flops_n{n}_act_spread", 0,
                     round(act_spread, 2)))
        lines.append(f"N={n:3d}: compute imb flops={ci_f:.3f} "
                     f"length={ci_l:.3f}; activation spread (flops) "
                     f"{act_spread:.2f}x (paper Fig5: 10.59/2.87≈3.7x @N=8)")
    return rows, "\n".join(lines)


def bench_offload() -> Tuple[List, str]:
    """§5.2: α schedule, overlap, peak memory vs fixed policies."""
    w = GPT["gpt-7b"]
    w = Workload(w.name, w.n_params, w.n_layers, w.d_model, 1 << 20, 1,
                 sp=8, pp=4)
    rows, lines = [], ["== §5.2 adaptive offload (gpt-7b @1M, A100) =="]
    for n in (16, 32):
        ad = sppo_iter_time(w, cm.A100, n, adaptive=True)
        fx = sppo_iter_time(w, cm.A100, n, adaptive=False)
        rows.append((f"offload_adaptive_n{n}_stall_s", 0,
                     round(ad["stall"], 4)))
        rows.append((f"offload_fixedfull_n{n}_stall_s", 0,
                     round(fx["stall"], 4)))
        rows.append((f"offload_adaptive_n{n}_peakGB", 0,
                     round(ad["peak_act"] / 1e9, 2)))
        lines.append(
            f"N={n}: adaptive stall {ad['stall']*1e3:.1f} ms vs fixed-full "
            f"{fx['stall']*1e3:.1f} ms; peak act {ad['peak_act']/1e9:.1f} GB "
            f"(alphas {['%.2f' % a for a in ad['alphas'][:4]]}...)")
    return rows, "\n".join(lines)


def bench_pipeline(measure=True) -> Tuple[List, str]:
    """Fig. 7 + §3.3: T(N) trade-off; CPU-measured per-chunk overhead."""
    rows, lines = [], ["== Fig 7: subsequence count trade-off =="]
    w = Workload("gpt-7b", 6_700_000_000, 32, 4096, 131072, 1, sp=8, pp=4)
    for n in (4, 8, 16, 32, 64, 128):
        r = sppo_iter_time(w, cm.A100, n)
        rows.append((f"pipeline_T_n{n}", 0, round(r["time"], 4)))
        lines.append(f"N={n:4d}: T={r['time']*1e3:8.1f} ms  bubble="
                     f"{sched.bubble_ratio(w.pp, n):.3f}")
    if measure:
        us = _measure_chunk_overhead()
        rows.append(("measured_per_chunk_dispatch_us", round(us, 1), 0))
        lines.append(f"measured per-chunk dispatch overhead (CPU, reduced "
                     f"config): {us:.0f} us/chunk")
    return rows, "\n".join(lines)


def _measure_chunk_overhead() -> float:
    """Real measurement: per-chunk cost of the chunk machinery at tiny size."""
    import jax
    import jax.numpy as jnp
    from repro.configs.base import ShapeConfig
    from repro.models.model_zoo import build_model
    from repro.parallel.ctx import SINGLE
    from repro.parallel.runner import resolve_cell, run_pipeline

    cfg = get_config("qwen2-7b").reduced()
    mdef = build_model(cfg)
    key = jax.random.PRNGKey(0)
    sp = mdef.init_stage_params(key, 0, 1, jnp.bfloat16)
    g = mdef.init_globals(key, jnp.bfloat16)
    toks = jax.random.randint(key, (2, 512), 0, cfg.vocab_size)
    times = {}
    for n in (1, 4):
        cell = resolve_cell(mdef, ShapeConfig("b", 512, 2, "train"),
                            data_size=1, model_size=1,
                            overrides=dict(n_chunks=n, grad_accum=1,
                                           offload=False, remat="none",
                                           partition="length"))

        def f(sp_, g_):
            out = run_pipeline(cell, SINGLE, sp_, g_, toks, toks, None,
                               with_loss=True)
            return out["loss"]

        jf = jax.jit(f)
        jf(sp, g).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(5):
            jf(sp, g).block_until_ready()
        times[n] = (time.perf_counter() - t0) / 5
    return max(0.0, (times[4] - times[1]) / 3 * 1e6)


def bench_e2e() -> Tuple[List, str]:
    """Fig. 10: modeled TGS, SPPO vs the paper's Table-4 baseline configs.

    Baselines use the paper's own tuned layouts (Table 4): Megatron-Tuned
    runs SP=32/PP=1 for 7B (bubble-free, pays +1/3 recompute), SP=8/PP=8
    for 13B, SP=64/PP=2 for 65B; at these sequence lengths the micro-batch
    count collapses to 1 (the paper's Fig. 3b observation), so PP>1
    baselines eat the naive-pipeline bubble."""
    rows = []
    lines = ["== Fig 10 (modeled, A100 constants): TGS =="]
    # (model, gpus, [seq K], sppo (sp,pp), megatron-tuned (sp,pp))
    cases = [("gpt-7b", 32, [512, 768, 1024], (8, 4), (32, 1)),
             ("gpt-13b", 64, [512, 1024, 1280], (8, 8), (8, 8)),
             ("gpt-65b", 128, [512, 640, 1024], (16, 8), (64, 2))]
    for name, gpus, seqs, (ssp, spp), (msp_, mpp) in cases:
        base = GPT[name]
        for sk in seqs:
            s = sk * 1024
            w = Workload(name, base.n_params, base.n_layers, base.d_model,
                         s, 1, sp=ssp, pp=spp)
            wm = Workload(name, base.n_params, base.n_layers, base.d_model,
                          s, 1, sp=msp_, pp=mpp)
            n = max(spp * 2, s // 65536)
            sppo = sppo_iter_time(w, cm.A100, n, msp=True)
            meg = megatron_iter_time(wm, cm.A100)
            ds = ds_ulysses_iter_time(w, cm.A100, n_heads=base.d_model // 128)
            sp_up = meg["time"] / sppo["time"]
            rows.append((f"e2e_{name}_{sk}k_sppo_tgs", 0,
                         round(sppo["tgs"], 1)))
            rows.append((f"e2e_{name}_{sk}k_speedup_vs_meg", 0,
                         round(sp_up, 2)))
            lines.append(f"{name} @{sk}K x{gpus}gpu: SPPO {sppo['tgs']:.0f} "
                         f"tgs | meg-tuned {meg['tgs']:.0f} | ulysses "
                         f"{ds['tgs']:.0f} | speedup vs meg {sp_up:.2f}x")
    lines.append("paper reports 1.13-1.29x (7B, tuned baseline) up to "
                 "3.38x (65B); the model lands in the same regimes "
                 "(recompute-bound 7B ~1.2-1.3x, bubble-bound 65B multi-x)")
    return rows, "\n".join(lines)


def bench_breakdown() -> Tuple[List, str]:
    """Fig. 11: ablation — no offload / fixed full / adaptive / +MSP."""
    w = Workload("gpt-13b", 13_000_000_000, 40, 5120, 512 * 1024, 1,
                 sp=8, pp=8)
    n = 32
    rows, lines = [], ["== Fig 11 (modeled): breakdown, gpt-13b @512K =="]
    base = megatron_iter_time(w, cm.A100)["time"]
    variants = {
        "no_offload": sppo_iter_time(w, cm.A100, n, adaptive=True),
        "full_offload": sppo_iter_time(w, cm.A100, n, adaptive=False),
        "adaptive": sppo_iter_time(w, cm.A100, n, adaptive=True),
        "adaptive_msp": sppo_iter_time(w, cm.A100, n, adaptive=True,
                                       msp=True),
    }
    for k, v in variants.items():
        rows.append((f"breakdown_{k}_rel_speedup", 0,
                     round(base / v["time"], 2)))
        lines.append(f"{k:14s}: {base / v['time']:.2f}x vs megatron-ish")
    return rows, "\n".join(lines)


def bench_seqscale() -> Tuple[List, str]:
    """Fig. 12: max sequence length vs chip count."""
    rows, lines = [], ["== Fig 12 (modeled): max seq len, gpt-7b =="]
    base7 = GPT["gpt-7b"]
    baseline = None
    for gpus in (32, 64, 128):
        sp = 8
        pp = gpus // sp
        w = Workload("gpt-7b", base7.n_params, base7.n_layers, base7.d_model,
                     0, 1, sp=sp, pp=pp)
        s_sppo = max_seq_len(w, cm.A100, mode="sppo")
        s_meg = max_seq_len(w, cm.A100, mode="megatron")
        s_ds = max_seq_len(w, cm.A100, mode="ulysses")
        if baseline is None:
            baseline = s_sppo
        rows.append((f"seqscale_{gpus}gpu_sppo_rel", 0,
                     round(s_sppo / baseline, 2)))
        lines.append(f"{gpus:4d} gpus: sppo {s_sppo/1e6:.2f}M "
                     f"({s_sppo/baseline:.2f}x) | megatron {s_meg/1e6:.2f}M "
                     f"| ulysses {s_ds/1e6:.2f}M")
    lines.append("paper: near-linear sppo scaling 1.3x/2x/4x @32/64/128; "
                 "ulysses head-limited; megatron sub-linear")
    return rows, "\n".join(lines)


def bench_schedule_sim(measure=True) -> Tuple[List, str]:
    """DESIGN.md §3: event-simulated vs closed-form vs measured iteration
    time, per schedule (plain / MSP ramp), with simulated bubble ratios.

    The closed forms assume bubbles only at the pipeline ends; the playout
    exposes steady-phase resynchronization and unhidden transfers — the gap
    between the two columns is the solver's reason to simulate."""
    from repro.core.solver import simulate_candidate

    cfg = get_config("sppo-gpt-7b")
    rows, lines = [], ["== DESIGN §3: schedule playout vs closed form "
                      "(gpt-7b @512K, v5e) =="]
    seq, batch, n_params, sp = 524288, 1, 6_700_000_000, 16
    for pp, n in ((4, 16), (4, 32), (8, 32)):
        for msp in (False, True):
            name = f"pp{pp}_n{n}" + ("_msp" if msp else "")
            t_sim, _, res = simulate_candidate(
                cfg, seq, batch, n_params, pp, n, sp, cm.V5E, msp=msp)
            # closed form over the same FLOPs-weighted chunk costs
            per_stage = res.stage_busy[0]  # F(N): one stage's total work
            cf = (sched.msp_total_time(pp, n, per_stage)
                  if msp else sched.total_time(pp, n, per_stage))
            rows.append((f"schedsim_{name}_sim_s", 0, round(t_sim, 4)))
            rows.append((f"schedsim_{name}_closed_s", 0, round(cf, 4)))
            rows.append((f"schedsim_{name}_bubble", 0,
                         round(res.bubble_ratio, 4)))
            lines.append(
                f"pp={pp} N={n:3d} {'msp ' if msp else 'plain'}: "
                f"sim {t_sim*1e3:7.1f} ms | closed {cf*1e3:7.1f} ms | "
                f"bubble {res.bubble_ratio:.3f} | fill "
                f"{res.fill_bubble[-1]*1e3:.1f} ms | d2h stall "
                f"{res.d2h_stall*1e3:.1f} ms")
    if measure:
        us, n_ratio = _measure_tick_loop()
        rows.append(("schedsim_measured_tick_us", round(us, 1), 0))
        rows.append(("schedsim_measured_n4_over_n1", 0, round(n_ratio, 3)))
        lines.append(f"measured CPU chunk-loop step (reduced cfg, pp=1): "
                     f"{us:.0f} us/chunk at N=4; N=4/N=1 wall ratio "
                     f"{n_ratio:.2f} — below 1.0 because block-causal "
                     f"chunking skips the masked upper attention blocks a "
                     f"dense single-chunk pass still computes ((N−1)/2N of "
                     f"pairs saved), minus per-chunk dispatch overhead "
                     f"pushing the other way")
    return rows, "\n".join(lines)


def _measure_tick_loop() -> Tuple[float, float]:
    """Real CPU measurement of the runner's chunk-loop N-scaling, 4 chunks
    vs 1 over the same sequence.  NOTE this is *not* iso-work: a dense
    masked attention computes the full S x S rectangle in one chunk, while
    block-causal chunking structurally skips the strictly-upper blocks, so
    the ratio bundles that saving with per-chunk dispatch overhead."""
    import jax
    import jax.numpy as jnp
    from repro.configs.base import ShapeConfig
    from repro.models.model_zoo import build_model
    from repro.parallel.ctx import SINGLE
    from repro.parallel.runner import resolve_cell, run_pipeline

    cfg = get_config("qwen2-7b").reduced()
    mdef = build_model(cfg)
    key = jax.random.PRNGKey(0)
    sp = mdef.init_stage_params(key, 0, 1, jnp.bfloat16)
    g = mdef.init_globals(key, jnp.bfloat16)
    toks = jax.random.randint(key, (2, 512), 0, cfg.vocab_size)
    times = {}
    for n in (1, 4):
        cell = resolve_cell(mdef, ShapeConfig("b", 512, 2, "train"),
                            data_size=1, model_size=1,
                            overrides=dict(n_chunks=n, grad_accum=1,
                                           offload=False, remat="none",
                                           partition="length"))

        def f(sp_, g_):
            out = run_pipeline(cell, SINGLE, sp_, g_, toks, toks, None,
                               with_loss=True)
            return out["loss"]

        jf = jax.jit(f)
        jf(sp, g).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(5):
            jf(sp, g).block_until_ready()
        times[n] = (time.perf_counter() - t0) / 5
    return times[4] / 4 * 1e6, times[4] / times[1]


def bench_solver() -> Tuple[List, str]:
    """§6.1: heuristic solver choices across the paper's Table 4 regimes."""
    rows, lines = [], ["== §6.1 heuristic solver =="]
    for name, seq in (("sppo-gpt-7b", 512 * 1024), ("sppo-gpt-7b", 1 << 20),
                      ("sppo-gpt-13b", 512 * 1024)):
        cfg = get_config(name)
        n_params = 6.7e9 if "7b" in name else 13e9
        res = solver.solve(cfg, seq, 1, int(n_params))
        rows.append((f"solver_{name}_{seq >> 10}k_pp", 0, res.pp))
        rows.append((f"solver_{name}_{seq >> 10}k_N", 0, res.n_chunks))
        lines.append(f"{name} @{seq >> 10}K: PP={res.pp} N={res.n_chunks} "
                     f"bubble={res.bubble_ratio:.3f} "
                     f"T≈{res.est_time * 1e3:.0f} ms")
    return rows, "\n".join(lines)
