"""CI memory-gate: measured-vs-predicted peak memory honesty check.

  PYTHONPATH=src python -m benchmarks.memgate \
      --budgets benchmarks/budgets.json --out memledger/ [--update]

For every gate in budgets.json this builds the cell (offload on, pp>1
emulated mesh), executes one real train-grad step through
runtime/memledger.measure, and enforces two contracts:

  1. honesty gate — measured peak bytes may not exceed the simulator's
     prediction (costmodel.chunk_act_bytes -> simulate.spmd_tick_peak over
     the runner's feed events) by more than ``max_ratio`` (1.10: the §5.2
     recurrence must describe reality);
  2. budget diff — the measured peak must stay within ``band`` of the
     value recorded in budgets.json, so any intentional change to the
     memory behavior shows up as a reviewed diff to that file
     (regenerate with --update).

Gates with ``"offload_moments": true`` additionally measure the executed
optimizer-state offload (DESIGN.md §11): one real AdamW update over the
measured grads, the ledger's moments channel (opt_m@/opt_v@ jaxpr walk +
update-phase probes + the one-H2D-per-leaf copy count), the *combined*
activations+moments device peak against ``predicted_combined_peak``, and a
strict-reduction check — moment offload must measurably lower the combined
device peak vs the same cell with ``offload_moments=False``.

Plain gates run the prefetch ablation (DESIGN.md §12): the same cell is
re-measured with ``prefetch="sync"`` and the gate fails unless
``prefetch="ahead"`` leaves the measured §5.2 peak unraised AND strictly
reduces the priced exposed-H2D (``MemLedger.price_h2d`` over the measured
bytes and backward windows).

Gates with ``"offload_dtype"`` (fp8/int8) run the compression ablation
instead (DESIGN.md §14): the same cell — same alphas, so the row split is
held fixed — is re-measured with ``offload_dtype="none"`` and the gate
fails unless the codec strictly cuts the measured host/wire off-bytes AND
the priced sync-mode exposed-H2D, while leaving the raw device drain
identical, and the one-step loss/grad drift of the compressed step against
the raw step stays within the gate's pinned tolerances.

The per-tick ledger CSVs (including the moments and h2d_stall_s columns,
plus the sync-mode ablation ledgers) land in --out and are uploaded as a
CI artifact.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import json
import sys

import jax.numpy as jnp

from repro.configs.base import ShapeConfig, get_config
from repro.models.model_zoo import build_model
from repro.parallel import runner
from repro.runtime import memledger as ml

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}


def run_gate(gate: dict):
    """Returns (measured_peak, predicted_peak, ledger, cell).

    Plain gates compare the §5.2 activation peak; opt-state gates
    (``offload_moments``) compare the combined activations+moments device
    peak and measure the moments channel from a real AdamW update."""
    import dataclasses

    cfg = get_config(gate["arch"])
    if gate.get("reduced", True):
        cfg = cfg.reduced()
    mdef = build_model(cfg)
    opt_gate = bool(gate.get("offload_moments", False))
    shape = ShapeConfig(gate["name"], gate["seq"], gate["batch"], "train")
    doc_lens = None
    if gate.get("doc_lens"):
        # packed variable-length gate cell (DESIGN.md §13): the seeded
        # skewed histogram resolves to document lengths, the measured step
        # runs the packed batch generated from them
        from repro.data import pipeline as dpipe

        doc_lens = [int(x) for x in
                    dpipe.sample_doc_lengths(**gate["doc_lens"])]
    cell = runner.resolve_cell(
        mdef, shape, data_size=gate["data_size"],
        model_size=gate["model_size"],
        overrides=dict(pp=gate["pp"], dp=gate["data_size"] // gate["pp"],
                       n_chunks=gate["n_chunks"], grad_accum=1,
                       partition="length", offload=True,
                       msp=gate.get("msp", False),
                       offload_moments=opt_gate,
                       opt_dtype=gate.get("opt_dtype", "float32"),
                       offload_dtype=gate.get("offload_dtype", "none"),
                       moments_dtype=gate.get("moments_dtype", "none")),
        doc_lens=doc_lens)
    cell = dataclasses.replace(cell, dtype=DTYPES[gate.get("dtype",
                                                           "bfloat16")])
    led = ml.measure(cell, data_size=gate["data_size"],
                     model_size=gate["model_size"], opt=opt_gate)
    if opt_gate:
        measured = led.combined_peak_bytes
        predicted = ml.predicted_combined_peak(
            cell, data_size=gate["data_size"])
    else:
        measured, predicted = led.peak_bytes, ml.predicted_spmd_peak(cell)
    return measured, predicted, led, cell


def prefetch_ablation_check(gate: dict, cell, led, out_dir: str) -> list:
    """The prefetch='ahead' seam must *pay off* against the autodiff
    placement (DESIGN.md §12): on the same cell with prefetch='sync' the
    measured §5.2 peak may not be lower (ahead never raises the peak — the
    one-slot staging buffer keeps the residual bytes identical), and the
    priced exposed-H2D over the measured bytes/windows must be strictly
    smaller under 'ahead'.  The sync-mode per-tick ledger (with the
    h2d_stall_s column) lands next to the main CSV in the artifact."""
    import dataclasses

    failures = []
    cell_sync = dataclasses.replace(
        cell, plan=dataclasses.replace(cell.plan, prefetch="sync"))
    led_sync = ml.measure(cell_sync, data_size=gate["data_size"],
                          model_size=gate["model_size"], baseline=False)
    led_sync.to_csv(os.path.join(out_dir,
                                 f"memledger-{gate['name']}-syncpf.csv"))
    if led.peak_bytes > led_sync.peak_bytes:
        failures.append(
            f"{gate['name']}: prefetch='ahead' raised the measured peak "
            f"({led.peak_bytes} B vs {led_sync.peak_bytes} B sync) — the "
            "one-slot staging invariant is broken")
    ahead_exp = led.h2d_exposed_s or 0.0
    sync_exp = led_sync.h2d_exposed_s or 0.0
    if sync_exp > 0.0:
        if not ahead_exp < sync_exp:
            failures.append(
                f"{gate['name']}: prefetch='ahead' exposed H2D "
                f"({ahead_exp:.3e}s) is not strictly below 'sync' "
                f"({sync_exp:.3e}s) — the one-chunk-ahead reload is not "
                "hiding under the next backward")
    elif any(r.off_bytes for r in led_sync.ticks):
        failures.append(
            f"{gate['name']}: sync-mode exposure priced 0 despite "
            "deployed off-rows — the h2d channel is broken")
    else:
        # a gate cell whose alphas quantize to zero rows has nothing to
        # ablate; the strict comparison would be vacuously unsatisfiable
        print(f"{gate['name']:32s} prefetch: no off-rows deployed — "
              "ablation vacuous (check the cell's alphas)")
    print(f"{gate['name']:32s} prefetch: exposed h2d "
          f"{ahead_exp:.3e}s ahead vs {sync_exp:.3e}s sync, peak "
          f"{led.peak_bytes} B vs {led_sync.peak_bytes} B")
    return failures


def moment_reduction_check(gate: dict, cell, led) -> list:
    """The executed path must *pay off*: the same cell with
    offload_moments=False has to show a strictly larger measured combined
    device peak, and the offloaded update must honor the
    one-H2D-per-moment-leaf contract."""
    import dataclasses

    failures = []
    cell_off = dataclasses.replace(
        cell, plan=dataclasses.replace(cell.plan, offload_moments=False))
    led_off = ml.measure(cell_off, data_size=gate["data_size"],
                         model_size=gate["model_size"], opt=True,
                         baseline=False)
    if not led.combined_peak_bytes < led_off.combined_peak_bytes:
        failures.append(
            f"{gate['name']}: moment offload did not reduce the measured "
            f"combined device peak ({led.combined_peak_bytes} B offloaded "
            f"vs {led_off.combined_peak_bytes} B resident)")
    mom = led.moments
    if mom is None:
        failures.append(f"{gate['name']}: no moments channel was measured")
    elif mom.mode == "explicit" and mom.host_kind is not None \
            and mom.h2d_count != 2 * mom.n_leaves:
        failures.append(
            f"{gate['name']}: explicit update staged {mom.h2d_count} H2D "
            f"copies for {mom.n_leaves} moment-tree leaves — the "
            "one-H2D-per-moment-leaf contract is broken")
    print(f"{gate['name']:32s} moments: offloaded "
          f"{led.moments.host_bytes if led.moments else 0:>12d} B host, "
          f"combined {led.combined_peak_bytes} B vs resident "
          f"{led_off.combined_peak_bytes} B")
    return failures


def quant_reduction_check(gate: dict, cell, led, out_dir: str) -> list:
    """The compressed channel must *pay off* honestly (DESIGN.md §14): the
    same cell with ``offload_dtype="none"`` — the plan replace preserves
    ``cell.alphas``, so both runs deploy the *identical* row split and the
    comparison isolates the codec's byte effect — has to show strictly
    larger measured host/wire off-bytes and strictly larger priced
    sync-mode exposed-H2D (sync prices every reload in full, making the
    comparison independent of the wall-clock backward windows), while the
    raw device bytes the §5.2 recurrence drains stay identical.  On top of
    the byte contract, the compressed step must still train: one real step
    of each cell from the same init/batch, with the loss drift and the
    relative grad-L2 drift within the gate's pinned tolerances."""
    import dataclasses

    import jax
    import numpy as np

    failures = []
    name, codec = gate["name"], cell.plan.offload_dtype
    cell_raw = dataclasses.replace(
        cell, plan=dataclasses.replace(cell.plan, offload_dtype="none"))
    led_raw = ml.measure(cell_raw, data_size=gate["data_size"],
                         model_size=gate["model_size"], baseline=False)
    led_raw.to_csv(os.path.join(out_dir, f"memledger-{name}-rawoff.csv"))
    comp_wire = led.off_wire_bytes_total
    raw_wire = led_raw.off_wire_bytes_total
    if not comp_wire < raw_wire:
        failures.append(
            f"{name}: codec {codec} did not cut the measured host off-bytes"
            f" ({comp_wire} B compressed vs {raw_wire} B raw)")
    if led.off_bytes_total != led_raw.off_bytes_total:
        failures.append(
            f"{name}: raw device drain diverged under compression "
            f"({led.off_bytes_total} B vs {led_raw.off_bytes_total} B) — "
            "the recurrence subject must be codec-independent")
    if comp_wire and not led.scale_bytes_total > 0:
        failures.append(
            f"{name}: compressed rows deployed but no act_scale bytes were "
            "traced — the per-row scales are not riding the keep set")
    from repro.core import costmodel as _cm

    bw = _cm.V5E.d2h_bw
    comp_exp = led.price_h2d(bw=bw, prefetch="sync")
    raw_exp = led_raw.price_h2d(bw=bw, prefetch="sync")
    if raw_exp > 0.0 and not comp_exp < raw_exp:
        failures.append(
            f"{name}: codec {codec} did not cut the priced sync exposed-H2D"
            f" ({comp_exp:.3e}s vs {raw_exp:.3e}s raw)")
    # one-step numerics drift against the raw-residency step
    mk = dict(data_size=gate["data_size"], model_size=gate["model_size"])
    fn_c, args_c = ml.build_step(cell, with_grad=True, **mk)
    fn_r, args_r = ml.build_step(cell_raw, with_grad=True, **mk)
    loss_c, grads_c = jax.jit(fn_c)(*args_c)
    loss_r, grads_r = jax.jit(fn_r)(*args_r)
    loss_drift = abs(float(loss_c) - float(loss_r)) / max(
        abs(float(loss_r)), 1e-9)
    flat_c = np.concatenate([np.asarray(l, np.float64).ravel()
                             for l in jax.tree_util.tree_leaves(grads_c)])
    flat_r = np.concatenate([np.asarray(l, np.float64).ravel()
                             for l in jax.tree_util.tree_leaves(grads_r)])
    gnorm = float(np.linalg.norm(flat_r))
    grad_drift = float(np.linalg.norm(flat_c - flat_r)) / max(gnorm, 1e-12)
    loss_tol = gate.get("loss_drift_tol", 0.02)
    grad_tol = gate.get("grad_drift_tol", 0.15)
    if loss_drift > loss_tol:
        failures.append(
            f"{name}: codec {codec} loss drift {loss_drift:.3e} exceeds "
            f"the pinned tolerance {loss_tol:.0e}")
    if grad_drift > grad_tol:
        failures.append(
            f"{name}: codec {codec} grad drift {grad_drift:.3e} exceeds "
            f"the pinned tolerance {grad_tol:.0e}")
    print(f"{name:32s} quant: wire {comp_wire} B vs {raw_wire} B raw, "
          f"scales {led.scale_bytes_total} B, sync h2d {comp_exp:.3e}s vs "
          f"{raw_exp:.3e}s, drift loss {loss_drift:.2e} grad "
          f"{grad_drift:.2e}")
    return failures


def run_serve_gate(gate: dict, out_dir: str, update: bool) -> list:
    """Type-0 honesty gate (DESIGN.md §16): serve a seeded trace through
    the continuous-batching engine, measure the paged KV pool's real
    per-rank device bytes, and hold them to the cost model's closed form
    (``costmodel.kv_pool_bytes``) within ``max_ratio`` — plus the budget
    band against the value pinned in budgets.json.  The pool ledger CSV
    (kv_pool_* summary rows) lands in the artifact next to the train
    ledgers."""
    import numpy as np

    from repro.launch import serve as serve_mod
    from repro.launch.mesh import make_test_mesh

    name = gate["name"]
    mesh = make_test_mesh(gate["data_size"], gate["model_size"])
    eng = serve_mod.ServeEngine(
        gate["arch"], mesh, s_bucket=gate["s_bucket"],
        slots=gate["slots"], max_new=gate["max_new"],
        block_tokens=gate["block_tokens"],
        reduced=gate.get("reduced", True))
    rng = np.random.default_rng(gate.get("seed", 0))
    reqs = []
    for i in range(gate.get("n_requests", 5)):
        plen = int(rng.integers(4, gate["s_bucket"] + 1))
        reqs.append(serve_mod.Request(
            rid=i, prompt=rng.integers(
                2, eng.cfg.vocab_size, size=plen).astype(np.int32),
            max_new=int(rng.integers(1, gate["max_new"] + 1)),
            arrival=int(rng.integers(0, 4))))
    _, stats = eng.run(reqs, mode="continuous")

    measured = stats.pool_bytes
    predicted = eng.predicted_pool_bytes()
    led = ml.MemLedger(pool=ml.PoolChannel(
        n_blocks=eng.geo.n_blocks, block_tokens=eng.geo.block_tokens,
        n_layers=eng.mdef.slots_per_stage(1), measured_bytes=measured,
        predicted_bytes=predicted, peak_blocks=max(stats.peak_blocks),
        total_blocks=sum(stats.total_blocks)))
    led.to_csv(os.path.join(out_dir, f"memledger-{name}.csv"))
    ratio = measured / max(predicted, 1)
    print(f"{name:32s} pool     {measured:>12d} B  "
          f"predicted {predicted:>14.0f} B  ratio {ratio:.4f}  "
          f"{stats.steps} steps / {stats.waves} waves, blocks peak "
          f"{max(stats.peak_blocks)} of {eng.geo.n_blocks}")
    failures = []
    if ratio > gate["max_ratio"]:
        failures.append(
            f"{name}: measured pool {measured} B exceeds "
            f"{gate['max_ratio']:.2f}x the cost model's predicted "
            f"{predicted:.0f} B (ratio {ratio:.4f}) — kv_pool_bytes no "
            "longer describes the device arrays")
    if update:
        gate["measured_pool_bytes"] = int(measured)
        gate["predicted_pool_bytes"] = int(predicted)
    else:
        want = gate.get("measured_pool_bytes")
        band = gate.get("band", 0.02)
        if want and abs(measured - want) > band * want:
            failures.append(
                f"{name}: measured pool {measured} B deviates more than "
                f"{band:.0%} from the budgeted {want} B — if intentional, "
                "regenerate with `python -m benchmarks.memgate --update`")
    return failures


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--budgets", default="benchmarks/budgets.json")
    ap.add_argument("--out", default="memledger")
    ap.add_argument("--update", action="store_true",
                    help="rewrite budgets.json with the measured numbers")
    args = ap.parse_args(argv)

    with open(args.budgets) as f:
        budgets = json.load(f)
    os.makedirs(args.out, exist_ok=True)

    failures = []
    for gate in budgets["gates"]:
        name = gate["name"]
        if gate.get("kind") == "serve":
            failures.extend(run_serve_gate(gate, args.out, args.update))
            continue
        measured, predicted, led, cell = run_gate(gate)
        led.to_csv(os.path.join(args.out, f"memledger-{name}.csv"))
        ratio = measured / max(predicted, 1)
        exposed = led.exposed_transfer_s
        print(f"{name:32s} measured {measured:>12d} B  "
              f"predicted {predicted:>14.0f} B  ratio {ratio:.4f}  "
              f"step {led.step_time_s:.3f}s  exposed "
              f"{0.0 if exposed is None else exposed:.3f}s")
        if not led.runtime_coverage_ok():
            failures.append(f"{name}: runtime probes missed ticks or the "
                            "update phase (the step did not fully execute)")
        if gate.get("offload_moments"):
            failures.extend(moment_reduction_check(gate, cell, led))
        elif gate.get("offload_dtype", "none") != "none":
            # compression ablation on the compressed-residency cells (§14)
            failures.extend(quant_reduction_check(gate, cell, led,
                                                  args.out))
        else:
            # prefetch ablation on the plain activation cells (§12)
            failures.extend(prefetch_ablation_check(gate, cell, led,
                                                    args.out))
        if ratio > gate["max_ratio"]:
            failures.append(
                f"{name}: measured peak {measured} B exceeds "
                f"{gate['max_ratio']:.2f}x the simulator's predicted "
                f"{predicted:.0f} B (ratio {ratio:.4f}) — the §5.2 "
                "recurrence no longer describes the executed program")
        if args.update:
            gate["measured_peak_bytes"] = int(measured)
            gate["predicted_peak_bytes"] = int(predicted)
        else:
            want = gate.get("measured_peak_bytes")
            band = gate.get("band", 0.02)
            if want and abs(measured - want) > band * want:
                failures.append(
                    f"{name}: measured peak {measured} B deviates more "
                    f"than {band:.0%} from the budgeted {want} B — if "
                    "intentional, regenerate with "
                    "`python -m benchmarks.memgate --update`")

    if args.update:
        with open(args.budgets, "w") as f:
            json.dump(budgets, f, indent=2)
            f.write("\n")
        print(f"updated {args.budgets}")
    if failures:
        print("\nMEMORY GATE FAILED:", file=sys.stderr)
        for msg in failures:
            print(f"  - {msg}", file=sys.stderr)
        return 1
    print("memory gate OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
