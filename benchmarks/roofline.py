"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh).

Reads the dry-run artifacts (launch/dryrun.py JSON) and computes, per cell:

  compute term    = dot_FLOPs(trip-corrected) / peak_FLOPs
  memory term     = HBM bytes / hbm_bw         (dot-tensor traffic proxy;
                    module-level `bytes accessed` is scan-undercounted and
                    reported alongside for reference)
  collective term = collective bytes / link_bw

All quantities are per-chip (the compiled HLO is the per-device program, so
its totals already divide by the mesh).  MODEL_FLOPS = 6·N_active·D (train)
or 2·N_active·D (inference) gives the useful-compute ratio.
"""
from __future__ import annotations

import json
import sys
from typing import Optional

from repro.configs.base import SHAPES
from repro.core import costmodel as cm
from repro.models.model_zoo import build_model
from repro.parallel import specs as SP


def model_flops_per_device(arch: str, shape_name: str, plan: dict,
                           pods: int = 1) -> float:
    shape = SHAPES[shape_name]
    mdef = build_model(arch)
    data = plan["pp"] * plan["dp"]
    n_active = SP.count_active_params(mdef, plan["pp"], data)
    chips = data * plan["sp"] * pods
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens / chips
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens / chips
    tokens = shape.global_batch  # one token per sequence
    return 2.0 * n_active * tokens / chips


def analyze_record(rec: dict, hw: cm.Hardware = cm.V5E,
                   pods: int = 1) -> Optional[dict]:
    if rec.get("status") != "ok":
        return None
    comp = rec["dot_flops"] / hw.peak_flops_bf16
    memt = rec["dot_bytes"] / hw.hbm_bw
    # collective bytes from the jaxpr walker (dtype-faithful, scan-exact)
    coll = rec["collective_bytes"] / hw.ici_bw
    terms = {"compute": comp, "memory": memt, "collective": coll}
    dom = max(terms, key=terms.get)
    mf = model_flops_per_device(rec["arch"], rec["shape"], rec["plan"], pods)
    bound = max(terms.values())
    out = dict(rec)
    out.update({
        "compute_s": comp, "memory_s": memt, "collective_s": coll,
        "dominant": dom,
        "model_flops": mf,
        "useful_ratio": mf / max(rec["dot_flops"], 1.0),
        # fraction of roofline: useful work time / bound time
        "roofline_frac": (mf / hw.peak_flops_bf16) / max(bound, 1e-12),
    })
    return out


MOVE_HINTS = {
    "compute": "cut redundant FLOPs: pipeline garbage ticks, attention "
               "over-read (kv_view), remat recompute, loss on all stages",
    "memory": "fuse/bf16-ify big intermediates; larger matmul tiles",
    "collective": "bf16 softmax-merge + grad reduce-scatters; merge-then-"
                  "scatter attention; overlap weight gathers with compute",
}


def report(path: str, hw: cm.Hardware = cm.V5E, pods: int = 1) -> str:
    recs = json.load(open(path))
    lines = [
        "| arch | shape | mesh | pp×dp×sp | compute s | memory s | "
        "collective s | dominant | useful | roofline |",
        "|---|---|---|---|---|---|---|---|---|---|".replace("|---|---|---|---|---|---|---|---|---|---|",
            "|---|---|---|---|---|---|---|---|---|---|"),
    ]
    rows = []
    for rec in recs:
        if rec.get("status") == "skipped":
            lines.append(f"| {rec['arch']} | {rec['shape']} | - | - | - | - "
                         f"| - | skipped: {rec['reason'][:40]} | - | - |")
            continue
        rec_pods = (2 if rec.get("mesh", "").startswith("2x") else 1)
        a = analyze_record(rec, hw, rec_pods)
        if a is None:
            lines.append(f"| {rec['arch']} | {rec['shape']} | - | FAILED "
                         f"| - | - | - | - | - | - |")
            continue
        p = a["plan"]
        lines.append(
            f"| {a['arch']} | {a['shape']} | {a['mesh']} "
            f"| {p['pp']}x{p['dp']}x{p['sp']} "
            f"| {a['compute_s']:.3f} | {a['memory_s']:.3f} "
            f"| {a['collective_s']:.3f} | {a['dominant']} "
            f"| {a['useful_ratio']:.2f} | {a['roofline_frac']:.3f} |")
        rows.append(a)
    return "\n".join(lines), rows


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_single_pod.json"
    table, rows = report(path)
    print(table)
    if rows:
        worst = min(rows, key=lambda r: r["roofline_frac"])
        collb = max(rows, key=lambda r: r["collective_s"]
                    / max(r["compute_s"], 1e-12))
        print(f"\nworst roofline fraction: {worst['arch']} x {worst['shape']}"
              f" ({worst['roofline_frac']:.3f})")
        print(f"most collective-bound: {collb['arch']} x {collb['shape']}")
        for r in rows[:1]:
            pass
        print("\nper-bottleneck hints:")
        for k, v in MOVE_HINTS.items():
            print(f"  {k}: {v}")


if __name__ == "__main__":
    main()
