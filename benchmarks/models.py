"""Analytic performance models shared by the paper-figure benchmarks.

All models work from first principles over (flops, bytes, bandwidths) with
the hardware constants in core/costmodel.py.  A100 constants reproduce the
paper's own cluster (Figs. 10-12 comparisons); v5e constants give the TPU
projection used in §Roofline.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.core import costmodel as cm
from repro.core import offload as ofl
from repro.core import partition as part
from repro.core.schedule import msp_total_time, total_time


@dataclass(frozen=True)
class Workload:
    name: str
    n_params: int           # non-embedding
    n_layers: int
    d_model: int
    seq_len: int
    batch: int = 1
    sp: int = 8
    pp: int = 4


def act_bytes_per_token(w: Workload, dtype_bytes=2) -> float:
    """Type-1 (offloadable) activation bytes per token per device."""
    return 34 * w.d_model * dtype_bytes * (w.n_layers / w.pp) / w.sp


def kv_bytes_per_token(w: Workload, dtype_bytes=2) -> float:
    """Type-0 skeletal KV bytes per token per device (2BSH per layer)."""
    return 2 * w.d_model * dtype_bytes * (w.n_layers / w.pp) / w.sp


def compute_time(w: Workload, hw: cm.Hardware, *, recompute_frac=0.0) -> float:
    """Ideal fwd+bwd wall time on sp*pp chips, +recompute overhead."""
    flops = 6 * w.n_params * w.batch * w.seq_len
    # causal attention term
    flops += 2 * 12 * w.n_layers * w.d_model * w.batch * w.seq_len ** 2 / 2 \
        / w.d_model  # 4*H*hd*S^2/2 * 3(fwd+bwd) ~ folded approximation
    chips = w.sp * w.pp
    return flops * (1 + recompute_frac) / (chips * hw.peak_flops_bf16)


def sppo_iter_time(w: Workload, hw: cm.Hardware, n_chunks: int, *,
                   msp=False, adaptive=True, cfg=None) -> Dict:
    """SPPO iteration model: chunked pipeline + sequence-aware offload."""
    r = 4.0 / 12.0 / w.d_model * w.seq_len  # attn/lin per-token cost ratio
    sched = part.partition_flops(w.seq_len, n_chunks, max(r, 1e-9),
                                 multiple=1) if n_chunks > 1 else \
        part.partition_length(w.seq_len, n_chunks)
    costs = part.chunk_costs(sched, max(r, 1e-9))
    f_total = compute_time(w, hw)
    times = [f_total * c / sum(costs) for c in costs]
    acts = [act_bytes_per_token(w) * l * w.batch for l in sched.lengths]
    if adaptive:
        plan = ofl.sequence_aware_alphas(acts, times, hw.d2h_bw)
        alphas = plan.alphas
    else:
        alphas = ofl.fixed_full_alphas(n_chunks)
    # unhidden transfer time (fixed-full offload stalls; adaptive hides)
    stall = 0.0
    for i, (a, al) in enumerate(zip(acts, alphas)):
        window = times[i + 1] if i + 1 < len(times) else 0.0
        stall += max(0.0, al * a / hw.d2h_bw - window)
    f_n = sum(times) + 2 * n_chunks * w.n_layers / w.pp \
        * hw.kernel_launch_us * 1e-6
    t = (msp_total_time(w.pp, n_chunks, f_n) if msp
         else total_time(w.pp, n_chunks, f_n))
    t = t + stall
    peak = ofl.peak_memory(acts, alphas) + kv_bytes_per_token(w) \
        * w.seq_len * w.batch
    return {"time": t, "alphas": alphas, "stall": stall, "peak_act": peak,
            "tgs": w.batch * w.seq_len / t / (w.sp * w.pp)}


def megatron_iter_time(w: Workload, hw: cm.Hardware, *, microbatches=1) -> Dict:
    """Megatron-ish baseline: full recompute (the paper's +1/3), 1F1B over
    microbatches (collapses to naive PP at long sequence: M=1)."""
    f = compute_time(w, hw, recompute_frac=1.0 / 3.0)
    m = microbatches
    t = (m + w.pp - 1) / m * f
    peak = act_bytes_per_token(w) * w.seq_len * w.batch / w.n_layers * 2 \
        + kv_bytes_per_token(w) * w.seq_len * w.batch  # boundary acts only
    return {"time": t, "tgs": w.batch * w.seq_len / t / (w.sp * w.pp),
            "peak_act": peak}


def ds_ulysses_iter_time(w: Workload, hw: cm.Hardware, n_heads: int) -> Dict:
    """DeepSpeed-Ulysses baseline: head-limited SP (sp <= heads), full
    activations resident w/ full offload of everything (FPDT-strengthened),
    charged for unhidden transfer."""
    sp_eff = min(w.sp * w.pp, n_heads)
    flops = 6 * w.n_params * w.batch * w.seq_len
    f = flops / (sp_eff * hw.peak_flops_bf16)
    act = 34 * w.d_model * 2 * w.n_layers / sp_eff * w.seq_len * w.batch
    stall = max(0.0, act / hw.d2h_bw - f)
    t = f + stall
    return {"time": t, "tgs": w.batch * w.seq_len / t / (w.sp * w.pp),
            "sp_eff": sp_eff}


def max_seq_len(w: Workload, hw: cm.Hardware, *, mode: str,
                n_heads: int = 32) -> int:
    """Fig. 12 model: largest S fitting device memory."""
    budget = hw.hbm_bytes * 0.8 - 3 * w.n_params * 2 / (w.sp * w.pp)
    if budget <= 0:
        return 0
    per_tok_kv = kv_bytes_per_token(w)
    per_tok_act = act_bytes_per_token(w)
    if mode == "sppo":
        # activations offloadable up to host budget; device keeps KV + the
        # working chunk (~1/16 of sequence)
        denom = per_tok_kv + per_tok_act / 16
    elif mode == "megatron":
        # full recompute: keep layer-boundary activations (2 of 34) + KV
        denom = per_tok_kv + per_tok_act * 2 / 34
    else:  # ulysses
        sp_eff = min(w.sp * w.pp, n_heads)
        denom = (2 * w.d_model * 2 * w.n_layers + 34 * w.d_model * 2) / sp_eff
    return int(budget / denom / w.batch)
