"""Served-traffic benchmark: continuous batching vs static lock-step
(DESIGN.md §16).

One seeded request trace — Poisson arrivals, a short/long decode-length
mixture (the bimodal shape real serving traffic has) — is decoded twice
through the *same* paged-pool engine (``launch/serve.ServeEngine``):

  * ``static``      — admission barriered on an empty pool: a wave of K
    requests locks until the longest one finishes (the lock-step baseline
    the static serve path implements);
  * ``continuous``  — admission into freed slots mid-flight whenever
    ``admit_min_free`` slots are open.

Both modes run the identical per-step function, so the wall-clock ratio
isolates the scheduler; per-request token streams are bitwise identical
across modes (asserted — the per-row compute does not depend on
co-residents), so the comparison is throughput-only by construction.

Gates (all three must hold):
  1. continuous requests/s >= ``--factor`` x static (default 1.5; the
     margin is structural: a lock-step wave pays max(len) for every
     member, continuous back-fills freed slots);
  2. per-request tokens identical across modes;
  3. measured pool device bytes within 1.1x the cost model's
     ``kv_pool_bytes`` prediction.

Latency methodology: the decode loop never syncs the host (that is the
point), so per-step wall times are not individually observable without
perturbing the pipeline.  Request latency is measured in scheduler steps
(finish step - arrival step) and scaled by the run's average step time
(wall / steps) — an average-cost approximation, stated as such in the CSV.

``--fast`` replays the scheduler host-side only (no device work, no jit)
and gates on the step-count ratio; the mode ``benchmarks.run`` registers.

  PYTHONPATH=src python -m benchmarks.bench_serving \
      [--fast] [--factor 1.5] [--csv serving.csv]
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import Dict, List, Tuple

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np

ARCH = "qwen2-7b"
S_BUCKET = 64
SLOTS = 4                 # request slots (single data shard)
MAX_NEW_CAP = 48
BLOCK_TOKENS = 8
ADMIT_MIN_FREE = 1
N_REQUESTS = 16
N_LONG = 4                # long decodes in the mixture
LEN_SHORT, LEN_LONG = 4, 48
ARRIVAL_RATE = 1.0        # Poisson arrivals per scheduler step
SEED = 1
DEFAULT_FACTOR = 1.5
POOL_RATIO_MAX = 1.1


def make_trace(seed: int = SEED, vocab: int = 256):
    """Seeded Poisson-arrival trace with a bimodal decode-length mixture."""
    rng = np.random.default_rng(seed)
    lens = np.array([LEN_LONG] * N_LONG
                    + [LEN_SHORT] * (N_REQUESTS - N_LONG))
    rng.shuffle(lens)
    gaps = rng.exponential(1.0 / ARRIVAL_RATE, size=N_REQUESTS)
    arrivals = np.floor(np.cumsum(gaps)).astype(int)
    out = []
    for i in range(N_REQUESTS):
        plen = int(rng.integers(8, S_BUCKET + 1))
        out.append(dict(rid=i, prompt=rng.integers(
            2, vocab, size=plen).astype(np.int32),
            max_new=int(lens[i]), arrival=int(arrivals[i])))
    return out


def simulate_steps(trace, mode: str,
                   admit_min_free: int = ADMIT_MIN_FREE,
                   slots: int = SLOTS) -> Tuple[int, int, Dict[int, int]]:
    """Host-only replay of the ServeEngine admission rules: returns
    (decode_steps, admission_waves, {rid: finish_step - arrival})."""
    queue = sorted(trace, key=lambda r: (r["arrival"], r["rid"]))
    active: Dict[int, int] = {}   # slot -> steps left
    rids: Dict[int, int] = {}
    lat: Dict[int, int] = {}
    steps = waves = t = qi = 0
    while qi < len(queue) or active:
        if qi < len(queue) and not active and queue[qi]["arrival"] > t:
            t = queue[qi]["arrival"]
        free = [k for k in range(slots) if k not in active]
        n_avail = 0
        while qi + n_avail < len(queue) \
                and queue[qi + n_avail]["arrival"] <= t:
            n_avail += 1
        gate = (not active) if mode == "static" else (
            not active or len(free) >= admit_min_free)
        if n_avail and free and gate:
            for k in free[:n_avail]:
                active[k] = queue[qi]["max_new"]
                rids[k] = queue[qi]["rid"]
                qi += 1
            waves += 1
        steps += 1
        for k in list(active):
            active[k] -= 1
            if active[k] == 0:
                r = next(x for x in trace if x["rid"] == rids[k])
                lat[rids[k]] = t + 1 - r["arrival"]
                del active[k]
        t += 1
    return steps, waves, lat


def bench_serving(measure: bool = True, factor: float = DEFAULT_FACTOR,
                  csv_path: str | None = None) -> Tuple[List, str, bool]:
    """Returns (csv_rows, text, gate_ok)."""
    results = {}
    tokens = {}
    pool_ok = True
    pool_line = ""
    if measure:
        import jax  # noqa: F401  (device path only in measured mode)

        from repro.launch.mesh import make_test_mesh
        from repro.launch.serve import Request, ServeEngine

        mesh = make_test_mesh(1, 1)
        eng = ServeEngine(ARCH, mesh, s_bucket=S_BUCKET, slots=SLOTS,
                          max_new=MAX_NEW_CAP, block_tokens=BLOCK_TOKENS,
                          admit_min_free=ADMIT_MIN_FREE, reduced=True)
        trace = make_trace(vocab=eng.cfg.vocab_size)
        reqs = [Request(**r) for r in trace]
        # warmup: compile prefill/ingest/step on a one-request trace
        eng.run([Request(rid=-1, prompt=reqs[0].prompt, max_new=2)],
                mode="static")
        for mode in ("static", "continuous"):
            toks, stats = eng.run(reqs, mode=mode)
            results[mode] = stats
            tokens[mode] = toks
        predicted = eng.predicted_pool_bytes()
        measured_pool = results["continuous"].pool_bytes
        pool_ratio = measured_pool / max(predicted, 1)
        pool_ok = pool_ratio <= POOL_RATIO_MAX
        pool_line = (f"pool: measured {measured_pool} B vs predicted "
                     f"{predicted} B (ratio {pool_ratio:.4f}, gate <= "
                     f"{POOL_RATIO_MAX:.2f}x -> "
                     f"{'OK' if pool_ok else 'FAIL'})")
    else:
        trace = make_trace()

    sim = {m: simulate_steps(trace, m) for m in ("static", "continuous")}
    ratio_steps = sim["static"][0] / sim["continuous"][0]

    tokens_ok = True
    if measure:
        tokens_ok = all(
            (tokens["static"][r["rid"]]
             == tokens["continuous"][r["rid"]]).all() for r in trace)
        rps = {m: len(trace) / results[m].wall_s
               for m in ("static", "continuous")}
        ratio = rps["continuous"] / rps["static"]
    else:
        ratio = ratio_steps
    ok = (ratio >= factor) and tokens_ok and pool_ok

    lines = [f"== Continuous batching vs static lock-step ({ARCH} reduced, "
             f"bucket {S_BUCKET}, {SLOTS} slots, {N_REQUESTS} reqs: "
             f"{N_REQUESTS - N_LONG}x{LEN_SHORT} + {N_LONG}x{LEN_LONG} "
             "tokens, Poisson arrivals) =="]
    csv_rows = []
    lat_rows = {}
    for mode in ("static", "continuous"):
        steps, waves, lat = sim[mode]
        lvals = np.array(sorted(lat.values()))
        p50 = float(np.percentile(lvals, 50))
        p99 = float(np.percentile(lvals, 99))
        if measure:
            st = results[mode]
            step_s = st.wall_s / max(st.steps, 1)
            lat_rows[mode] = (st.steps, st.waves, p50 * step_s,
                              p99 * step_s)
            lines.append(
                f"{mode:10s} {st.steps:4d} steps / {st.waves} waves  "
                f"wall {st.wall_s:7.2f}s  {len(trace) / st.wall_s:6.2f} "
                f"req/s  token-latency p50 {p50 * step_s:6.2f}s "
                f"p99 {p99 * step_s:6.2f}s (avg-step scaled)")
            csv_rows.append((f"serving_{mode}",
                             f"{st.wall_s * 1e6 / max(st.steps, 1):.0f}",
                             f"{steps}"))
        else:
            lat_rows[mode] = (steps, waves, p50, p99)
            lines.append(
                f"{mode:10s} {steps:4d} steps / {waves} waves (simulated)  "
                f"latency p50 {p50:.0f} p99 {p99:.0f} steps")
            csv_rows.append((f"serving_{mode}", "", f"{steps}"))
    lines.append(
        "speedup continuous/static: "
        + (f"{ratio:.2f}x requests/s measured, " if measure else "")
        + f"{ratio_steps:.2f}x scheduler steps "
        f"(gate: >= {factor:.2f}x -> {'OK' if ratio >= factor else 'FAIL'})")
    if measure:
        lines.append("token equality across modes: "
                     + ("OK" if tokens_ok else "FAIL"))
        lines.append(pool_line)
    csv_rows.append(("serving_speedup",
                     f"{ratio:.3f}" if measure else "",
                     f"{ratio_steps:.3f}"))

    if csv_path:
        import csv as _csv

        with open(csv_path, "w", newline="") as f:
            w = _csv.writer(f)
            w.writerow(["mode", "steps", "waves", "wall_s", "req_per_s",
                        "lat_p50_s", "lat_p99_s"])
            for mode in ("static", "continuous"):
                steps, waves, p50, p99 = lat_rows[mode]
                if measure:
                    st = results[mode]
                    w.writerow([mode, st.steps, st.waves,
                                f"{st.wall_s:.4f}",
                                f"{len(trace) / st.wall_s:.4f}",
                                f"{p50:.4f}", f"{p99:.4f}"])
                else:
                    w.writerow([mode, steps, waves, "", "",
                                f"{p50:.1f}", f"{p99:.1f}"])
            w.writerow([])
            w.writerow(["speedup_measured", f"{ratio:.4f}" if measure
                        else ""])
            w.writerow(["speedup_steps", f"{ratio_steps:.4f}"])
            w.writerow(["factor", f"{factor:.2f}"])
            w.writerow(["tokens_identical", int(tokens_ok)])
            w.writerow(["pool_gate_ok", int(pool_ok)])
            w.writerow(["gate_ok", int(ok)])
            w.writerow(["latency_note",
                        "p50/p99 scaled by avg step time (wall/steps); "
                        "per-step sync would perturb the pipeline"])
    return csv_rows, "\n".join(lines), ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="host-side scheduler replay only (no device work)")
    ap.add_argument("--factor", type=float, default=DEFAULT_FACTOR)
    ap.add_argument("--csv", default=None)
    args = ap.parse_args(argv)
    rows, text, ok = bench_serving(measure=not args.fast,
                                   factor=args.factor, csv_path=args.csv)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us},{derived}")
    print()
    print(text)
    if not ok:
        print("\nSERVING GATE FAILED: continuous batching did not clear "
              f"the pinned {args.factor:.2f}x margin (or token/pool gates "
              "tripped)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
