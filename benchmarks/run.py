"""Benchmark harness entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call is a real CPU
measurement where one exists; derived carries the analytic value) followed
by the human-readable tables, and — when a dry-run artifact is present —
the roofline table (§Roofline inputs).

  PYTHONPATH=src python -m benchmarks.run [--fast]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import figures  # noqa: E402
from benchmarks.bench_attention import bench_attention  # noqa: E402
from benchmarks.bench_offload_quant import bench_offload_quant  # noqa: E402
from benchmarks.bench_serving import bench_serving  # noqa: E402
from benchmarks.bench_varlen import bench_varlen  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip the CPU micro-measurements")
    args, _ = ap.parse_known_args()

    benches = [
        ("bench_partition", figures.bench_partition),
        ("bench_offload", figures.bench_offload),
        ("bench_pipeline",
         lambda: figures.bench_pipeline(measure=not args.fast)),
        ("bench_e2e", figures.bench_e2e),
        ("bench_breakdown", figures.bench_breakdown),
        ("bench_seqscale", figures.bench_seqscale),
        ("bench_schedule_sim",
         lambda: figures.bench_schedule_sim(measure=not args.fast)),
        ("bench_solver", figures.bench_solver),
        ("bench_attention",
         lambda: bench_attention(measure=not args.fast, fast=args.fast)),
        ("bench_varlen",
         lambda: bench_varlen(measure=not args.fast)[:2]),
        ("bench_offload_quant",
         lambda: bench_offload_quant(measure=not args.fast)),
        ("bench_serving",
         lambda: bench_serving(measure=not args.fast)[:2]),
    ]
    all_rows = []
    texts = []
    for name, fn in benches:
        rows, text = fn()
        all_rows.extend(rows)
        texts.append(text)

    print("name,us_per_call,derived")
    for name, us, derived in all_rows:
        print(f"{name},{us},{derived}")
    print()
    for t in texts:
        print(t)
        print()

    for artifact in ("dryrun_single_pod.json", "dryrun_multi_pod.json"):
        if os.path.exists(artifact):
            from benchmarks import roofline
            table, rows = roofline.report(artifact)
            print(f"== Roofline ({artifact}) ==")
            print(table)
            print()


if __name__ == "__main__":
    main()
