"""Ring-attention smoke + the 4M-token admission gate (DESIGN.md §15).

Two halves, one artifact:

  * executed smoke — one real train step (loss + grads through the SPPO
    chunk loop) on the emulated (1, 2) mesh, attn_mode="ring" vs the
    "gather_kv" baseline.  Both are collectives over the same shards, so
    the step times should be the same order; the row exists to catch a
    ring schedule that traces into something pathological, not to race
    two CPU emulations.
  * priced artifact — THE acceptance gate: the simulated 4M-token
    qwen2-7b cell (batch=1, pp=4, sp=16) must be *rejected* by the
    per-stage memory model at attn_mode="local" (full visible KV per
    device) and *admitted* at "ring" (one resident shard + two in-flight
    blocks), and the solver's chooser must pick ring.  The per-hop CSV
    rows come from ``simulate.ring_overlap`` on that cell's last (widest)
    chunk: per hop the zig-zag compute fraction, KV bytes on the wire,
    transfer/compute spans, and the exposed (unhidden) time.

  PYTHONPATH=src python -m benchmarks.bench_ring [--fast] [--csv ring.csv]
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from typing import List, Tuple

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax

from repro.configs.base import ShapeConfig, get_config
from repro.core import costmodel as cm
from repro.core import simulate as sim
from repro.core import solver
from repro.models.model_zoo import build_model
from repro.parallel.runner import resolve_cell

ARCH = "qwen2-7b"
SEQ_LEN = 256
BATCH = 4
# the acceptance cell: 4M tokens on a 16-way ring, 4 stages
BIG_SEQ = 4 * 2 ** 20
BIG_N_PARAMS = 7_600_000_000
BIG_PP, BIG_N, BIG_SP = 4, 32, 16


def _dist_step_time(mdef, attn_mode: str, reps: int = 3) -> float:
    """Best-of-N wall time of one jitted dist loss+grad step on (1, 2).

    Uses the memledger step scaffold — the same shard_map'd program the
    honesty tests and the memory gate execute — so the timed step is the
    real pipeline, grads included."""
    from repro.runtime import memledger as ml

    cell = resolve_cell(mdef,
                        ShapeConfig(f"ring-bench-{attn_mode}", SEQ_LEN,
                                    BATCH, "train"),
                        data_size=1, model_size=2,
                        overrides=dict(n_chunks=2, grad_accum=1,
                                       partition="length",
                                       attn_mode=attn_mode))
    fn, args = ml.build_step(cell, data_size=1, model_size=2)
    step = jax.jit(fn)
    jax.block_until_ready(step(*args))  # compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(step(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _big_cell_hops(cfg, hw=cm.V5E):
    """Per-hop (frac, bytes, xfer_s, comp_s, start, end, exposed) rows for
    the widest chunk of the acceptance cell, forward pass."""
    fracs = cm.ring_hop_fractions(BIG_SP, causal=True, layout="zigzag")
    kv_end = BIG_SEQ  # last chunk sees the full context
    ln = BIG_SEQ // BIG_N
    hop_bytes = cm.ring_hop_bytes(cfg, kv_end / BIG_SP, 1)
    xfer = [0.0] + [hop_bytes / hw.ici_bw] * (BIG_SP - 1)
    hop_flops = (4.0 * 1 * (ln / BIG_SP) * (kv_end / BIG_SP)
                 * cfg.n_heads * cfg.head_dim)
    comp = [f * hop_flops / hw.peak_flops_bf16 for f in fracs]
    _, _, events = sim.ring_overlap(comp, xfer)
    spans = {h: (s, e) for kind, h, s, e in events if kind == "compute"}
    rows = []
    prev_end = 0.0
    for h in range(BIG_SP):
        start, end = spans[h]
        exposed = max(0.0, start - prev_end)
        rows.append((h, fracs[h], hop_bytes if h else 0.0, xfer[h],
                     comp[h], start, end, exposed))
        prev_end = end
    return rows


def bench_ring(measure: bool = True,
               csv_path: str | None = None) -> Tuple[List, str, bool]:
    """Returns (csv_rows, text, gate_ok)."""
    cfg_big = get_config(ARCH)
    times = {}
    if measure:
        mdef = build_model(get_config(ARCH).reduced())
        for mode in ("ring", "gather_kv"):
            times[mode] = _dist_step_time(mdef, mode)

    adm = solver.admit_attn_mode(cfg_big, BIG_SEQ, 1, BIG_N_PARAMS,
                                 pp=BIG_PP, sp=BIG_SP)
    chosen, report = solver.choose_attn_mode(cfg_big, BIG_SEQ, 1,
                                             BIG_N_PARAMS, pp=BIG_PP,
                                             n=BIG_N, sp=BIG_SP,
                                             modes=("local", "ring"))
    ok = (not adm["local"][0]) and adm["ring"][0] and chosen == "ring"
    hops = _big_cell_hops(cfg_big)

    csv_rows = []
    lines = [f"== Ring-distributed attention ({ARCH}) =="]
    if measure:
        for mode in ("ring", "gather_kv"):
            t = times[mode]
            csv_rows.append((f"ring_step_{mode}", f"{t * 1e6:.0f}", ""))
            lines.append(f"executed step ({mode:9s}, reduced, (1,2) mesh): "
                         f"{t * 1e3:8.1f} ms")
        lines.append(f"ring/gather_kv ratio: "
                     f"{times['ring'] / times['gather_kv']:.2f}x "
                     "(informational — same collectives family)")
    gib = 2 ** 30
    for mode, (fits, d) in adm.items():
        lines.append(f"4M cell demand [{mode:9s}]: "
                     f"{d['total'] / gib:7.2f} GiB vs "
                     f"{cm.V5E.hbm_bytes / gib:.0f} GiB HBM -> "
                     f"{'admit' if fits else 'REJECT'}")
        csv_rows.append((f"ring_admit_{mode}", "",
                         f"{d['total'] / gib:.2f}"))
    lines.append(f"chooser picked: {chosen} "
                 f"(est {report['ring']['est_time']:.1f} s/iter)")
    lines.append(f"gate (local rejected, ring admitted, ring chosen): "
                 f"{'OK' if ok else 'FAIL'}")

    if csv_path:
        import csv as _csv

        with open(csv_path, "w", newline="") as f:
            w = _csv.writer(f)
            w.writerow(["section", "name", "value"])
            for mode, t in times.items():
                w.writerow(["step", mode, f"{t:.6f}"])
            for mode, (fits, d) in adm.items():
                w.writerow(["admit", mode, int(fits)])
                w.writerow(["demand_bytes", mode, int(d["total"])])
            w.writerow(["chosen", chosen, ""])
            w.writerow([])
            w.writerow(["hop", "frac", "wire_bytes", "xfer_s", "comp_s",
                        "comp_start_s", "comp_end_s", "exposed_s"])
            for h, frac, nbytes, xf, cp, s0, s1, exp in hops:
                w.writerow([h, f"{frac:.4f}", int(nbytes), f"{xf:.6f}",
                            f"{cp:.6f}", f"{s0:.6f}", f"{s1:.6f}",
                            f"{exp:.6f}"])
            w.writerow([])
            w.writerow(["gate_ok", int(ok), ""])
    return csv_rows, "\n".join(lines), ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip the executed step timing; gate on the "
                         "priced admission artifact only")
    ap.add_argument("--csv", default=None)
    args = ap.parse_args(argv)
    rows, text, ok = bench_ring(measure=not args.fast, csv_path=args.csv)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us},{derived}")
    print()
    print(text)
    if not ok:
        print("\nRING GATE FAILED: the 4M-token cell admission artifact "
              "does not hold (expected: local rejected, ring admitted, "
              "ring chosen)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
