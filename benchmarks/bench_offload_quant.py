"""Compressed offload-channel report (DESIGN.md §14).

Two views of the bf16 -> fp8/int8 + per-row-scale codec behind
``ParallelPlan.offload_dtype``:

  * analytic — per-chunk host/wire bytes of the reduced gate cell under
    each codec: raw off rows vs 1-byte payload + fp32 scales (the scales
    stay device-resident, so the wire column excludes them but the table
    reports them), plus the codec's effective-bandwidth ratio the alpha
    solver plans with;
  * measured — codec kernel round-trip error on representative activation
    rows (including the degenerate all-zero row), quantize/dequantize wall
    time per row block, and the one-step pp=1 loss drift of a compressed
    cell against the same cell with raw residency.

  PYTHONPATH=src python -m benchmarks.bench_offload_quant [--fast]
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from typing import List, Tuple

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.base import ShapeConfig, get_config
from repro.core import costmodel as cm
from repro.core import offload as ofl
from repro.runtime import hostmem

ARCH = "sppo-gpt-7b"
SEQ_LEN = 256
BATCH = 4
N_CHUNKS = 4


def _codec_error(codec: str, key) -> float:
    """Max relative row error of the round trip on unit-scale rows."""
    x = jax.random.normal(key, (64, 128), jnp.float32).astype(jnp.bfloat16)
    p, s = hostmem.quantize(x, codec)
    y = hostmem.dequantize(p, s, codec, x.dtype)
    num = jnp.max(jnp.abs(x.astype(jnp.float32) - y.astype(jnp.float32)),
                  axis=-1)
    den = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    return float(jnp.max(num / jnp.maximum(den, 1e-9)))


def _codec_time(codec: str, key, reps: int = 5) -> float:
    x = jax.random.normal(key, (256, 1024), jnp.bfloat16)

    def rt(t):
        p, s = hostmem.quantize(t, codec)
        return hostmem.dequantize(p, s, codec, t.dtype)

    f = jax.jit(rt)
    jax.block_until_ready(f(x))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(f(x))
        best = min(best, time.perf_counter() - t0)
    return best


def _step_drift(codec: str) -> Tuple[float, float]:
    """One pp=1 step: (loss drift, relative grad-L2 drift) of the
    compressed cell against the same cell with raw residency.  Under the
    default prefetch='ahead' seam the capture forward is an identity, so
    the loss drift is exactly 0 and the codec resolution shows up only in
    the backward replay's gradients."""
    import dataclasses

    import numpy as np

    from repro.models.model_zoo import build_model
    from repro.parallel.ctx import SINGLE
    from repro.parallel.runner import resolve_cell, run_pipeline

    cfg = get_config(ARCH).reduced()
    mdef = build_model(cfg)
    shape = ShapeConfig(f"quant-{codec}", SEQ_LEN, BATCH, "train")
    cell = resolve_cell(mdef, shape, data_size=1, model_size=1,
                        overrides=dict(n_chunks=N_CHUNKS, grad_accum=1,
                                       offload=True, offload_dtype=codec))
    key = jax.random.PRNGKey(0)
    sp1 = mdef.init_stage_params(key, 0, 1, cell.dtype)
    g1 = mdef.init_globals(key, cell.dtype)
    tok = jax.random.randint(key, (BATCH, SEQ_LEN), 0, cfg.vocab_size)
    lab = jnp.roll(tok, -1, axis=1)

    def step_for(c):
        def loss(sp_, g_):
            out = run_pipeline(c, SINGLE, sp_, g_, tok, lab, None,
                               with_loss=True)
            return out["loss"] / jnp.maximum(out["denom"], 1.0)
        l, gr = jax.jit(jax.value_and_grad(loss, argnums=(0, 1)))(sp1, g1)
        flat = np.concatenate([np.asarray(x, np.float64).ravel()
                               for x in jax.tree_util.tree_leaves(gr)])
        return float(l), flat

    l_c, g_c = step_for(cell)
    l_r, g_r = step_for(dataclasses.replace(
        cell, plan=dataclasses.replace(cell.plan, offload_dtype="none")))
    loss_drift = abs(l_c - l_r) / max(abs(l_r), 1e-9)
    grad_drift = float(np.linalg.norm(g_c - g_r)) / max(
        float(np.linalg.norm(g_r)), 1e-12)
    return loss_drift, grad_drift


def bench_offload_quant(measure: bool = True) -> Tuple[List, str]:
    """Returns (csv_rows, text) — the benchmarks.run contract."""
    cfg = get_config(ARCH).reduced()
    lengths = [SEQ_LEN // N_CHUNKS] * N_CHUNKS
    acts = cm.chunk_act_bytes(cfg, lengths, batch=BATCH, pp=1, sp=1)
    raw_off = sum(acts)

    rows: List = []
    lines = [f"== Compressed offload channel ({ARCH} reduced, S={SEQ_LEN}, "
             f"B={BATCH}, {N_CHUNKS} chunks; full-row alpha=1 view) =="]
    key = jax.random.PRNGKey(0)
    for codec in ("fp8", "int8"):
        ratio = cm.offload_wire_ratio(codec)
        wire = raw_off * ratio
        scales = sum(cm.chunk_scale_bytes(cfg, lengths, batch=BATCH, pp=1,
                                          sp=1, offload_dtype=codec))
        err = _codec_error(codec, key)
        # degenerate rows must survive exactly (satellite: zero-row safety)
        z_p, z_s = hostmem.quantize(jnp.zeros((4, 16), jnp.bfloat16), codec)
        zero_ok = bool(jnp.all(hostmem.dequantize(
            z_p, z_s, codec, jnp.bfloat16) == 0))
        t = _codec_time(codec, key) if measure else None
        drift = _step_drift(codec) if measure else None
        rows.append((f"quant_{codec}_wire",
                     f"{t * 1e6:.0f}" if t else "", f"{wire:.0f}"))
        lines.append(
            f"{codec:5s} wire {wire:10.0f} B (x{ratio:.2f} of "
            f"{raw_off:.0f} B raw)  dev scales {scales:8.0f} B  "
            f"row err {err:.3f}  zero-row {'ok' if zero_ok else 'FAIL'}"
            + (f"  rt {t * 1e3:6.2f} ms/block" if t else "")
            + (f"  drift loss {drift[0]:.2e} grad {drift[1]:.2e}"
               if drift is not None else ""))
    return rows, "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="analytic bytes only (no wall clock / step)")
    args = ap.parse_args(argv)
    rows, text = bench_offload_quant(measure=not args.fast)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us},{derived}")
    print()
    print(text)
    return 0


if __name__ == "__main__":
    sys.exit(main())
