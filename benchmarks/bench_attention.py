"""Attention fwd/bwd micro-benchmark — the Pallas-kernel perf trajectory.

Measures wall time of the partial-softmax attention forward and of a full
loss+grad (dq/dk/dv) step for the three implementations:

  * ``pallas``  — the fused flash kernels (interpret mode on CPU; on a real
    TPU the same rows become native-kernel numbers),
  * ``ref``     — the blockwise-jnp reference (the CPU training path),
  * ``dense``   — the naive einsum oracle (materializes S×S; the ceiling
    that flash attention exists to avoid).

The ``derived`` CSV column carries the analytic FLOPs from the cost model
(forward: 2 matmuls; backward: 5 — the recompute-based flash backward), so
CI runs double as the measured-vs-modeled ledger (DESIGN.md §9).

  PYTHONPATH=src python -m benchmarks.bench_attention [--fast] [--csv out.csv]
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from typing import List, Tuple

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core import costmodel as cm
from repro.kernels.flash_attention import flash_attention_partial
from repro.kernels.ref import attention_partial_ref, mha_reference, normalize

# B, Tq, S, H, Hkv, hd — one chunk-vs-cache cell, one decode-ish tail cell
SHAPES_FULL = [(1, 128, 512, 8, 2, 64), (1, 16, 512, 8, 2, 64)]
SHAPES_FAST = [(1, 32, 128, 4, 2, 32)]


def _time(fn, *args, reps=5):
    fn(*args)[0].block_until_ready()          # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.tree_util.tree_leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def _impls(q_pos, kv_pos, w):
    def fwd_pallas(q, k, v):
        o, m, l = flash_attention_partial(q, k, v, q_pos, kv_pos,
                                          interpret=True)
        return normalize(o, l), m

    def fwd_ref(q, k, v):
        o, m, l = attention_partial_ref(q, k, v, q_pos, kv_pos)
        return normalize(o, l), m

    def fwd_dense(q, k, v):
        return mha_reference(q, k, v, q_pos, kv_pos), None

    def as_grad(fwd):
        def loss(q, k, v):
            return jnp.sum(fwd(q, k, v)[0] * w)

        def run(q, k, v):
            l, g = jax.value_and_grad(loss, argnums=(0, 1, 2))(q, k, v)
            return (l,) + g

        return run

    return [("pallas", fwd_pallas), ("ref", fwd_ref), ("dense", fwd_dense)], \
        as_grad


def bench_attention(measure: bool = True, fast: bool = False
                    ) -> Tuple[List, str]:
    rows, lines = [], ["== Attention fwd/bwd: pallas-interpret vs ref vs "
                       "dense (CPU us; derived = analytic MXU flops) =="]
    for (B, Tq, S, H, Hkv, hd) in (SHAPES_FAST if fast else SHAPES_FULL):
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        q = jax.random.normal(ks[0], (B, Tq, H, hd), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, Hkv, hd), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, Hkv, hd), jnp.float32)
        w = jax.random.normal(ks[3], (B, Tq, H, hd), jnp.float32)
        q_pos = jnp.arange(Tq, dtype=jnp.int32) + (S - Tq)
        kv_pos = jnp.arange(S, dtype=jnp.int32)
        f_fwd = cm.attn_flops(B, Tq, H, hd, causal=True, kv_len=S)
        f_bwd = cm.attn_bwd_flops(B, Tq, H, hd, causal=True, kv_len=S)
        by_bwd = cm.attn_bwd_bytes(B, Tq, S, H, Hkv, hd, hd, io_bytes=4)
        tag = f"B{B}_T{Tq}_S{S}_H{H}"
        impls, as_grad = _impls(q_pos, kv_pos, w)
        for name, fwd in impls:
            us_f = _time(jax.jit(fwd), q, k, v) if measure else 0
            us_b = _time(jax.jit(as_grad(fwd)), q, k, v) if measure else 0
            rows.append((f"attn_fwd_{name}_{tag}", round(us_f, 1), f_fwd))
            rows.append((f"attn_bwd_{name}_{tag}", round(us_b, 1),
                         f_fwd + f_bwd))
            lines.append(f"{tag:18s} {name:7s} fwd {us_f:10.1f}us  "
                         f"fwd+bwd {us_b:10.1f}us")
        lines.append(f"{tag:18s} bwd arithmetic intensity "
                     f"{f_bwd / by_bwd:.1f} flops/byte "
                     f"({by_bwd / 1e6:.2f} MB HBM traffic, fp32)")
    lines.append(f"(bwd/fwd flops ratio: matmul {cm.BWD_RATIO:.1f}, "
                 f"recompute-flash attention {cm.ATTN_BWD_RATIO:.1f})")
    return rows, "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smallest shape only (CI smoke)")
    ap.add_argument("--csv", default=None, help="also write rows to a file")
    args = ap.parse_args()
    rows, text = bench_attention(measure=True, fast=args.fast)
    out = ["name,us_per_call,derived"]
    out += [f"{n},{us},{d}" for n, us, d in rows]
    print("\n".join(out))
    print()
    print(text)
    if args.csv:
        with open(args.csv, "w") as f:
            f.write("\n".join(out) + "\n")


if __name__ == "__main__":
    main()
