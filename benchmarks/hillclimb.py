"""§Perf hillclimb driver: lower one (arch x shape) cell under a sequence of
plan variants, extract the roofline terms per variant, and log the
hypothesis -> change -> before -> after chain.

  PYTHONPATH=src python -m benchmarks.hillclimb --cell qwen2-7b:train_4k \
      --variants baseline,auto_attn,auto_attn+gc --out hc.json

Variants (cumulative names joined by '+'):
  baseline   — paper-faithful: gather_q attention, f32 merges/grad RS
  auto_attn  — byte-count gather_kv/gather_q switch (GQA-narrow KV)
  merge_bf16 — bf16 softmax-merge reduce-scatter
  gc         — bf16 weight-gradient reduce-scatter (custom_vjp)
  nX         — n_chunks = X (pipeline feed depth)
  accumX     — grad_accum = X
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import argparse
import json


from repro.launch.mesh import make_production_mesh
from repro.launch.dryrun import run_cell
from benchmarks.roofline import analyze_record


def variant_overrides(spec: str) -> dict:
    ov = {}
    for part in spec.split("+"):
        if part == "baseline":
            continue
        elif part == "auto_attn":
            ov["attn_mode"] = "auto"
        elif part == "merge_bf16":
            ov["merge_bf16"] = True
        elif part == "gc":
            ov["grad_compress"] = True
        elif part.startswith("n") and part[1:].isdigit():
            ov["n_chunks"] = int(part[1:])
        elif part.startswith("accum") and part[5:].isdigit():
            ov["grad_accum"] = int(part[5:])
        elif part.startswith("pp") and part[2:].isdigit():
            ov["pp"] = int(part[2:])
            ov["dp"] = 16 // int(part[2:])
        elif part == "msp":
            ov["msp"] = True
        elif part == "rematfull":
            ov["remat"] = "full"
        elif part == "nooffload":
            ov["offload"] = False
        else:
            raise ValueError(part)
    return ov


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    arch, shape = args.cell.split(":")
    mesh = make_production_mesh()

    results = []
    import repro.launch.dryrun as DR

    for spec in args.variants.split(","):
        ov = variant_overrides(spec)
        # monkey-patch overrides into resolve_cell via run_cell's path
        import repro.parallel.runner as R
        orig = R.resolve_cell

        def patched(a, s, **kw):
            kw = dict(kw)
            base = kw.pop("overrides", None) or {}
            base.update(ov)
            return orig(a, s, overrides=base, **kw)

        R.resolve_cell = patched
        DR.resolve_cell = patched
        try:
            rec = run_cell(arch, shape, mesh, verbose=False)
        finally:
            R.resolve_cell = orig
            DR.resolve_cell = orig
        rec["variant"] = spec
        a = analyze_record(rec) if rec.get("status") == "ok" else None
        if a:
            rec.update({k: a[k] for k in ("compute_s", "memory_s",
                                          "collective_s", "dominant",
                                          "useful_ratio", "roofline_frac")})
            m = rec["memory"]
            dev = (m["argument_bytes"] + m["temp_bytes"] + m["output_bytes"]
                   - m["alias_bytes"]) / 2**30
            proj = dev - rec.get("cpu_upcast_artifact_bytes", 0) / 2**30
            print(f"{spec:28s} comp {a['compute_s']:7.3f}s mem "
                  f"{a['memory_s']:7.3f}s coll {a['collective_s']:7.3f}s "
                  f"dom={a['dominant']:10s} roofline {a['roofline_frac']:.3f}"
                  f" devGiB {dev:6.1f} (tpu~{proj:5.1f})")
        else:
            print(f"{spec:28s} {rec.get('status')}: "
                  f"{rec.get('error', '')[:120]}")
        results.append(rec)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
