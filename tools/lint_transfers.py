#!/usr/bin/env python
"""Transfer lint: every host/device copy goes through runtime/hostmem.py.

The contract auditor (analysis/audit.py) proves transfer-count and
placement invariants on traced programs — but only for transfers it can
attribute.  A raw ``jax.device_put`` scattered elsewhere in the tree is
invisible to the offload accounting until it breaks a gate, so this lint
forbids the attribute ``.device_put`` outside ``runtime/hostmem.py`` (the
one blessed seam, where every put carries an explicit memory kind).

Known-legitimate sites — host-side input staging, checkpoint restore
placement, test fixtures — carry an inline allowlist marker with a
mandatory reason, on the offending line or the line above:

    x = jax.device_put(v, sharding)  # transfer-lint: ok (input staging)

Usage: ``python tools/lint_transfers.py src tests benchmarks`` — prints
one line per violation and exits 1 when any exist.  No dependencies
beyond the stdlib; runs in the lint CI job next to ruff.
"""
import ast
import os
import re
import sys

MARKER = re.compile(r"#\s*transfer-lint:\s*ok\s*\((.+?)\)")
EXEMPT_BASENAMES = {"hostmem.py", "lint_transfers.py"}


def iter_py_files(roots):
    for root in roots:
        if os.path.isfile(root):
            if root.endswith(".py"):
                yield root
            continue
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames[:] = [d for d in dirnames
                           if d not in ("__pycache__", ".git")]
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    yield os.path.join(dirpath, fn)


def marker_reason(lines, lineno):
    """Allowlist marker on the flagged line or the line above (1-based)."""
    for ln in (lineno, lineno - 1):
        if 1 <= ln <= len(lines):
            m = MARKER.search(lines[ln - 1])
            if m and m.group(1).strip():
                return m.group(1).strip()
    return None


def lint_file(path):
    with open(path, encoding="utf-8") as f:
        src = f.read()
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:  # pragma: no cover - repo code parses
        return [(getattr(e, "lineno", 0) or 0, f"syntax error: {e.msg}")]
    lines = src.splitlines()
    out = []
    for node in ast.walk(tree):
        # attribute references, not just calls: `tree_map(jax.device_put, …)`
        # moves bytes exactly like a direct call does
        if not (isinstance(node, ast.Attribute)
                and node.attr == "device_put"):
            continue
        if marker_reason(lines, node.lineno):
            continue
        out.append((node.lineno,
                    "raw device_put outside runtime/hostmem.py — route "
                    "through hostmem.to_host/to_device, or mark the line "
                    "`# transfer-lint: ok (<reason>)`"))
    return out


def main(argv=None) -> int:
    roots = (argv if argv is not None else sys.argv[1:]) or ["src"]
    violations = []
    for path in iter_py_files(roots):
        if os.path.basename(path) in EXEMPT_BASENAMES:
            continue
        for lineno, msg in lint_file(path):
            violations.append(f"{path}:{lineno}: {msg}")
    for v in violations:
        print(v)
    if violations:
        print(f"transfer-lint: {len(violations)} violation(s)")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
