"""AdamW with global-norm clipping, schedules, and *executed* memory knobs.

Runs *outside* shard_map on global (sharded) arrays — XLA/GSPMD inserts the
(elementwise-free) collectives for the norm reductions.  Memory knobs used by
the big-model plans (DESIGN.md §4, §11):
  * ``opt_dtype``: moment dtype (deepseek-v3 uses bf16, as in its report);
  * ``offload_moments``: keep ``AdamWState.m/v`` resident in host memory
    (ZeRO-Offload analogue — the same host memory kinds and D2H/H2D
    primitives the activation offload path uses, runtime/hostmem.py).
    Since PR 4 this is *executed dataflow*, not a sharding hint:
    ``init_state`` births the moments in host space (no device allocation),
    and ``apply_update`` under ``moments_mode="explicit"`` stages exactly
    one H2D per moment leaf, computes the fp32 update on device, and writes
    the new moments back with one D2H per leaf.  ``moments_mode="xla"``
    is the legacy path: the moments stay host-committed through their
    shardings and XLA streams them through HBM during the update.
  * ZeRO-1 across the `pod` axis is expressed through the moment shardings
    built in parallel/specs.py.

Every host-resident moment leaf is tagged with a ``checkpoint_name``
(``opt_m@<i>`` / ``opt_v@<i>``) so the memory ledger
(runtime/memledger.moment_bytes_from_jaxpr) can account the exact bytes kept
off-device from the traced update — the optimizer-state analogue of the
``act_off@<tick>`` activation names.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.runtime import hostmem

# checkpoint-name bases for the host-resident moments; leaf-qualified as
# opt_m@<leaf-index> so the ledger attributes bytes per leaf exactly
OPT_M_NAME = "opt_m"
OPT_V_NAME = "opt_v"


def moment_names(i: int):
    return f"{OPT_M_NAME}@{i}", f"{OPT_V_NAME}@{i}"


def moment_scale_names(i: int):
    """Names of a compressed moment leaf's per-row scales.  Deliberately
    *not* under the ``opt_m@``/``opt_v@`` prefixes — the ledger's moment
    channel counts payload bytes and scale bytes separately (the scales are
    host-resident here, unlike the activation channel's device-resident
    ``act_scale``; see DESIGN.md §14)."""
    return f"{OPT_M_NAME}_scale@{i}", f"{OPT_V_NAME}_scale@{i}"


class AdamWState(NamedTuple):
    step: jax.Array   # int32 []
    m: object         # pytree like params
    v: object         # pytree like params


def init_state(params, opt_dtype=jnp.float32, *, offload_moments: bool = False,
               host_kind="auto", moments_dtype: str = "none") -> AdamWState:
    """Zero moments, placed where they will live.

    With ``offload_moments`` the zeros are *born in host memory*
    (hostmem.host_zeros: numpy buffer -> device_put into the host space), so
    initialization never materializes an opt_dtype copy of the parameters in
    device memory — the step-0 peak equals the steady-state peak
    (regression-tested in tests/test_opt_offload.py).

    With ``moments_dtype`` ("fp8" | "int8", DESIGN.md §14) each moment leaf
    is the compressed host residency pair ``(payload, scale)`` — the 1-byte
    wire payload plus its per-row fp32 scales, both host-resident.  Zero
    payload dequantizes to zero under any scale, so all-zero init is exact."""
    if moments_dtype not in (None, "none"):
        assert offload_moments, (
            "moments_dtype compression requires offload_moments (there is "
            "no host channel to compress otherwise)")
        kind = hostmem.resolve_host_kind(host_kind)
        wire = hostmem.codec_wire_dtype(moments_dtype)

        def zeros(p):
            sshape = p.shape[:-1] + (1,) if p.ndim >= 1 else ()
            # the scale can't inherit p's sharding verbatim: its trailing
            # dim is 1, so a last-axis-sharded param needs the partition
            # dropped there (row_scale_sharding)
            ssh = (hostmem.row_scale_sharding(p, kind)
                   if kind is not None and not isinstance(p, jax.core.Tracer)
                   else None)
            return (hostmem.host_zeros(p.shape, wire, kind, like=p),
                    hostmem.host_zeros(sshape, jnp.float32, kind, like=p,
                                       sharding=ssh))
    elif offload_moments:
        kind = hostmem.resolve_host_kind(host_kind)
        zeros = lambda p: hostmem.host_zeros(p.shape, opt_dtype, kind, like=p)
    else:
        zeros = lambda p: jnp.zeros(p.shape, opt_dtype)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree_util.tree_map(zeros, params),
                      v=jax.tree_util.tree_map(zeros, params))


def cosine_lr(step, *, peak=3e-4, warmup=100, total=10000, floor=0.1):
    warm = peak * (step + 1) / warmup
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos).astype(jnp.float32)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply_update(params, grads, state: AdamWState, *, lr, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1, clip_norm=1.0,
                 offload_moments: bool = False,
                 moments_mode: str = "explicit", host_kind="auto",
                 moments_dtype: str = "none",
                 probe: Optional[callable] = None):
    """One AdamW step. Returns (new_params, new_state, metrics).

    offload_moments + moments_mode="explicit": per moment leaf, exactly one
    H2D device_put brings the host-resident moment on device, the fp32
    update runs there, and one D2H writes the new moment back to host —
    the round trip is value-level identity, so offload on/off updates are
    equal (tests/test_opt_offload.py).  moments_mode="xla" keeps the legacy
    behavior: no explicit copies; placement/streaming delegated to XLA via
    the moments' committed host shardings.

    moments_dtype ("fp8" | "int8", DESIGN.md §14): the host residency is
    the compressed ``(payload, scale)`` pair — the H2D brings both on
    device and dequantizes to fp32 for the update; the D2H writes back the
    re-quantized pair.  Compression cuts the *host* bytes and the transfer
    volume (payload + scales vs the full opt_dtype leaf); the device-side
    update still runs in fp32 either way.  Lossy by design — drift bounds
    are pinned in tests/test_offload_quant.py.

    probe: optional identity hook (runtime/memledger.update_probe) threaded
    onto the step counter — runtime evidence that the update phase executed.
    """
    assert moments_mode in ("explicit", "xla"), moments_mode
    compressed = moments_dtype not in (None, "none")
    assert not compressed or (offload_moments
                              and moments_mode == "explicit"), (
        "moments_dtype compression requires offload_moments with "
        "moments_mode='explicit'")
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    kind = hostmem.resolve_host_kind(host_kind) if offload_moments else None

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m_new = b1 * m32 + (1 - b1) * g
        v_new = b2 * v32 + (1 - b2) * g * g
        u = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            u = u + weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
        return p_new, m_new.astype(m.dtype), v_new.astype(v.dtype)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    def fetch(leaf, name, scale_name):
        """Host residency -> device fp32 moment (compressed: H2D the
        (payload, scale) pair and dequantize; raw: H2D the named leaf)."""
        if compressed:
            payload, sc = leaf
            payload = hostmem.to_device(checkpoint_name(payload, name), kind)
            sc = hostmem.to_device(checkpoint_name(sc, scale_name), kind)
            return hostmem.dequantize(payload, sc, moments_dtype, jnp.float32)
        # the *host-resident* buffer carries the name, mirroring the
        # act_off contract: what the ledger counts is what lives off
        # device between steps
        leaf = checkpoint_name(leaf, name)
        if moments_mode == "explicit":
            leaf = hostmem.to_device(leaf, kind)   # one H2D per moment leaf
        return leaf

    def store(leaf_new):
        """Device moment -> host residency (compressed: quantize and D2H
        the pair; raw: D2H the leaf)."""
        if compressed:
            payload, sc = hostmem.quantize(leaf_new, moments_dtype)
            return (hostmem.to_host(payload, kind), hostmem.to_host(sc, kind))
        if offload_moments and moments_mode == "explicit":
            return hostmem.to_host(leaf_new, kind)  # one D2H writes back
        return leaf_new

    out = []
    for i, (p, g, m, v) in enumerate(zip(flat_p, flat_g, flat_m, flat_v)):
        if offload_moments:
            nm, nv = moment_names(i)
            nms, nvs = moment_scale_names(i)
            m = fetch(m, nm, nms)
            v = fetch(v, nv, nvs)
        p_new, m_new, v_new = upd(p, g, m, v)
        out.append((p_new, store(m_new), store(v_new)))
    if probe is not None:
        step = probe(step)
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step=step, m=new_m, v=new_v), metrics
