"""AdamW with global-norm clipping, schedules, and memory knobs.

Runs *outside* shard_map on global (sharded) arrays — XLA/GSPMD inserts the
(elementwise-free) collectives for the norm reductions.  Memory knobs used by
the big-model plans (DESIGN.md §4):
  * ``opt_dtype``: moment dtype (deepseek-v3 uses bf16, as in its report);
  * ``offload_moments``: place m/v in ``pinned_host`` memory (ZeRO-Offload
    analogue — thematically the same host-offload machinery SPPO uses for
    activations); streamed through HBM by XLA during the update;
  * ZeRO-1 across the `pod` axis is expressed through the moment shardings
    built in parallel/specs.py.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array   # int32 []
    m: object         # pytree like params
    v: object         # pytree like params


def init_state(params, opt_dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, opt_dtype)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree_util.tree_map(zeros, params),
                      v=jax.tree_util.tree_map(zeros, params))


def cosine_lr(step, *, peak=3e-4, warmup=100, total=10000, floor=0.1):
    warm = peak * (step + 1) / warmup
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos).astype(jnp.float32)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply_update(params, grads, state: AdamWState, *, lr, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1, clip_norm=1.0):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m_new = b1 * m32 + (1 - b1) * g
        v_new = b2 * v32 + (1 - b2) * g * g
        u = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            u = u + weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
        return p_new, m_new.astype(m.dtype), v_new.astype(v.dtype)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step=step, m=new_m, v=new_v), metrics
