"""Granite-3.0-1B-A400M [hf:ibm-granite] — MoE, 32 experts top-8, GQA kv=8."""
from repro.configs.base import MoEConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,  # per-expert ffn dim
    vocab_size=49155,
    head_dim=64,
    act="swiglu",
    norm="rmsnorm",
    rope=True,
    rope_theta=1e4,
    tie_embeddings=True,
    moe=MoEConfig(num_experts=32, top_k=8, d_ff_expert=512),
))
