"""The paper's own GPT configs (Table 2): GPT-7B / GPT-13B / GPT-65B.

These are the models SPPO evaluates on (512K–4M token sequences).  They are
registered alongside the assigned architectures so the paper's tables can be
reproduced by the benchmark harness.
"""
from repro.configs.base import ModelConfig, register

GPT_7B = register(ModelConfig(
    name="sppo-gpt-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=16384,
    vocab_size=51200,
    head_dim=128,
    act="gelu",
    norm="layernorm",
    rope=True,
))

GPT_13B = register(ModelConfig(
    name="sppo-gpt-13b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=20480,
    vocab_size=51200,
    head_dim=128,
    act="gelu",
    norm="layernorm",
    rope=True,
))

GPT_65B = register(ModelConfig(
    name="sppo-gpt-65b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=64,
    d_ff=32768,
    vocab_size=51200,
    head_dim=128,
    act="gelu",
    norm="layernorm",
    rope=True,
))
