"""Zamba2-7B [arXiv:2411.15242] — hybrid: Mamba2 mixers + shared attention block.

81 mixer layers; a single *shared* (weight-tied) attention+MLP block is applied
after every 6 Mamba2 layers (14 applications, last group ghost-padded).
ssm_state=64 per the brief.
"""
from repro.configs.base import ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    head_dim=112,   # 3584 / 32 for the shared attention block
    act="swiglu",
    norm="rmsnorm",
    rope=True,
    rope_theta=1e4,
    ssm=SSMConfig(kind="mamba2", d_state=64, head_dim=64, expand=2),
    shared_attn_every=6,
))
