"""Config system: model configs, input-shape configs, parallel plans, registry.

Every assigned architecture gets a ``ModelConfig`` in its own module under
``repro.configs``.  Shapes are the four assigned input-shape cells.  A
``ParallelPlan`` describes how a (arch x shape) cell maps onto the production
mesh (see parallel/plans.py for the solver-assisted defaults).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs for family-specific blocks
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 multi-head latent attention."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba2"  # "mamba2" | "rwkv6"
    d_state: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4  # mamba2 short conv (stubbed as identity-free conv)


@dataclass(frozen=True)
class CrossAttnConfig:
    """VLM / enc-dec cross-attention frontends (stub embeddings)."""

    n_context_tokens: int = 1600  # patches (vlm) or frames (audio)
    every: int = 0  # insert a cross-attn block after every `every` self blocks
    context_dim: Optional[int] = None  # None -> d_model (stub pre-projected)


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | vlm | audio | hybrid | moe | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    act: str = "swiglu"  # swiglu | gelu | relu2 | geglu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    qkv_bias: bool = False
    mlp_bias: bool = False
    rope: bool = True
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0  # glm4 uses partial rotary
    pos_emb: str = "rope"  # rope | learned | none
    max_position: int = 1 << 20
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    cross_attn: Optional[CrossAttnConfig] = None
    # zamba2-style shared attention block applied after every k mixer layers
    shared_attn_every: int = 0
    # whisper-style encoder (frames already embedded by the stub frontend)
    encoder_layers: int = 0
    n_frames: int = 0
    # squared-relu etc. keep the attention softmax in fp32 regardless
    attn_softmax_fp32: bool = True
    dtype: str = "bfloat16"

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True when long_500k decode is runnable (SSM state / linear attn)."""
        return self.family in ("ssm", "hybrid")

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        small = dict(
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            d_ff=128,
            vocab_size=256,
            head_dim=16,
            max_position=4096,
        )
        if self.moe is not None:
            small["moe"] = dataclasses.replace(
                self.moe, num_experts=4, top_k=2, d_ff_expert=32)
        if self.mla is not None:
            small["mla"] = MLAConfig(
                q_lora_rank=32, kv_lora_rank=16, rope_head_dim=8,
                nope_head_dim=16, v_head_dim=16)
            small["head_dim"] = None
        if self.ssm is not None:
            small["ssm"] = dataclasses.replace(self.ssm, d_state=16, head_dim=16)
        if self.cross_attn is not None:
            small["cross_attn"] = dataclasses.replace(
                self.cross_attn, n_context_tokens=8)
        if self.encoder_layers:
            small["encoder_layers"] = 2
            small["n_frames"] = 16
        if self.shared_attn_every:
            small["shared_attn_every"] = 2
        small["name"] = self.name + "-reduced"
        small.update(overrides)
        return dataclasses.replace(self, **small)


# ---------------------------------------------------------------------------
# Shapes (assigned cells) — LM shapes are seq_len x global_batch
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Parallel plan — how a cell maps onto the production mesh
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParallelPlan:
    dp: int = 16          # data-parallel groups on the 'data' axis
    pp: int = 1           # SPPO pipeline stages on the 'data' axis (dp*pp == data)
    sp: int = 16          # sequence/model parallel width == 'model' axis size
    n_chunks: int = 1     # N subsequences (SPPO)
    partition: str = "flops"   # flops | length  (SPPO sequence partitioning)
    offload: bool = True       # adaptive activation offload to pinned_host
    # offload execution form (DESIGN.md §10): "explicit" places act_off rows
    # via memory-kind device_puts in the tick loop (staged-copy emulation on
    # backends without host memory kinds); "xla" delegates placement to the
    # remat offload policy (save_and_offload_only_these_names)
    offload_mode: str = "explicit"
    # backward-reload placement on the explicit path (DESIGN.md §12):
    # "ahead" = tick-level custom_vjp seam issuing chunk i's H2D one event
    # ahead, overlapped with chunk i+1's backward (the simulator's
    # memory-mirror rule, executed); "sync" = autodiff placement — the
    # checkpoint remat replays each chunk's reload at its own backward
    prefetch: str = "ahead"
    msp: bool = False          # multiplexed sequence partitioning (ramp chunks)
    msp_split: int = 2         # sub-chunks per ramp chunk (DESIGN.md §2)
    remat: str = "sppo"        # sppo | full | none
    zero1: bool = True         # shard optimizer states over dp (and pod)
    opt_dtype: str = "float32"  # moment dtype; deepseek uses bfloat16
    # executed optimizer-state offload (DESIGN.md §11): AdamW m/v live in
    # host memory kinds between steps.  moments_mode "explicit" stages one
    # H2D per moment leaf into the device update and one D2H back;
    # "xla" (legacy) keeps host-committed shardings and lets XLA stream.
    offload_moments: bool = False
    moments_mode: str = "explicit"
    # compressed host residency (DESIGN.md §14): quantize the executed
    # offload channels across the host link — act_off rows (offload_dtype)
    # and the AdamW m/v moments (moments_dtype) — as fp8_e4m3 or int8 wire
    # payloads with per-row fp32 scales; "none" keeps raw bf16/fp32 bytes
    offload_dtype: str = "none"
    moments_dtype: str = "none"
    grad_accum: int = 1
    # decode-only: microbatch pipeline over batch dim when pp > 1
    decode_microbatch: int = 1
    # --- beyond-paper perf knobs (§Perf hillclimb; baseline keeps defaults)
    # attn_mode: "gather_q" (paper-faithful flash-decoding merge) |
    #            "gather_kv" (all-gather the KV shard, no merge collectives)
    #            | "auto" (byte-count switch per call site)
    #            | "ring" (rotate KV blocks around the model axis via
    #              ppermute, fold per-hop partials in canonical source order
    #              — DESIGN.md §15; KV working set stays at two blocks, so
    #              chunks whose visible KV exceeds one stage's HBM admit)
    #            | "local" (no attention collectives at all — executed only
    #              at sp == 1; in the cost model it prices full visible-KV
    #              residency per device, the mode the §15 memory model
    #              rejects for beyond-one-stage contexts)
    attn_mode: str = "gather_q"
    # cast the attention softmax-merge partials to bf16 before reduction
    merge_bf16: bool = False
    # reduce-scatter weight gradients in bf16 (custom_vjp on the gather)
    grad_compress: bool = False

    def validate(self, data_size: int, model_size: int) -> None:
        assert self.dp * self.pp == data_size, (
            f"dp({self.dp}) * pp({self.pp}) must equal data axis ({data_size})")
        assert self.sp == model_size, (
            f"sp({self.sp}) must equal model axis ({model_size})")
        assert not self.msp or self.msp_split >= 2, (
            f"msp_split({self.msp_split}) must be >= 2 (sub-chunks per ramp)")
        assert self.offload_mode in ("explicit", "xla"), (
            f"offload_mode({self.offload_mode!r}) must be explicit|xla")
        assert self.prefetch in ("ahead", "sync"), (
            f"prefetch({self.prefetch!r}) must be ahead|sync")
        assert self.moments_mode in ("explicit", "xla"), (
            f"moments_mode({self.moments_mode!r}) must be explicit|xla")
        assert self.offload_dtype in ("none", "fp8", "int8"), (
            f"offload_dtype({self.offload_dtype!r}) must be none|fp8|int8")
        assert self.moments_dtype in ("none", "fp8", "int8"), (
            f"moments_dtype({self.moments_dtype!r}) must be none|fp8|int8")
        assert self.moments_dtype == "none" or (
            self.offload_moments and self.moments_mode == "explicit"), (
            "moments_dtype compression requires offload_moments with "
            "moments_mode='explicit' (there is no host channel to compress "
            "otherwise)")
        assert self.attn_mode in ("gather_q", "gather_kv", "auto", "ring",
                                  "local"), (
            f"attn_mode({self.attn_mode!r}) must be "
            "gather_q|gather_kv|auto|ring|local")
        assert self.attn_mode != "local" or model_size == 1, (
            "attn_mode='local' runs attention without any cross-device KV "
            "movement, which is only executable at model_size == 1 — on a "
            "wider mesh pick ring/gather_q/gather_kv (DESIGN.md §15)")


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> Tuple[str, ...]:
    if not _REGISTRY:
        _load_all()
    return tuple(sorted(_REGISTRY))


ASSIGNED_ARCHS = (
    "qwen2-7b",
    "glm4-9b",
    "nemotron-4-15b",
    "starcoder2-3b",
    "llama-3.2-vision-11b",
    "whisper-tiny",
    "zamba2-7b",
    "granite-moe-1b-a400m",
    "deepseek-v3-671b",
    "rwkv6-3b",
)


def _load_all() -> None:
    import importlib

    for mod in (
        "qwen2_7b",
        "glm4_9b",
        "nemotron_4_15b",
        "starcoder2_3b",
        "llama_3_2_vision_11b",
        "whisper_tiny",
        "zamba2_7b",
        "granite_moe_1b_a400m",
        "deepseek_v3_671b",
        "rwkv6_3b",
        "sppo_gpt",
    ):
        importlib.import_module(f"repro.configs.{mod}")


def cell_is_runnable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch x shape) cell runs, per the brief's skip rules."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k needs sub-quadratic attention; skipped for full-attention arch"
    return True, ""
