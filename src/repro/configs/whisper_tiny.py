"""Whisper-tiny [arXiv:2212.04356] — enc-dec backbone, conv frontend STUB.

Input spec provides precomputed frame embeddings [B, n_frames, d_model]
(the mel+conv frontend is stubbed per the brief).  4 encoder layers
(bidirectional) + 4 decoder layers (causal self-attn + cross-attn).
"""
from repro.configs.base import CrossAttnConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,            # decoder layers
    encoder_layers=4,
    n_frames=1500,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    head_dim=64,
    act="gelu",
    norm="layernorm",
    qkv_bias=True,
    mlp_bias=True,
    rope=False,
    pos_emb="learned",
    max_position=1 << 16,
    cross_attn=CrossAttnConfig(n_context_tokens=1500, every=1),
))
