"""RWKV6-3B "Finch" [arXiv:2404.05892; hf] — attention-free, data-dependent decay."""
from repro.configs.base import ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,        # head size 64
    n_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    head_dim=64,
    act="relu2",       # rwkv channel-mix uses squared relu
    norm="layernorm",
    rope=False,
    pos_emb="none",
    ssm=SSMConfig(kind="rwkv6", d_state=64, head_dim=64),
))
