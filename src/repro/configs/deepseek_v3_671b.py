"""DeepSeek-V3-671B [arXiv:2412.19437; hf] — MLA + MoE 256e top-8 + 1 shared.

Per the brief's config: 61 layers, d_model=7168, 128 heads, MoE with 1 shared
+ 256 routed experts (top-8), per-expert d_ff=2048.  MLA latent attention with
kv_lora_rank=512, rope/nope split head dims.  Simplifications recorded in
DESIGN.md: all 61 layers are MoE (the HF checkpoint's first-3-dense detail is
not in the assigned config); the MTP auxiliary head is omitted.
Optimizer moments are bf16 (as in the DeepSeek-V3 report) so states fit HBM.
"""
from repro.configs.base import MLAConfig, MoEConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=2048,  # per-expert ffn dim
    vocab_size=129280,
    act="swiglu",
    norm="rmsnorm",
    rope=True,
    rope_theta=1e4,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(num_experts=256, top_k=8, d_ff_expert=2048,
                  n_shared_experts=1),
))
