"""Nemotron-4-15B [arXiv:2402.16819] — dense, GQA kv=8, squared-ReLU MLP."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=256000,
    head_dim=128,
    act="relu2",       # squared ReLU, non-gated
    norm="layernorm",  # nemotron layernorm1p ~ layernorm
    rope=True,
    rope_theta=1e4,
))
