"""GLM4-9B [hf:THUDM/glm-4-9b] — dense, GQA kv=2, partial RoPE, SwiGLU."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=151552,
    head_dim=128,
    act="swiglu",
    norm="rmsnorm",
    qkv_bias=True,
    rope=True,
    rope_theta=1e4,
    rope_fraction=0.5,
))
