"""StarCoder2-3B [arXiv:2402.19173; hf] — dense, GQA kv=2, RoPE, GELU MLP."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12288,
    vocab_size=49152,
    head_dim=128,
    act="gelu",       # non-gated
    norm="layernorm",
    qkv_bias=True,
    mlp_bias=True,
    rope=True,
    rope_theta=1e5,
))
