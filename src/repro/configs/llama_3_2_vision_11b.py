"""Llama-3.2-Vision-11B [hf:meta-llama/Llama-3.2-11B-Vision] — VLM backbone.

Decoder with a cross-attention image layer after every 5 self-attention
layers (8 cross blocks across 40 self layers, as in the HF checkpoint).  The
vision frontend is a STUB: the input spec provides precomputed patch
embeddings [B, n_patches, d_model].
"""
from repro.configs.base import CrossAttnConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    head_dim=128,
    act="swiglu",
    norm="rmsnorm",
    rope=True,
    rope_theta=5e5,
    cross_attn=CrossAttnConfig(n_context_tokens=1600, every=5),
))
