"""Pallas TPU kernels: chunked-causal flash attention, forward + backward.

This is the compute hot-spot of SPPO's subsequence processing: the attention
of one subsequence (chunk) of queries against the device-local shard of the
accumulated KV cache (all previous chunks + the current one).  Causality
across chunks is positional: visibility is ``q_pos >= kv_pos`` on *global*
token positions, so the same kernel serves intra-chunk causal attention,
cross-chunk cache attention, decode (Tq == 1 padded to a block) and
bidirectional encoder attention (causal=False).

TPU mapping (target: v5e — MXU 128x128, ~16 MiB VMEM/core):
  grid = (B * Hkv, Tq // bq, S // bk) with the KV dimension innermost
  ("arbitrary" semantics) so the (m, l, acc) accumulators live in VMEM
  scratch across KV steps.  Block shapes default to (bq=128, bk=128) * G
  query rows — q rows for all G grouped query heads of one KV head are
  folded into the q-block row dimension, so GQA costs no extra KV traffic:
  the [bk, hd] KV block is streamed once per q block for all G heads.

VMEM budget at defaults (bq=128, bk=128, hd=128, G<=8, fp32 accum):
  q (G*128*128*4) + k/v (2*128*128*4) + acc (G*128*128*4) + p (G*128*128*4)
  ~= 3.3 MiB at G=8 — comfortably inside 16 MiB with double buffering.

Outputs are the *partial* (o, m, l) triple (see kernels/ref.py) so the
cross-device softmax merge (psum over the `model` axis) composes with the
kernel unchanged.

Backward (SPPO trains — the kernel must differentiate).  The public entry
``flash_attention_partial`` carries a ``jax.custom_vjp``:

  * residuals are (q, k, v, positions, o, m, l) — exactly the per-chunk
    tensors the two-level activation plan (core/offload.py) already budgets:
    q/k/v are recomputed-or-saved Type-1 rows and the (o, m, l) triple is the
    Type-1 attention output.  Nothing quadratic is ever saved.
  * the backward recomputes p = exp(s − m) from the saved per-row logsumexp
    statistic m inside two fused Pallas kernels (DESIGN.md §8):
      - dq:  the forward's grid (B·Hkv, nq, nk), KV innermost, dq accumulated
        in VMEM scratch across KV steps;
      - dkv: the transposed grid (B·Hkv, nk, nq), q innermost, dk/dv
        accumulated in VMEM scratch across q steps (the GQA head fold makes
        the sum over grouped heads implicit in the row reduction).
  * the max statistic m is gradient-frozen (matching kernels/ref.py): its
    contribution cancels exactly in the o/l ratio downstream, and dropping
    its cotangent keeps the cross-device pmax merge differentiable.

Because (o, l) are *un-normalized*, the quotient rule of out = o/l lives in
jnp-land outside the kernel; the kernel backward only needs the cotangents
(do, dl) and never the D = rowsum(do∘out) term of the fused-normalization
formulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
PAD_POS = 2**30


def _flash_partial_kernel(qpos_ref, kpos_ref, qstart_ref,  # position blocks
                          q_ref, k_ref, v_ref,    # [bq*G, hd] / [bk, hd] blocks
                          o_ref, m_ref, l_ref,    # outputs
                          acc_ref, mm_ref, ll_ref,  # VMEM scratch
                          *, causal: bool, scale: float, bq: int, bk: int,
                          g: int, nk: int):
    ks = pl.program_id(2)

    @pl.when(ks == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        mm_ref[...] = jnp.full_like(mm_ref, NEG_INF)
        ll_ref[...] = jnp.zeros_like(ll_ref)

    q = q_ref[...].astype(jnp.float32)          # [G*bq, hd]
    k = k_ref[...].astype(jnp.float32)          # [bk, hd]
    v = v_ref[...].astype(jnp.float32)          # [bk, hv]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # [G*bq, bk]

    s = jnp.where(_visible(qpos_ref, kpos_ref, qstart_ref, g, causal),
                  s, NEG_INF)

    m_prev = mm_ref[...]                        # [G*bq, 1]
    m_blk = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_blk)
    safe = m_new > NEG_INF / 2
    alpha = jnp.where(safe, jnp.exp(m_prev - m_new), 0.0)
    p = jnp.where(safe, jnp.exp(s - m_new), 0.0)
    ll_ref[...] = ll_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    mm_ref[...] = m_new

    @pl.when(ks == nk - 1)
    def _fin():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)
        m_ref[...] = mm_ref[...].astype(m_ref.dtype)
        l_ref[...] = ll_ref[...].astype(l_ref.dtype)


def _visible(qpos_ref, kpos_ref, qstart_ref, g: int, causal: bool):
    """[G*bq, bk] visibility mask — identical in forward and backward.
    ``qstart_ref`` is the per-query segment window (packed-document
    blocking, [bq] int32 per batch row): a kv slot is visible only when
    kv_pos >= q_start.  Zeros degenerate to the plain positional mask;
    PAD_POS marks dead (padding) query rows — no real kv slot reaches
    2**30, so those rows mask fully."""
    qpos = qpos_ref[...]                        # [bq] int32
    kpos = kpos_ref[...]                        # [bk] int32
    qpos_g = jnp.tile(qpos, (g,))               # [G*bq] — heads share positions
    qstart_g = jnp.tile(qstart_ref[...], (g,))  # [G*bq] — per batch row
    valid = (kpos[None, :] != PAD_POS)
    if causal:
        valid = valid & (qpos_g[:, None] >= kpos[None, :])
    valid = valid & (kpos[None, :] >= qstart_g[:, None])
    return valid


def _recompute_p_ds(qpos_ref, kpos_ref, qstart_ref, q, k, v, do, m, dl,
                    *, causal: bool, scale: float, g: int):
    """Shared backward block math: recompute p from the saved logsumexp row
    statistic, then dS = P ∘ (dO·Vᵀ + dl).  m is treated as a constant (the
    gradient-frozen max statistic, see module docstring)."""
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    s = jnp.where(_visible(qpos_ref, kpos_ref, qstart_ref, g, causal),
                  s, NEG_INF)
    # fully-masked rows carry m == NEG_INF; exp(NEG_INF - NEG_INF) would be 1
    safe = m > NEG_INF / 2                       # [G*bq, 1]
    p = jnp.where(safe, jnp.exp(s - m), 0.0)     # [G*bq, bk]
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ()))) + dl
    return p, p * dp


def _flash_bwd_dq_kernel(qpos_ref, kpos_ref, qstart_ref, q_ref, k_ref, v_ref,
                         do_ref, m_ref, dl_ref,
                         dq_ref, dq_acc,
                         *, causal: bool, scale: float, g: int, nk: int):
    ks = pl.program_id(2)

    @pl.when(ks == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    do = do_ref[...].astype(jnp.float32)
    _, ds = _recompute_p_ds(qpos_ref, kpos_ref, qstart_ref, q, k, v, do,
                            m_ref[...], dl_ref[...],
                            causal=causal, scale=scale, g=g)
    dq_acc[...] += jax.lax.dot_general(
        ds, k, (((1,), (0,)), ((), ()))) * scale

    @pl.when(ks == nk - 1)
    def _fin():
        dq_ref[...] = dq_acc[...].astype(dq_ref.dtype)


def _flash_bwd_dkv_kernel(qpos_ref, kpos_ref, qstart_ref, q_ref, k_ref, v_ref,
                          do_ref, m_ref, dl_ref,
                          dk_ref, dv_ref, dk_acc, dv_acc,
                          *, causal: bool, scale: float, g: int, nq: int):
    qs = pl.program_id(2)

    @pl.when(qs == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    do = do_ref[...].astype(jnp.float32)
    p, ds = _recompute_p_ds(qpos_ref, kpos_ref, qstart_ref, q, k, v, do,
                            m_ref[...], dl_ref[...],
                            causal=causal, scale=scale, g=g)
    # row reductions over the G*bq folded q rows sum the GQA group for free
    dv_acc[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())))
    dk_acc[...] += jax.lax.dot_general(
        ds, q, (((0,), (0,)), ((), ()))) * scale

    @pl.when(qs == nq - 1)
    def _fin():
        dk_ref[...] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[...] = dv_acc[...].astype(dv_ref.dtype)


# ---------------------------------------------------------------------------
# Geometry helpers shared by forward and backward
# ---------------------------------------------------------------------------


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _geometry(Tq: int, S: int, block_q: int, block_k: int):
    bq = min(block_q, _round_up(Tq, 8))
    bk = min(block_k, _round_up(S, 8))
    Tqp, Sp = _round_up(Tq, bq), _round_up(S, bk)
    return bq, bk, Tqp, Sp, Tqp // bq, Sp // bk


def _pad_inputs(q, k, v, q_pos, kv_pos, q_start, Tqp, Sp):
    Tq, S = q.shape[1], k.shape[1]
    if Tqp != Tq:
        q = jnp.pad(q, ((0, 0), (0, Tqp - Tq), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, Tqp - Tq)), constant_values=-1)
        # block-padding query rows are dead: q_start = PAD_POS masks them
        q_start = jnp.pad(q_start, ((0, 0), (0, Tqp - Tq)),
                          constant_values=PAD_POS)
    if Sp != S:
        k = jnp.pad(k, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, (0, Sp - S), constant_values=PAD_POS)
    return q, k, v, q_pos, kv_pos, q_start


def _fold_q_like(x, B, Hkv, G, nq, bq, last):
    """[B, Tqp, H, last] -> [B*Hkv, nq, G*bq, last] (GQA head fold)."""
    return (x.reshape(B, nq, bq, Hkv, G, last)
             .transpose(0, 3, 1, 4, 2, 5)
             .reshape(B * Hkv, nq, G * bq, last))


def _unfold_q_like(x, B, Hkv, G, nq, bq, last, Tq):
    x = x.reshape(B, Hkv, nq, G, bq, last).transpose(0, 2, 4, 1, 3, 5)
    return x.reshape(B, nq * bq, Hkv * G, last)[:, :Tq]


def _fold_kv(x, B, Hkv, Sp, last):
    return x.transpose(0, 2, 1, 3).reshape(B * Hkv, Sp, last)


# ---------------------------------------------------------------------------
# Forward / backward pallas_call wrappers
# ---------------------------------------------------------------------------


def _fwd_impl(q, k, v, q_pos, kv_pos, q_start, causal, scale, block_q,
              block_k, interpret):
    B, Tq, H, hdk = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    hdv = v.shape[-1]
    G = H // Hkv
    bq, bk, Tqp, Sp, nq, nk = _geometry(Tq, S, block_q, block_k)
    q, k, v, q_pos, kv_pos, q_start = _pad_inputs(
        q, k, v, q_pos, kv_pos, q_start, Tqp, Sp)

    qg = _fold_q_like(q, B, Hkv, G, nq, bq, hdk)
    kg = _fold_kv(k, B, Hkv, Sp, hdk)
    vg = _fold_kv(v, B, Hkv, Sp, hdv)

    grid = (B * Hkv, nq, nk)
    kern = functools.partial(_flash_partial_kernel, causal=causal,
                             scale=scale, bq=bq, bk=bk, g=G, nk=nk)
    o, m, l = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            # q_pos and q_start vary per batch row (paged decode gives every
            # row its own position; packed layouts differ row to row): grid
            # axis 0 is B*Hkv, so row = b // Hkv
            pl.BlockSpec((None, bq), lambda b, i, j, Hkv=Hkv: (b // Hkv, i)),
            pl.BlockSpec((bk,), lambda b, i, j: (j,)),                  # kv_pos
            pl.BlockSpec((None, bq), lambda b, i, j, Hkv=Hkv: (b // Hkv, i)),
            pl.BlockSpec((None, None, G * bq, hdk), lambda b, i, j: (b, i, 0, 0)),
            pl.BlockSpec((None, bk, hdk), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((None, bk, hdv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, None, G * bq, hdv), lambda b, i, j: (b, i, 0, 0)),
            pl.BlockSpec((None, None, G * bq, 1), lambda b, i, j: (b, i, 0, 0)),
            pl.BlockSpec((None, None, G * bq, 1), lambda b, i, j: (b, i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * Hkv, nq, G * bq, hdv), jnp.float32),
            jax.ShapeDtypeStruct((B * Hkv, nq, G * bq, 1), jnp.float32),
            jax.ShapeDtypeStruct((B * Hkv, nq, G * bq, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((G * bq, hdv), jnp.float32),   # acc
            pltpu.VMEM((G * bq, 1), jnp.float32),     # running max
            pltpu.VMEM((G * bq, 1), jnp.float32),     # running sum
        ],
        interpret=interpret,
    )(q_pos, kv_pos, q_start, qg, kg, vg)

    o = _unfold_q_like(o, B, Hkv, G, nq, bq, hdv, Tq)
    m = _unfold_q_like(m, B, Hkv, G, nq, bq, 1, Tq)[..., 0]
    l = _unfold_q_like(l, B, Hkv, G, nq, bq, 1, Tq)[..., 0]
    return o, m, l


def _bwd_impl(q, k, v, q_pos, kv_pos, q_start, do, m, dl, causal, scale,
              block_q, block_k, interpret):
    """dq/dk/dv via the two fused backward grids; all accumulation fp32."""
    B, Tq, H, hdk = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    hdv = v.shape[-1]
    G = H // Hkv
    bq, bk, Tqp, Sp, nq, nk = _geometry(Tq, S, block_q, block_k)
    # fully-masked rows (m == NEG_INF) have o == l == 0 identically; their
    # cotangents are meaningless and can be inf/NaN (the 1/l² of the
    # downstream quotient rule overflows fp32) — zero them so 0·NaN can't
    # poison dq/dk through the p·dS products
    live = (m > NEG_INF / 2)
    do = jnp.where(live[..., None], do, 0.0)
    dl = jnp.where(live, dl, 0.0)
    q, k, v, q_pos, kv_pos, q_start = _pad_inputs(
        q, k, v, q_pos, kv_pos, q_start, Tqp, Sp)
    if Tqp != Tq:
        do = jnp.pad(do, ((0, 0), (0, Tqp - Tq), (0, 0), (0, 0)))
        # padded rows get m = NEG_INF: the safe-row guard zeroes their p
        m = jnp.pad(m, ((0, 0), (0, Tqp - Tq), (0, 0)),
                    constant_values=NEG_INF)
        dl = jnp.pad(dl, ((0, 0), (0, Tqp - Tq), (0, 0)))

    qg = _fold_q_like(q, B, Hkv, G, nq, bq, hdk)
    kg = _fold_kv(k, B, Hkv, Sp, hdk)
    vg = _fold_kv(v, B, Hkv, Sp, hdv)
    dog = _fold_q_like(do.astype(jnp.float32), B, Hkv, G, nq, bq, hdv)
    mg = _fold_q_like(m[..., None], B, Hkv, G, nq, bq, 1)
    dlg = _fold_q_like(dl.astype(jnp.float32)[..., None], B, Hkv, G, nq, bq, 1)
    qpos_b = q_pos

    # --- dq: forward's grid, KV innermost, dq accumulates in scratch
    dq = pl.pallas_call(
        functools.partial(_flash_bwd_dq_kernel, causal=causal, scale=scale,
                          g=G, nk=nk),
        grid=(B * Hkv, nq, nk),
        in_specs=[
            pl.BlockSpec((None, bq), lambda b, i, j, Hkv=Hkv: (b // Hkv, i)),
            pl.BlockSpec((bk,), lambda b, i, j: (j,)),
            pl.BlockSpec((None, bq), lambda b, i, j, Hkv=Hkv: (b // Hkv, i)),
            pl.BlockSpec((None, None, G * bq, hdk), lambda b, i, j: (b, i, 0, 0)),
            pl.BlockSpec((None, bk, hdk), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((None, bk, hdv), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((None, None, G * bq, hdv), lambda b, i, j: (b, i, 0, 0)),
            pl.BlockSpec((None, None, G * bq, 1), lambda b, i, j: (b, i, 0, 0)),
            pl.BlockSpec((None, None, G * bq, 1), lambda b, i, j: (b, i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, G * bq, hdk),
                               lambda b, i, j: (b, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hkv, nq, G * bq, hdk),
                                       jnp.float32),
        scratch_shapes=[pltpu.VMEM((G * bq, hdk), jnp.float32)],
        interpret=interpret,
    )(qpos_b, kv_pos, q_start, qg, kg, vg, dog, mg, dlg)

    # --- dk/dv: transposed grid, q innermost, dk/dv accumulate in scratch
    dk, dv = pl.pallas_call(
        functools.partial(_flash_bwd_dkv_kernel, causal=causal, scale=scale,
                          g=G, nq=nq),
        grid=(B * Hkv, nk, nq),
        in_specs=[
            pl.BlockSpec((None, bq), lambda b, j, i, Hkv=Hkv: (b // Hkv, i)),
            pl.BlockSpec((bk,), lambda b, j, i: (j,)),
            pl.BlockSpec((None, bq), lambda b, j, i, Hkv=Hkv: (b // Hkv, i)),
            pl.BlockSpec((None, None, G * bq, hdk), lambda b, j, i: (b, i, 0, 0)),
            pl.BlockSpec((None, bk, hdk), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((None, bk, hdv), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((None, None, G * bq, hdv), lambda b, j, i: (b, i, 0, 0)),
            pl.BlockSpec((None, None, G * bq, 1), lambda b, j, i: (b, i, 0, 0)),
            pl.BlockSpec((None, None, G * bq, 1), lambda b, j, i: (b, i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, None, bk, hdk), lambda b, j, i: (b, j, 0, 0)),
            pl.BlockSpec((None, None, bk, hdv), lambda b, j, i: (b, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * Hkv, nk, bk, hdk), jnp.float32),
            jax.ShapeDtypeStruct((B * Hkv, nk, bk, hdv), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bk, hdk), jnp.float32),
            pltpu.VMEM((bk, hdv), jnp.float32),
        ],
        interpret=interpret,
    )(qpos_b, kv_pos, q_start, qg, kg, vg, dog, mg, dlg)

    dq = _unfold_q_like(dq, B, Hkv, G, nq, bq, hdk, Tq)

    def unfold_kv(x, last):
        return x.reshape(B, Hkv, Sp, last).transpose(0, 2, 1, 3)[:, :S]

    return dq, unfold_kv(dk, hdk), unfold_kv(dv, hdv)


# ---------------------------------------------------------------------------
# custom_vjp wiring
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9, 10))
def _flash_partial(q, k, v, q_pos, kv_pos, q_start, causal, scale, block_q,
                   block_k, interpret):
    return _fwd_impl(q, k, v, q_pos, kv_pos, q_start, causal, scale, block_q,
                     block_k, interpret)


def _flash_partial_fwd(q, k, v, q_pos, kv_pos, q_start, causal, scale,
                       block_q, block_k, interpret):
    o, m, l = _fwd_impl(q, k, v, q_pos, kv_pos, q_start, causal, scale,
                        block_q, block_k, interpret)
    # (q, k, v, positions, o, m, l): the Type-1 residual set the offload
    # planner budgets.  The recompute-based kernels consume only m (o and l
    # alias the primal outputs, so saving them costs nothing extra on
    # device); the planner may still row-split any of them to pinned_host.
    return (o, m, l), (q, k, v, q_pos, kv_pos, q_start, o, m, l)


def _flash_partial_bwd(causal, scale, block_q, block_k, interpret, res, cts):
    q, k, v, q_pos, kv_pos, q_start, _o, m, _l = res
    do, _dm, dl = cts   # the max statistic is gradient-frozen (kernels/ref.py)
    dq, dk, dv = _bwd_impl(q, k, v, q_pos, kv_pos, q_start, do, m, dl,
                           causal, scale, block_q, block_k, interpret)

    def zero_pos(p):    # int positions: cotangent space is float0
        return np.zeros(np.shape(p), jax.dtypes.float0)

    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            zero_pos(q_pos), zero_pos(kv_pos), zero_pos(q_start))


_flash_partial.defvjp(_flash_partial_fwd, _flash_partial_bwd)


def flash_attention_partial(q, k, v, q_pos, kv_pos, *, causal=True,
                            scale=None, block_q=128, block_k=128,
                            interpret=True, q_start=None):
    """Pallas partial flash attention (differentiable in q, k, v).

    q: [B, Tq, H, hd_k]; k: [B, S, Hkv, hd_k]; v: [B, S, Hkv, hd_v]
    q_pos: [Tq] or [B, Tq]; kv_pos: [S]  (2**30 == padding)
    q_start: optional [B, Tq] or [Tq] segment window — kv slots below
    q_start are masked (packed-document blocking); None degenerates to the
    plain positional mask (a zero window changes no visibility bit).
    Returns (o [B,Tq,H,hd_v] f32 un-normalized, m [B,Tq,H] f32, l [B,Tq,H] f32).
    """
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    B, Tq = q.shape[0], q.shape[1]
    if q_pos.ndim == 1:
        q_pos = jnp.broadcast_to(q_pos[None, :], (B, Tq))
    if q_start is None:
        q_start = jnp.zeros((B, Tq), jnp.int32)
    elif q_start.ndim == 1:
        q_start = jnp.broadcast_to(q_start[None, :], (B, Tq))
    return _flash_partial(q, k, v, q_pos, kv_pos, q_start, bool(causal),
                          float(scale), int(block_q), int(block_k),
                          bool(interpret))
