"""Pallas TPU kernel: chunked-causal flash attention with partial-softmax out.

This is the compute hot-spot of SPPO's subsequence processing: the attention
of one subsequence (chunk) of queries against the device-local shard of the
accumulated KV cache (all previous chunks + the current one).  Causality
across chunks is positional: visibility is ``q_pos >= kv_pos`` on *global*
token positions, so the same kernel serves intra-chunk causal attention,
cross-chunk cache attention, decode (Tq == 1 padded to a block) and
bidirectional encoder attention (causal=False).

TPU mapping (target: v5e — MXU 128x128, ~16 MiB VMEM/core):
  grid = (B * Hkv, Tq // bq, S // bk) with the KV dimension innermost
  ("arbitrary" semantics) so the (m, l, acc) accumulators live in VMEM
  scratch across KV steps.  Block shapes default to (bq=128, bk=128) * G
  query rows — q rows for all G grouped query heads of one KV head are
  folded into the q-block row dimension, so GQA costs no extra KV traffic:
  the [bk, hd] KV block is streamed once per q block for all G heads.

VMEM budget at defaults (bq=128, bk=128, hd=128, G<=8, fp32 accum):
  q (G*128*128*4) + k/v (2*128*128*4) + acc (G*128*128*4) + p (G*128*128*4)
  ~= 3.3 MiB at G=8 — comfortably inside 16 MiB with double buffering.

Outputs are the *partial* (o, m, l) triple (see kernels/ref.py) so the
cross-device softmax merge (psum over the `model` axis) composes with the
kernel unchanged.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_partial_kernel(qpos_ref, kpos_ref,     # prefetch-style position blocks
                          q_ref, k_ref, v_ref,    # [bq*G, hd] / [bk, hd] blocks
                          o_ref, m_ref, l_ref,    # outputs
                          acc_ref, mm_ref, ll_ref,  # VMEM scratch
                          *, causal: bool, scale: float, bq: int, bk: int,
                          g: int, nk: int):
    ks = pl.program_id(2)

    @pl.when(ks == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        mm_ref[...] = jnp.full_like(mm_ref, NEG_INF)
        ll_ref[...] = jnp.zeros_like(ll_ref)

    q = q_ref[...].astype(jnp.float32)          # [G*bq, hd]
    k = k_ref[...].astype(jnp.float32)          # [bk, hd]
    v = v_ref[...].astype(jnp.float32)          # [bk, hv]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # [G*bq, bk]

    qpos = qpos_ref[...]                        # [bq] int32
    kpos = kpos_ref[...]                        # [bk] int32
    qpos_g = jnp.tile(qpos, (g,))               # [G*bq] — heads share positions
    valid = (kpos[None, :] != 2**30)
    if causal:
        valid = valid & (qpos_g[:, None] >= kpos[None, :])
    s = jnp.where(valid, s, NEG_INF)

    m_prev = mm_ref[...]                        # [G*bq, 1]
    m_blk = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_blk)
    safe = m_new > NEG_INF / 2
    alpha = jnp.where(safe, jnp.exp(m_prev - m_new), 0.0)
    p = jnp.where(safe, jnp.exp(s - m_new), 0.0)
    ll_ref[...] = ll_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    mm_ref[...] = m_new

    @pl.when(ks == nk - 1)
    def _fin():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)
        m_ref[...] = mm_ref[...].astype(m_ref.dtype)
        l_ref[...] = ll_ref[...].astype(l_ref.dtype)


def flash_attention_partial(q, k, v, q_pos, kv_pos, *, causal=True,
                            scale=None, block_q=128, block_k=128,
                            interpret=True):
    """Pallas partial flash attention.

    q: [B, Tq, H, hd_k]; k: [B, S, Hkv, hd_k]; v: [B, S, Hkv, hd_v]
    q_pos: [Tq] or [B, Tq]; kv_pos: [S]  (2**30 == padding)
    Returns (o [B,Tq,H,hd_v] f32 un-normalized, m [B,Tq,H] f32, l [B,Tq,H] f32).
    """
    B, Tq, H, hdk = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    hdv = v.shape[-1]
    G = H // Hkv
    if scale is None:
        scale = 1.0 / (hdk ** 0.5)
    if q_pos.ndim == 2:
        # kernel assumes positions shared across batch; models pass [Tq]
        q_pos = q_pos[0]

    bq = min(block_q, _round_up(Tq, 8))
    bk = min(block_k, _round_up(S, 8))
    Tqp = _round_up(Tq, bq)
    Sp = _round_up(S, bk)
    nq, nk = Tqp // bq, Sp // bk

    if Tqp != Tq:
        q = jnp.pad(q, ((0, 0), (0, Tqp - Tq), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, Tqp - Tq), constant_values=-1)
    if Sp != S:
        k = jnp.pad(k, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, (0, Sp - S), constant_values=2**30)

    # fold grouped heads into q block rows: [B*Hkv, nq, G*bq, hd]
    qg = (q.reshape(B, Tqp // bq, bq, Hkv, G, hdk)
           .transpose(0, 3, 1, 4, 2, 5)
           .reshape(B * Hkv, Tqp // bq, G * bq, hdk))
    kg = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Sp, hdk)
    vg = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Sp, hdv)

    grid = (B * Hkv, nq, nk)
    kern = functools.partial(_flash_partial_kernel, causal=causal,
                             scale=scale, bq=bq, bk=bk, g=G, nk=nk)
    o, m, l = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bq), lambda b, i, j: (0, i)),          # q_pos
            pl.BlockSpec((bk,), lambda b, i, j: (j,)),                  # kv_pos
            pl.BlockSpec((None, None, G * bq, hdk), lambda b, i, j: (b, i, 0, 0)),
            pl.BlockSpec((None, bk, hdk), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((None, bk, hdv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, None, G * bq, hdv), lambda b, i, j: (b, i, 0, 0)),
            pl.BlockSpec((None, None, G * bq, 1), lambda b, i, j: (b, i, 0, 0)),
            pl.BlockSpec((None, None, G * bq, 1), lambda b, i, j: (b, i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * Hkv, nq, G * bq, hdv), jnp.float32),
            jax.ShapeDtypeStruct((B * Hkv, nq, G * bq, 1), jnp.float32),
            jax.ShapeDtypeStruct((B * Hkv, nq, G * bq, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((G * bq, hdv), jnp.float32),   # acc
            pltpu.VMEM((G * bq, 1), jnp.float32),     # running max
            pltpu.VMEM((G * bq, 1), jnp.float32),     # running sum
        ],
        interpret=interpret,
    )(jnp.broadcast_to(q_pos[None, :], (1, Tqp)), kv_pos, qg, kg, vg)

    # unfold: [B*Hkv, nq, G*bq, hv] -> [B, Tq, H, hv]
    def unfold(x, last):
        x = x.reshape(B, Hkv, nq, G, bq, last).transpose(0, 2, 4, 1, 3, 5)
        return x.reshape(B, Tqp, H, last)[:, :Tq]

    o = unfold(o, hdv)
    m = unfold(m, 1)[..., 0]
    l = unfold(l, 1)[..., 0]
    return o, m, l


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m
