"""Jitted wrappers / dispatch for the Pallas kernels.

On the CPU container the models execute the blockwise-jnp reference path
(fast to compile, identical math); setting ``REPRO_USE_PALLAS=1`` (or calling
``set_backend("pallas")``) routes attention through the Pallas kernel in
interpret mode — on real TPU the Pallas path is the default.
"""
from __future__ import annotations

import contextlib
import os

import jax

from repro.kernels import ref as _ref
from repro.kernels import flash_attention as _fa

_BACKEND = os.environ.get("REPRO_USE_PALLAS", "0") == "1" and "pallas" or "jnp"


def set_backend(name: str) -> None:
    global _BACKEND
    assert name in ("jnp", "pallas")
    _BACKEND = name


def get_backend() -> str:
    return _BACKEND


@contextlib.contextmanager
def backend(name: str):
    """Scoped backend switch: ``with kops.backend("pallas"): ...``.

    Restores the previous global on exit (exception-safe), so tests can flip
    jnp<->pallas without leaking state across modules.  The flag is read at
    trace time — re-trace (fresh ``jax.jit``) inside the block to take
    effect on jitted callables.
    """
    prev = _BACKEND
    set_backend(name)
    try:
        yield name
    finally:
        set_backend(prev)


def attention_partial(q, k, v, q_pos, kv_pos, *, causal=True, scale=None,
                      block_k=512, q_start=None):
    """Partial flash attention against a local KV shard (see kernels/ref.py).

    Dispatches to the Pallas kernel (TPU target / interpret on CPU) or the
    blockwise-jnp path by backend flag.  Both return identical (o, m, l) and
    both differentiate in (q, k, v) — the Pallas path via the fused backward
    kernels' custom_vjp, the jnp path via autodiff of the blockwise scan —
    with the max statistic m gradient-frozen on both.  ``q_start`` is the
    optional per-query segment window ([B,Tq] or [Tq] int32): only kv slots
    with kv_pos >= q_start are visible (packed-document blocking).
    """
    if _BACKEND == "pallas":
        on_tpu = jax.default_backend() == "tpu"
        return _fa.flash_attention_partial(
            q, k, v, q_pos, kv_pos, causal=causal, scale=scale,
            q_start=q_start, interpret=not on_tpu)
    return _ref.attention_partial_ref(
        q, k, v, q_pos, kv_pos, causal=causal, scale=scale, block_k=block_k,
        q_start=q_start)
