"""Pure-jnp oracles for the Pallas kernels.

``attention_partial_ref`` is both the correctness oracle for the Pallas flash
kernel and the CPU execution path for the models (blockwise, memory-safe —
never materializes the full score matrix).

Partial-softmax convention (flash-decoding style): given queries and a *local*
KV shard, return
    m   = row max of masked scores                  [B, H, Tq]   (fp32)
    l   = sum exp(s - m)                            [B, H, Tq]   (fp32)
    o   = sum exp(s - m) * V  (un-normalized)       [B, Tq, H, hd_v] (fp32)
so shards merge exactly: with M = max_r m_r,
    out = sum_r exp(m_r - M) o_r / sum_r exp(m_r - M) l_r.
Masking is positional: a KV slot with position kv_pos[j] is visible to query
position q_pos[i] iff (not causal or q_pos[i] >= kv_pos[j]) and
kv_pos[j] != PAD_POS.  PAD_POS marks empty cache slots.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

PAD_POS = jnp.int32(2**30)
NEG_INF = -1e30


def attention_partial_ref(q, k, v, q_pos, kv_pos, *, causal=True,
                          scale=None, block_k=512, q_start=None):
    """q: [B,Tq,H,hd_k]; k: [B,S,Hkv,hd_k]; v: [B,S,Hkv,hd_v];
    q_pos: [B,Tq] or [Tq] int32; kv_pos: [S] int32 (PAD_POS = invalid);
    q_start: optional [B,Tq] or [Tq] int32 segment window — query i sees only
    kv slots with kv_pos >= q_start[i] (packed documents never attend across
    boundaries; PAD_POS rows are fully masked).

    Returns (o [B,Tq,H,hd_v] fp32 un-normalized, m [B,Tq,H] fp32, l [B,Tq,H] fp32).
    """
    B, Tq, H, hdk = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    hdv = v.shape[-1]
    G = H // Hkv
    if scale is None:
        scale = 1.0 / (hdk ** 0.5)
    if q_pos.ndim == 1:
        q_pos = jnp.broadcast_to(q_pos[None, :], (B, Tq))
    if q_start is not None and q_start.ndim == 1:
        q_start = jnp.broadcast_to(q_start[None, :], (B, Tq))

    # pad S to a block multiple
    nb = max(1, -(-S // block_k))
    Sp = nb * block_k
    if Sp != S:
        k = jnp.pad(k, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, (0, Sp - S), constant_values=2**30)

    qf = q.astype(jnp.float32).reshape(B, Tq, Hkv, G, hdk)
    kb = k.astype(jnp.float32).reshape(B, nb, block_k, Hkv, hdk)
    vb = v.astype(jnp.float32).reshape(B, nb, block_k, Hkv, hdv)
    pb = kv_pos.reshape(nb, block_k)

    def step(carry, blk):
        m, l, acc = carry
        kblk, vblk, pblk = blk
        s = jnp.einsum("btkgh,bskh->btkgs", qf, kblk) * scale  # [B,Tq,Hkv,G,bk]
        valid = pblk[None, None, None, None, :] != 2**30
        if causal:
            valid = valid & (q_pos[:, :, None, None, None]
                             >= pblk[None, None, None, None, :])
        if q_start is not None:
            valid = valid & (pblk[None, None, None, None, :]
                             >= q_start[:, :, None, None, None])
        s = jnp.where(valid, s, NEG_INF)
        m_blk = jnp.max(s, axis=-1)
        # the max statistic is gradient-frozen (jax.nn.softmax-style): its
        # contribution cancels exactly in the o/l ratio, and freezing it
        # keeps cross-device merges (pmax has no VJP) differentiable.
        m_new = jax.lax.stop_gradient(jnp.maximum(m, m_blk))
        # guard fully-masked rows (m_new == NEG_INF): exp(NEG_INF-NEG_INF)=1 bad
        safe = m_new > NEG_INF / 2
        alpha = jnp.where(safe, jnp.exp(m - m_new), 0.0)
        p = jnp.where(safe[..., None], jnp.exp(s - m_new[..., None]), 0.0)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("btkgs,bskv->btkgv", p, vblk)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Tq, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Tq, Hkv, G), jnp.float32)
    a0 = jnp.zeros((B, Tq, Hkv, G, hdv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), pb))
    o = acc.reshape(B, Tq, H, hdv)
    return o, m.reshape(B, Tq, H), l.reshape(B, Tq, H)


def merge_partials(parts):
    """Merge a list of (o, m, l) partials (single-device oracle for the
    cross-shard psum merge).

    Gradient contract: the max statistics are frozen (as in the kernels —
    the rescale factors exp(m_r − M) carry no gradient; their m-dependence
    cancels exactly in the o/l ratio), so dq/dk/dv flow entirely through the
    o_r and l_r terms.  The explicit stop_gradient makes the merge
    differentiable even for partials whose m was *not* already detached
    (e.g. hand-built oracle partials in tests) and mirrors the device merge,
    where pmax has no VJP.  tests/test_kernel_grads.py finite-differences
    this: the winning block's dq must not be frozen."""
    ms = jax.lax.stop_gradient(jnp.stack([p[1] for p in parts]))
    m = jnp.max(ms, axis=0)
    o = sum(p[0] * jnp.exp(jax.lax.stop_gradient(p[1]) - m)[:, :, :, None]
            for p in parts)
    l = sum(p[2] * jnp.exp(jax.lax.stop_gradient(p[1]) - m) for p in parts)
    return o, m, l


def normalize(o, l):
    return (o / jnp.maximum(l, 1e-30)[:, :, :, None])


def mha_reference(q, k, v, q_pos, kv_pos, *, causal=True, scale=None,
                  q_start=None):
    """Naive full attention (small shapes only) — oracle for the oracle."""
    B, Tq, H, hdk = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    if scale is None:
        scale = 1.0 / (hdk ** 0.5)
    if q_pos.ndim == 1:
        q_pos = jnp.broadcast_to(q_pos[None, :], (B, Tq))
    if q_start is not None and q_start.ndim == 1:
        q_start = jnp.broadcast_to(q_start[None, :], (B, Tq))
    qf = q.astype(jnp.float32).reshape(B, Tq, Hkv, G, hdk)
    s = jnp.einsum("btkgh,bskh->btkgs", qf, k.astype(jnp.float32)) * scale
    valid = (kv_pos != 2**30)[None, None, None, None, :]
    if causal:
        valid = valid & (q_pos[:, :, None, None, None] >= kv_pos[None, None, None, None, :])
    if q_start is not None:
        valid = valid & (kv_pos[None, None, None, None, :]
                        >= q_start[:, :, None, None, None])
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.all(~valid, axis=-1, keepdims=True), 0.0, p)
    o = jnp.einsum("btkgs,bskv->btkgv", p, v.astype(jnp.float32))
    return o.reshape(B, Tq, H, v.shape[-1])
