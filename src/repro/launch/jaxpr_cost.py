"""Jaxpr-level collective accounting: exact, dtype-faithful, backend-free.

Walks a closed jaxpr (of the *differentiated, full* step function), summing
operand bytes of every collective primitive, recursing into sub-jaxprs with
structural multipliers:
  * scan  -> x length (trip count)
  * while -> x1 (no static trip; SPPO programs use scan everywhere)
  * cond  -> max over branches
  * pjit / remat / custom_* / shard_map -> x1 (bodies appear as written;
    the differentiated jaxpr already contains the replayed remat forwards)

This sidesteps two XLA-CPU artifacts that poison compiled-HLO accounting:
bf16 collective reductions promoted to f32, and scan bodies counted once.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Any, Dict

import jax
import numpy as np

COLLECTIVE_PRIMS = {
    "psum": "all-reduce",
    "psum2": "all-reduce",
    "all_gather": "all-gather",
    "psum_scatter": "reduce-scatter",
    "reduce_scatter": "reduce-scatter",
    "all_to_all": "all-to-all",
    "ppermute": "collective-permute",
    "pmax": "all-reduce",
    "pmin": "all-reduce",
}

# per-device link traffic of ring algorithms, as a multiple of the *input*
# bytes, given group size n:
#   all-gather: output = n x input, ring moves (n-1) x input per device
#   all-reduce: 2 (n-1)/n x input;  reduce-scatter: (n-1)/n x input
#   all-to-all: (n-1)/n x input;    ppermute: 1 x input


def _ring_factor(kind: str, n: int) -> float:
    if n <= 1:
        return 0.0
    if kind == "all-gather":
        return float(n - 1)
    if kind == "all-reduce":
        return 2.0 * (n - 1) / n
    if kind in ("reduce-scatter", "all-to-all"):
        return float(n - 1) / n
    return 1.0  # collective-permute


def _aval_bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:  # noqa
        return 0


def _group_size(eqn, axis_sizes: Dict[str, int]) -> int:
    gs = eqn.params.get("axis_index_groups")
    if gs:
        return len(gs[0])
    names = eqn.params.get("axis_name", ())
    if not isinstance(names, (tuple, list)):
        names = (names,)
    n = 1
    for nm in names:
        n *= axis_sizes.get(nm, 1)
    return n


def _walk(jaxpr, acc: Dict[str, float], mult: float,
          axis_sizes: Dict[str, int]) -> None:
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name in COLLECTIVE_PRIMS:
            kind = COLLECTIVE_PRIMS[name]
            b = sum(_aval_bytes(v.aval) for v in eqn.invars
                    if hasattr(v, "aval"))
            n = _group_size(eqn, axis_sizes)
            acc[kind] += b * mult * _ring_factor(kind, n)
            acc["_count"] += mult
            continue
        # recurse into sub-jaxprs
        submult = mult
        if name == "scan":
            submult = mult * eqn.params.get("length", 1)
        elif name == "while":
            submult = mult  # unknown trip; SPPO uses scan for loops
        for pname, p in eqn.params.items():
            stack = [p]
            while stack:
                q = stack.pop()
                if isinstance(q, (list, tuple)):
                    stack.extend(q)
                elif isinstance(q, jax.extend.core.ClosedJaxpr):
                    _walk(q.jaxpr, acc, submult, axis_sizes)
                elif hasattr(q, "eqns") and hasattr(q, "invars"):
                    _walk(q, acc, submult, axis_sizes)


def collective_bytes(fn, *args, axis_sizes: Dict[str, int] = None
                     ) -> Dict[str, Any]:
    """Trace fn(*args) (ShapeDtypeStructs fine) and count per-device link
    traffic of every collective (ring-algorithm model), with exact scan
    multipliers and true jaxpr dtypes."""
    axis_sizes = axis_sizes or {"model": 16, "data": 16, "pod": 2}
    closed = jax.make_jaxpr(fn)(*args)
    acc: Dict[str, float] = defaultdict(float)
    _walk(closed.jaxpr, acc, 1.0, axis_sizes)
    count = acc.pop("_count", 0.0)
    return {"kinds": dict(acc), "total": sum(acc.values()),
            "ops_weighted": count}
