"""Batched serving driver: prefill + decode loop on a (test) mesh.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --reduced \
      --mesh 2x2 --prompt-len 128 --batch 4 --decode-steps 16

Exercises the same prefill_step/serve_step the dry-run lowers, with real
values: prefill builds the position-tagged, sequence-sharded cache; decode
appends striped slots and samples greedily.
"""
from __future__ import annotations

import argparse
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.configs.base import ShapeConfig, get_config
from repro.launch.mesh import make_test_mesh
from repro.launch.train import build_params
from repro.models.model_zoo import build_model
from repro.parallel.runner import (batch_struct, make_prefill_step,
                                   make_serve_step, resolve_cell)

log = logging.getLogger("repro.serve")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--decode-steps", type=int, default=8)
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")

    data_size, model_size = (int(x) for x in args.mesh.split("x"))
    mesh = make_test_mesh(data_size, model_size)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mdef = build_model(cfg)

    S = args.prompt_len
    pre_shape = ShapeConfig("cli_prefill", S, args.batch, "prefill")
    dec_shape = ShapeConfig("cli_decode", S, args.batch, "decode")
    pre_cell = resolve_cell(mdef, pre_shape, data_size=data_size,
                            model_size=model_size,
                            overrides=dict(pp=1, dp=data_size,
                                           n_chunks=max(1, S // 64),
                                           offload=False, remat="none"))
    dec_cell = resolve_cell(mdef, dec_shape, data_size=data_size,
                            model_size=model_size,
                            overrides=dict(pp=1, dp=data_size))

    params, _, _ = build_params(pre_cell, mesh)
    prefill, _, _ = make_prefill_step(pre_cell, mesh)
    serve, _, _ = make_serve_step(dec_cell, mesh)
    prefill = jax.jit(prefill)
    serve = jax.jit(serve, donate_argnums=(1,))

    rng = np.random.default_rng(0)
    prompts = rng.integers(2, cfg.vocab_size,
                           size=(args.batch, S)).astype(np.int32)
    bstruct, bspecs = batch_struct(pre_cell)
    b_loc = pre_cell.b_loc
    tok = np.stack([prompts[(i // pre_cell.plan.pp) * b_loc:
                            (i // pre_cell.plan.pp) * b_loc + b_loc]
                    for i in range(data_size)])[None]
    batch = {"tokens": jnp.asarray(tok), "labels": jnp.asarray(tok)}
    if cfg.cross_attn is not None:
        n_ctx = (cfg.n_frames if cfg.encoder_layers
                 else cfg.cross_attn.n_context_tokens)
        n_pad = -(-n_ctx // model_size) * model_size
        batch["context"] = jnp.asarray(
            rng.standard_normal((1, data_size, b_loc, n_pad, cfg.d_model))
            * 0.02, jnp.bfloat16)
    batch = {k: jax.device_put(v, NamedSharding(mesh, bspecs[k]))
             for k, v in batch.items() if k in bspecs}

    t0 = time.time()
    state, last_hidden = prefill(params, batch)
    log.info("prefill %d tokens x %d seqs in %.2fs", S, args.batch,
             time.time() - t0)

    # NOTE: prefill and decode cells share cache geometry because
    # resolve_cell sizes the cache from the shape's seq_len + decode budget.
    toks = []
    cur = jnp.asarray(prompts[:, -1:])  # last prompt token (already in cache)
    for step in range(args.decode_steps):
        pos = jnp.int32(S + step)
        dbatch = {"tokens": jnp.asarray(
            np.stack([np.asarray(cur)[(i // dec_cell.plan.pp) * b_loc:
                                      (i // dec_cell.plan.pp) * b_loc + b_loc]
                      for i in range(data_size)])[None]),
            "pos": pos}
        state, nxt = serve(params, state, dbatch)
        # nxt: [data, B_loc, 1]; row i holds dp-group (i // pp)'s shard
        arr = np.asarray(nxt)
        pp = dec_cell.plan.pp
        rows = [arr[g * pp + (pp - 1), :, 0] for g in range(dec_cell.plan.dp)]
        cur = jnp.asarray(np.concatenate(rows)[:args.batch, None])
        toks.append(np.asarray(cur)[:, 0])
    out = np.stack(toks, axis=1)
    log.info("decoded %s tokens/seq; sample row: %s", out.shape[1],
             out[0][:16])
    return out


if __name__ == "__main__":
    main()
