"""Serving drivers: static lock-step decode and continuous batching.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --reduced \
      --mesh 2x2 --prompt-len 128 --batch 4 --decode-steps 16

Two engines over the same step functions:

  * the **static** CLI path (``main``): one prefill, then lock-step
    ``serve_step`` decode of a fixed batch — every request the same length,
    a private maximum-length cache row each;
  * ``ServeEngine``: a request-level scheduler over the paged KV pool
    (``runtime/kvpool.py``) — prompts right-aligned into a fixed bucket,
    per-request block tables, admission into freed slots mid-flight, and a
    decode loop that never syncs the host (sampled tokens feed back
    device-to-device; per-step handles are demuxed once at the end).
    ``mode="static"`` runs the same engine with admission barriered on an
    empty pool, which is the lock-step baseline the continuous scheduler is
    benchmarked against (token streams are bitwise identical by
    construction — the per-row compute does not depend on co-residents).
"""
from __future__ import annotations

import argparse
import logging
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ShapeConfig, get_config
from repro.launch.mesh import make_test_mesh
from repro.launch.train import build_params
from repro.models.model_zoo import build_model
from repro.parallel.runner import (batch_struct, make_pool_ingest,
                                   make_pool_serve_step, make_pool_state,
                                   make_prefill_step, make_serve_step,
                                   resolve_cell)
from repro.runtime import kvpool

log = logging.getLogger("repro.serve")


def shard_rows(arr: np.ndarray, dp: int, pp: int) -> np.ndarray:
    """[batch, ...] -> [1, dp*pp, b_loc, ...]: the decode batch layout.

    Data row i belongs to dp group i // pp; every stage row of a group
    carries the group's batch shard (stages need the same tokens).  Exact:
    batch must divide by dp.
    """
    batch = arr.shape[0]
    if batch % dp != 0:
        raise ValueError(
            f"batch {batch} does not divide by dp {dp}: the per-shard rows "
            "would truncate or duplicate requests")
    b_loc = batch // dp
    rows = np.stack([arr[(i // pp) * b_loc:(i // pp + 1) * b_loc]
                     for i in range(dp * pp)])
    return rows[None]


def gather_decode_tokens(nxt: np.ndarray, dp: int, pp: int,
                         batch: int) -> np.ndarray:
    """[dp*pp, b_loc, 1] serve_step output -> [batch] tokens, shape-exact.

    Inverse of ``shard_rows``: take each dp group's (replicated) stage rows
    once, in group order.  Raises instead of silently dropping or
    duplicating rows when the shapes disagree.
    """
    n_rows, b_loc = nxt.shape[0], nxt.shape[1]
    if n_rows != dp * pp:
        raise ValueError(f"expected {dp * pp} data rows, got {n_rows}")
    if b_loc * dp != batch:
        raise ValueError(
            f"{dp} groups x {b_loc} rows/group = {dp * b_loc} requests, "
            f"caller expects {batch}")
    return np.concatenate([nxt[g * pp + (pp - 1), :, 0] for g in range(dp)])


# ---------------------------------------------------------------------------
# Continuous-batching engine
# ---------------------------------------------------------------------------


@dataclass
class Request:
    """One decode request: token prompt + a fixed decode length.

    ``arrival`` is the earliest engine step the request may be admitted at
    (0 = present from the start).  Completion is by fixed length — EOS-based
    early exit would need a host read of the sampled token and is left as
    future work (DESIGN.md §16).
    """

    rid: int
    prompt: np.ndarray
    max_new: int
    arrival: int = 0


@dataclass
class RunStats:
    """Host-side accounting of one ``ServeEngine.run``."""

    steps: int = 0              # decode device steps launched
    waves: int = 0              # admission waves (each costs one prefill)
    wall_s: float = 0.0         # loop wall time, including the final sync
    pool_bytes: int = 0         # measured per-rank pool device bytes
    spans: Dict[int, Tuple[int, int]] = field(default_factory=dict)
    peak_blocks: List[int] = field(default_factory=list)   # per data shard
    total_blocks: List[int] = field(default_factory=list)  # per data shard


class ServeEngine:
    """Request-level continuous-batching scheduler over the paged KV pool.

    Fixed geometry per engine: ``slots`` request slots per data shard, a
    ``s_bucket``-token right-aligned prompt bucket, and ``max_new`` decode
    budget.  Admission allocates a request's blocks wholesale and prefills
    the wave's prompts in the batch rows of their target slots (identity
    ingest); eviction returns the blocks.  The decode loop pushes host
    state (positions, block tables, admission masks) down every step and
    threads sampled tokens device-to-device — it never blocks on a device
    value until the final demux.
    """

    def __init__(self, arch, mesh, *, s_bucket: int, slots: int,
                 max_new: int, block_tokens: int = 8,
                 n_blocks: Optional[int] = None, admit_min_free: int = 2,
                 reduced: bool = False, params=None):
        cfg = get_config(arch) if isinstance(arch, str) else arch
        if reduced:
            cfg = cfg.reduced()
        self.mdef = build_model(cfg)
        self.cfg = self.mdef.cfg
        self.mesh = mesh
        self.data_size = mesh.shape["data"]
        self.model_size = mesh.shape["model"]
        self.slots = slots
        self.admit_min_free = admit_min_free
        kg = slots * self.data_size

        pre_shape = ShapeConfig("engine_prefill", s_bucket, kg, "prefill")
        dec_shape = ShapeConfig("engine_decode", s_bucket, kg, "decode")
        ovr = dict(pp=1, dp=self.data_size)
        self.pre_cell = resolve_cell(
            self.mdef, pre_shape, data_size=self.data_size,
            model_size=self.model_size,
            overrides=dict(n_chunks=max(1, s_bucket // 64),
                           offload=False, remat="none", **ovr))
        self.dec_cell = resolve_cell(
            self.mdef, dec_shape, data_size=self.data_size,
            model_size=self.model_size, overrides=dict(ovr))

        dec_loc = -(-max_new // self.model_size)
        l_loc = s_bucket // self.model_size + dec_loc
        max_blocks = -(-l_loc // block_tokens)
        self.geo = kvpool.PoolGeometry(
            s_bucket=s_bucket, sp=self.model_size, max_new=max_new,
            block_tokens=block_tokens,
            n_blocks=slots * max_blocks if n_blocks is None else n_blocks,
            n_slots=slots)
        self.pos_map = kvpool.pos_map(self.geo, self.pre_cell.sched)

        if params is None:
            params, _, _ = build_params(self.pre_cell, mesh)
        self.params = params
        self._prefill = jax.jit(make_prefill_step(self.pre_cell, mesh)[0])
        self._ingest = jax.jit(make_pool_ingest(self.pre_cell, self.geo,
                                                mesh),
                               donate_argnums=(1,))
        self._step = jax.jit(make_pool_serve_step(self.dec_cell, self.geo,
                                                  mesh, self.pos_map),
                             donate_argnums=(1,))
        _, self._pre_bspecs = batch_struct(self.pre_cell)
        self._io = NamedSharding(mesh, P(None, "data"))

    # ----- helpers ---------------------------------------------------------
    def _put(self, arr: np.ndarray):
        # transfer-lint: ok (request ingestion, host->device input staging)
        return jax.device_put(jnp.asarray(arr)[None], self._io)

    def pool_device_bytes(self, pool) -> int:
        """Measured pool bytes on one (data, model) rank."""
        total = sum(int(a.nbytes)
                    for a in jax.tree_util.tree_leaves(pool))
        return total // self.data_size

    def predicted_pool_bytes(self) -> int:
        """Cost-model prediction of per-rank pool bytes (Type-0 channel)."""
        spp = self.mdef.slots_per_stage(1)
        itemsize = jnp.dtype(self.dec_cell.dtype).itemsize
        return self.geo.pool_bytes(self.cfg, n_layers=spp,
                                   itemsize=itemsize)

    # ----- scheduler -------------------------------------------------------
    def run(self, requests: Sequence[Request], mode: str = "continuous"
            ) -> Tuple[Dict[int, np.ndarray], RunStats]:
        """Decode every request; returns ({rid: tokens}, stats).

        ``mode="continuous"``: admit into freed slots mid-flight whenever at
        least ``admit_min_free`` slots are free (or the engine is idle).
        ``mode="static"``: admit only when *all* slots are free — the
        lock-step baseline.  Token streams are identical across modes.
        """
        assert mode in ("continuous", "static"), mode
        geo, d_size, k_slots = self.geo, self.data_size, self.slots
        for r in requests:
            if not 1 <= len(r.prompt) <= geo.s_bucket:
                raise ValueError(
                    f"request {r.rid}: prompt length {len(r.prompt)} not in "
                    f"[1, {geo.s_bucket}]")
            if not 1 <= r.max_new <= geo.max_new:
                raise ValueError(
                    f"request {r.rid}: max_new {r.max_new} not in "
                    f"[1, {geo.max_new}]")
        queue = sorted(requests, key=lambda r: (r.arrival, r.rid))
        pools = [kvpool.BlockPool(geo.n_blocks) for _ in range(d_size)]
        active: Dict[Tuple[int, int], dict] = {}
        qp = np.zeros((d_size, k_slots), np.int32)
        btab = np.full((d_size, k_slots, geo.max_blocks), -1, np.int32)
        pool, _ = make_pool_state(self.dec_cell, geo, self.mesh)
        tokens = self._put(np.zeros((d_size, k_slots, 1), np.int32))
        handles, traces = [], []
        stats = RunStats()
        stats.pool_bytes = self.pool_device_bytes(pool)
        t0 = time.time()
        t = 0
        qi = 0
        while qi < len(queue) or active:
            if qi < len(queue) and not active \
                    and queue[qi].arrival > t:
                t = queue[qi].arrival  # idle gap: jump to the next arrival
            free = [(d, k) for d in range(d_size) for k in range(k_slots)
                    if (d, k) not in active]
            n_avail = 0
            while qi + n_avail < len(queue) \
                    and queue[qi + n_avail].arrival <= t:
                n_avail += 1
            gate = (not active) if mode == "static" else (
                not active or len(free) >= self.admit_min_free)
            admit = np.zeros((d_size, k_slots), bool)
            atok = np.zeros((d_size, k_slots, 1), np.int32)
            if n_avail and free and gate:
                prompt_rows = np.zeros(
                    (d_size, k_slots, geo.s_bucket), np.int32)
                for (d, k) in free[:n_avail]:
                    r = queue[qi]
                    qi += 1
                    blocks = pools[d].alloc(geo.blocks_for(r.max_new))
                    btab[d, k] = kvpool.block_table_row(geo, blocks)
                    p = np.asarray(r.prompt, np.int32)
                    prompt_rows[d, k, geo.s_bucket - len(p):] = p
                    admit[d, k] = True
                    atok[d, k, 0] = p[-1]
                    qp[d, k] = geo.s_bucket
                    active[(d, k)] = dict(rid=r.rid, left=r.max_new,
                                          emitted=0, blocks=blocks)
                    stats.spans[r.rid] = (t, -1)
                pb = {"tokens": self._put(prompt_rows),
                      "labels": self._put(prompt_rows)}
                # transfer-lint: ok (prefill batch staging onto the mesh)
                pb = {k_: jax.device_put(
                    v, NamedSharding(self.mesh, self._pre_bspecs[k_]))
                    for k_, v in pb.items() if k_ in self._pre_bspecs}
                state_pre, _ = self._prefill(self.params, pb)
                pool = self._ingest(state_pre, pool, self._put(btab),
                                    self._put(admit))
                stats.waves += 1
            batch = {"tokens": tokens, "q_pos": self._put(qp),
                     "btab": self._put(btab), "admit": self._put(admit),
                     "admit_tok": self._put(atok)}
            pool, nxt = self._step(self.params, pool, batch)
            tokens = nxt[None]
            handles.append(nxt)
            traces.append([(d, k, st["rid"], st["emitted"])
                           for (d, k), st in active.items()])
            stats.steps += 1
            for (d, k) in list(active):
                st = active[(d, k)]
                st["emitted"] += 1
                st["left"] -= 1
                qp[d, k] += 1
                if st["left"] == 0:
                    pools[d].free(st["blocks"])
                    btab[d, k] = -1
                    qp[d, k] = 0
                    stats.spans[st["rid"]] = (stats.spans[st["rid"]][0],
                                              t + 1)
                    del active[(d, k)]
            t += 1
        out = {r.rid: np.zeros(r.max_new, np.int32) for r in requests}
        for h, emits in zip(handles, traces):  # single end-of-run sync
            arr = np.asarray(h)
            for d, k, rid, i in emits:
                out[rid][i] = arr[d, k, 0]
        stats.wall_s = time.time() - t0
        stats.peak_blocks = [p.peak_used for p in pools]
        stats.total_blocks = [p.total_allocated for p in pools]
        return out, stats


# ---------------------------------------------------------------------------
# Static CLI path
# ---------------------------------------------------------------------------


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--decode-steps", type=int, default=8)
    ap.add_argument("--continuous", action="store_true",
                    help="decode through the paged-pool ServeEngine instead "
                         "of the static lock-step path")
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")

    data_size, model_size = (int(x) for x in args.mesh.split("x"))
    mesh = make_test_mesh(data_size, model_size)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mdef = build_model(cfg)
    S = args.prompt_len

    rng = np.random.default_rng(0)
    prompts = rng.integers(2, cfg.vocab_size,
                           size=(args.batch, S)).astype(np.int32)

    if args.continuous:
        if args.batch % data_size != 0:
            raise ValueError(f"batch {args.batch} does not divide by "
                             f"data={data_size}")
        eng = ServeEngine(cfg, mesh, s_bucket=S,
                          slots=args.batch // data_size,
                          max_new=args.decode_steps)
        reqs = [Request(rid=i, prompt=prompts[i], max_new=args.decode_steps)
                for i in range(args.batch)]
        t0 = time.time()
        toks, stats = eng.run(reqs, mode="continuous")
        out = np.stack([toks[i] for i in range(args.batch)])
        log.info("continuous: %d steps, %d waves in %.2fs",
                 stats.steps, stats.waves, time.time() - t0)
        log.info("decoded %s tokens/seq; sample row: %s", out.shape[1],
                 out[0][:16])
        return out

    pre_shape = ShapeConfig("cli_prefill", S, args.batch, "prefill")
    dec_shape = ShapeConfig("cli_decode", S, args.batch, "decode")
    pre_cell = resolve_cell(mdef, pre_shape, data_size=data_size,
                            model_size=model_size,
                            overrides=dict(pp=1, dp=data_size,
                                           n_chunks=max(1, S // 64),
                                           offload=False, remat="none"))
    dec_cell = resolve_cell(mdef, dec_shape, data_size=data_size,
                            model_size=model_size,
                            overrides=dict(pp=1, dp=data_size))
    # Prefill built the cache the decode cell reads: the two cells must
    # agree on its geometry (same striped layout, same local length), or
    # decode reads garbage positions with no shape error anywhere.
    assert pre_cell.cache_loc == dec_cell.cache_loc, (
        f"prefill cache_loc {pre_cell.cache_loc} != decode cache_loc "
        f"{dec_cell.cache_loc}")
    assert pre_cell.plan.sp == dec_cell.plan.sp
    if args.batch % dec_cell.plan.dp != 0:
        raise ValueError(
            f"batch {args.batch} does not divide by dp {dec_cell.plan.dp}; "
            "per-shard rows would truncate or duplicate requests")

    params, _, _ = build_params(pre_cell, mesh)
    prefill, _, _ = make_prefill_step(pre_cell, mesh)
    # constructing with decode_steps validates the decode budget up front
    serve, _, _ = make_serve_step(dec_cell, mesh,
                                  decode_steps=args.decode_steps)
    prefill = jax.jit(prefill)
    serve = jax.jit(serve, donate_argnums=(1,))

    bstruct, bspecs = batch_struct(pre_cell)
    tok = shard_rows(prompts, pre_cell.plan.dp, pre_cell.plan.pp)
    batch = {"tokens": jnp.asarray(tok), "labels": jnp.asarray(tok)}
    if cfg.cross_attn is not None:
        n_ctx = (cfg.n_frames if cfg.encoder_layers
                 else cfg.cross_attn.n_context_tokens)
        n_pad = -(-n_ctx // model_size) * model_size
        batch["context"] = jnp.asarray(
            rng.standard_normal((1, data_size, pre_cell.b_loc, n_pad,
                                 cfg.d_model)) * 0.02, jnp.bfloat16)
    # transfer-lint: ok (bench input staging onto the mesh)
    batch = {k: jax.device_put(v, NamedSharding(mesh, bspecs[k]))
             for k, v in batch.items() if k in bspecs}

    t0 = time.time()
    state, last_hidden = prefill(params, batch)
    log.info("prefill %d tokens x %d seqs in %.2fs", S, args.batch,
             time.time() - t0)

    # Decode loop: tokens thread device-to-device (serve_step replicates the
    # last stage's samples to every stage row), so the host neither syncs
    # nor re-shards mid-loop; the collected handles demux once at the end.
    handles = []
    cur = jnp.asarray(shard_rows(prompts[:, -1:], dec_cell.plan.dp,
                                 dec_cell.plan.pp))
    for step in range(args.decode_steps):
        dbatch = {"tokens": cur, "pos": jnp.int32(S + step)}
        state, nxt = serve(params, state, dbatch)
        cur = nxt[None]
        handles.append(nxt)
    out = np.stack([gather_decode_tokens(np.asarray(h), dec_cell.plan.dp,
                                         dec_cell.plan.pp, args.batch)
                    for h in handles], axis=1)
    log.info("decoded %s tokens/seq; sample row: %s", out.shape[1],
             out[0][:16])
    return out


if __name__ == "__main__":
    main()
