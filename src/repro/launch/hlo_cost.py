"""HLO cost walker: trip-count-corrected FLOPs and collective bytes.

XLA's ``HloCostAnalysis`` (what ``compiled.cost_analysis()`` reports) visits
every computation **once** — a ``while`` body (every ``lax.scan``: the layer
scan, grad-accum scan, flash KV-block scan, SSD sub-chunk scan) is counted a
single time regardless of its trip count, so module-level numbers undercount
by orders of magnitude on scanned programs.

This walker parses the *compiled* HLO text, builds the computation call
graph, and accumulates per-computation costs bottom-up with multipliers:
``while`` ops contribute body_cost x trip (trip from the
``known_trip_count`` backend_config; 1 when absent), fusions/calls x1.

Costs tracked:
  * dot FLOPs (2 x prod(output dims) x contracted size; batch dims via the
    output shape) — matmuls dominate model FLOPs; elementwise/transcendental
    flops are intentionally excluded (documented in EXPERIMENTS.md).
  * collective bytes per kind (operand bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute), trip-corrected.
  * HBM bytes touched by dots (A+B+C tensor bytes) as a lower-bound memory
    proxy, trip-corrected.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DT_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
            "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2,
            "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_CALLED = re.compile(r"(?:calls=|to_apply=|body=|condition=)%?([\w.\-]+)")
_OPERANDS = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")
_TRIP = re.compile(r'known_trip_count[^0-9]*"?n"?[^0-9]*(\d+)')

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_info(s: str) -> Tuple[Optional[str], Tuple[int, ...]]:
    m = _SHAPE_RE.match(s.strip())
    if not m:
        return None, ()
    dt, dims = m.group(1), m.group(2)
    shape = tuple(int(x) for x in dims.split(",") if x)
    return dt, shape


def _nbytes(dt: str, shape: Tuple[int, ...]) -> int:
    n = 1
    for d in shape:
        n *= d
    return n * DT_BYTES.get(dt, 4)


@dataclass
class Cost:
    dot_flops: float = 0.0
    dot_bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    # f32 collective bytes whose operand is a convert-from-bf16: XLA *CPU*
    # promotes bf16 collective reductions to f32; TPU runs them natively in
    # bf16, so the TPU-projected size is half of what's counted here.
    coll_promoted: float = 0.0

    def add(self, other: "Cost", mult: float = 1.0):
        self.dot_flops += other.dot_flops * mult
        self.dot_bytes += other.dot_bytes * mult
        for k, v in other.coll.items():
            self.coll[k] += v * mult
        self.coll_promoted += other.coll_promoted * mult


class HloModule:
    def __init__(self, text: str):
        self.comps: Dict[str, List[str]] = {}
        self.shapes: Dict[str, Tuple[str, Tuple[int, ...]]] = {}
        self._parse(text)
        self._memo: Dict[str, Cost] = {}

    def _parse(self, text: str):
        cur = None
        for raw in text.splitlines():
            line = raw.rstrip()
            ls = line.strip()
            if not ls or ls.startswith("//"):
                continue
            is_inst = re.match(r"^(ROOT\s+)?%[\w.\-]+\s*=", ls)
            if (ls.endswith("{") and " -> " in ls and not is_inst):
                name = ls.split("(")[0].replace("ENTRY", "").strip().lstrip("%")
                cur = name
                self.comps[cur] = []
                if ls.startswith("ENTRY"):
                    self.entry = cur
                continue
            if ls == "}":
                cur = None
                continue
            if cur is None:
                continue
            self.comps[cur].append(ls)
            dm = _DEF_RE.match(ls)
            if dm:
                name, body = dm.group(1), dm.group(2)
                dt, shape = _shape_info(body)
                if dt is not None:
                    self.shapes[name] = (dt, shape)

    # ---- per-instruction costs ---------------------------------------------
    def _operand_names(self, body: str) -> List[str]:
        m = _OPERANDS.search(body)
        if not m:
            return []
        names = []
        for part in m.group(1).split(","):
            part = part.strip()
            mm = re.match(r"(?:[a-z0-9]+\[[0-9,]*\]\S*\s+)?%?([\w.\-]+)", part)
            if mm:
                names.append(mm.group(1))
        return names

    def _inst_cost(self, body: str) -> Tuple[Cost, List[Tuple[str, float]]]:
        """Returns (own cost, [(called_comp, multiplier), ...])."""
        c = Cost()
        calls: List[Tuple[str, float]] = []
        head = body.split("(")[0].split()
        opname = head[-1] if head else body
        out_dt, out_shape = _shape_info(body)

        if re.search(r"\bdot\b", body.split("(")[0]):
            ops = self._operand_names(body)
            if len(ops) >= 2 and ops[0] in self.shapes and ops[1] in self.shapes:
                ldt, lsh = self.shapes[ops[0]]
                rdt, rsh = self.shapes[ops[1]]
                mcd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", body)
                k = 1
                if mcd:
                    for d in mcd.group(1).split(","):
                        if d:
                            k *= lsh[int(d)] if int(d) < len(lsh) else 1
                out_n = 1
                for d in out_shape:
                    out_n *= d
                c.dot_flops += 2.0 * out_n * k
                c.dot_bytes += (_nbytes(ldt, lsh) + _nbytes(rdt, rsh)
                                + _nbytes(out_dt or "f32", out_shape))
        for kind in COLLECTIVES:
            if re.match(rf"(\w+-)*{kind}(-start|-done)?\b", opname) and \
               "-done" not in opname:
                ops = self._operand_names(body)
                b = 0
                promoted = 0
                for o in ops:
                    if o in self.shapes:
                        nb = _nbytes(*self.shapes[o])
                        b += nb
                        if (self.shapes[o][0] == "f32"
                                and "convert" in o.lower()):
                            promoted += nb
                c.coll[kind] += b
                c.coll_promoted += promoted
                break

        trip = 1.0
        tm = _TRIP.search(body)
        if tm:
            trip = float(tm.group(1))
        if "while(" in body:
            for role, mult in (("body", trip), ("condition", trip)):
                mm = re.search(rf"{role}=%?([\w.\-]+)", body)
                if mm:
                    calls.append((mm.group(1), mult))
        else:
            for mm in re.finditer(r"(?:calls=|to_apply=)%?([\w.\-]+)", body):
                calls.append((mm.group(1), 1.0))
        return c, calls

    def comp_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        total = Cost()
        self._memo[name] = total  # guard cycles (shouldn't happen)
        for ls in self.comps.get(name, ()):
            dm = _DEF_RE.match(ls)
            body = dm.group(2) if dm else ls
            c, calls = self._inst_cost(body)
            total.add(c)
            for callee, mult in calls:
                total.add(self.comp_cost(callee), mult)
        return total

    def entry_cost(self) -> Cost:
        return self.comp_cost(self.entry)


def cpu_upcast_artifact_bytes(mod: "HloModule", min_bytes=64 * 2**20) -> int:
    """XLA *CPU* has no native bf16 matmul: it inserts f32 `convert`s of the
    bf16 weights and hoists them out of scan loops, inflating temp memory by
    ~3x param bytes for weight-stationary programs.  TPU lowers bf16 dots
    natively, so these buffers don't exist there.  This sums large f32
    convert/copy outputs in the entry computation so the dry-run can report
    a TPU-projected temp estimate alongside the raw CPU number."""
    total = 0
    entry = getattr(mod, "entry", None)
    if entry is None:
        return 0
    for ls in mod.comps.get(entry, ()):
        dm = _DEF_RE.match(ls)
        if not dm:
            continue
        body = dm.group(2)
        head = body.split("(")[0]
        if not re.search(r"\b(convert|copy|fusion)\b", head):
            continue
        dt, shape = _shape_info(body)
        if dt != "f32":
            continue
        b = _nbytes(dt, shape)
        if b >= min_bytes and ("convert" in body or "copy" in head
                               or "fusion" in head):
            total += b
    return total


def analyze(hlo_text: str) -> dict:
    mod = HloModule(hlo_text)
    c = mod.entry_cost()
    return {
        "dot_flops": c.dot_flops,
        "dot_bytes": c.dot_bytes,
        "collectives": {k: v for k, v in c.coll.items()},
        "collective_bytes_total": sum(c.coll.values()),
        # TPU-projected: promoted bf16->f32 reductions run bf16 natively
        "collective_bytes_promoted_f32": c.coll_promoted,
        "cpu_upcast_artifact_bytes": cpu_upcast_artifact_bytes(mod),
    }
