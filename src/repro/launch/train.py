"""End-to-end training driver.

The same code path drives a reduced config on CPU (the quickstart / CI run)
and a full config on a real TPU mesh — only the mesh and config change.

  PYTHONPATH=src python -m repro.launch.train \
      --arch qwen2-7b --reduced --steps 50 --mesh 2x2 \
      --seq 256 --batch 8 --ckpt-dir /tmp/ckpt --resume auto

Features exercised: SPPO chunked pipeline with adaptive offload, AdamW with
ZeRO-1/bf16 knobs, async sharded checkpointing + auto-resume, straggler
watchdog, TGS/MFU metering.
"""
from __future__ import annotations

import argparse
import logging

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ShapeConfig, get_config
from repro.data.pipeline import SyntheticLM, make_context_stub, shard_batch
from repro.launch.mesh import make_test_mesh, mesh_dims
from repro.models.model_zoo import build_model
from repro.optim import adamw
from repro.parallel import specs as SP
from repro.parallel.runner import batch_struct, make_train_step, resolve_cell
from repro.runtime.fault_tolerance import RestartSupervisor, StepWatchdog
from repro.runtime.metrics import Meter

log = logging.getLogger("repro.train")


def build_params(cell, mesh):
    """Initialize real parameters laid out per specs (stage-major stacking)."""
    mdef, plan = cell.mdef, cell.plan
    dims = mesh_dims(mesh)
    key = jax.random.PRNGKey(0)
    stages = [mdef.init_stage_params(key, s, plan.pp, cell.dtype)
              for s in range(plan.pp)]
    stacked = jax.tree_util.tree_map(
        lambda *ls: jnp.stack([ls[i % plan.pp] for i in range(dims["data"])]),
        *stages)
    params = {"stages": stacked, "globals": mdef.init_globals(key, cell.dtype)}
    _, pspecs = SP.param_struct_and_specs(mdef, plan.pp, dims["data"],
                                          cell.dtype)
    shard = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), pspecs)
    # transfer-lint: ok (initial param placement onto the mesh)
    params = jax.tree_util.tree_map(jax.device_put, params, shard)
    return params, pspecs, shard


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--mesh", default="1x1", help="DATAxMODEL, e.g. 4x2")
    ap.add_argument("--pp", type=int, default=None)
    ap.add_argument("--n-chunks", type=int, default=None)
    ap.add_argument("--no-offload", action="store_true")
    ap.add_argument("--offload-moments", action="store_true",
                    help="keep AdamW m/v host-resident (executed "
                         "ZeRO-Offload analogue, DESIGN.md §11)")
    ap.add_argument("--moments-mode", default=None,
                    choices=["explicit", "xla"],
                    help="explicit: one H2D/D2H device_put per moment leaf "
                         "in the update; xla: host-committed shardings, "
                         "streaming delegated to XLA")
    ap.add_argument("--offload-dtype", default=None,
                    choices=["none", "fp8", "int8"],
                    help="compress the act_off host rows (DESIGN.md §14): "
                         "quantize on D2H to fp8_e4m3/int8 with per-row "
                         "fp32 scales, dequantize inside the backward")
    ap.add_argument("--moments-dtype", default=None,
                    choices=["none", "fp8", "int8"],
                    help="compressed host residency for the AdamW moments "
                         "(needs --offload-moments and explicit mode): "
                         "host leaves become (payload, per-row scale)")
    ap.add_argument("--prefetch", default=None, choices=["ahead", "sync"],
                    help="backward-reload placement on the explicit offload "
                         "path (DESIGN.md §12): ahead = one-chunk-ahead H2D "
                         "via the tick-level custom_vjp seam (default); "
                         "sync = autodiff placement, each chunk reloads at "
                         "its own backward")
    ap.add_argument("--attn-mode", default=None,
                    choices=["gather_q", "gather_kv", "auto", "ring",
                             "local"],
                    help="distributed attention schedule (DESIGN.md §15): "
                         "gather_q = flash-decoding merge (default); "
                         "gather_kv = all-gather the KV shard; auto = "
                         "byte-count switch; ring = rotate KV blocks via "
                         "ppermute (beyond-one-stage contexts); local = no "
                         "attention collectives (model axis 1 only)")
    ap.add_argument("--msp", action="store_true",
                    help="multiplexed sequence partitioning (pp > 1 only). "
                         "NOTE: on the lock-step SPMD runner the ramp "
                         "sub-chunks recompute their full chunk, so this "
                         "validates the schedule but costs extra compute "
                         "per step (DESIGN.md §2)")
    ap.add_argument("--msp-split", type=int, default=2,
                    help="sub-chunks per MSP ramp chunk")
    ap.add_argument("--audit", action="store_true",
                    help="statically audit the resolved cell before "
                         "training (analysis/audit.py, DESIGN.md §17): "
                         "trace the step over ShapeDtypeStructs and prove "
                         "the offload/pipeline contracts R1-R5; exit 2 on "
                         "any finding")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", default="none", choices=["none", "auto"])
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")

    data_size, model_size = (int(x) for x in args.mesh.split("x"))
    mesh = make_test_mesh(data_size, model_size)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mdef = build_model(cfg)
    shape = ShapeConfig("cli_train", args.seq, args.batch, "train")
    overrides = {}
    if args.pp:
        overrides["pp"] = args.pp
        overrides["dp"] = data_size // args.pp
    if args.n_chunks:
        overrides["n_chunks"] = args.n_chunks
    if args.no_offload:
        overrides["offload"] = False
    if args.offload_moments:
        overrides["offload_moments"] = True
    if args.moments_mode:
        overrides["moments_mode"] = args.moments_mode
    if args.prefetch:
        overrides["prefetch"] = args.prefetch
    if args.offload_dtype:
        overrides["offload_dtype"] = args.offload_dtype
    if args.moments_dtype:
        overrides["moments_dtype"] = args.moments_dtype
        if args.moments_dtype != "none":
            # compressed moments imply the explicit host-residency path
            overrides.setdefault("offload_moments", True)
            overrides.setdefault("moments_mode", "explicit")
    if args.attn_mode:
        overrides["attn_mode"] = args.attn_mode
    if args.msp:
        overrides["msp"] = True
        overrides["msp_split"] = args.msp_split
        log.warning("msp: ramp sub-chunks recompute their full chunk on the "
                    "SPMD runner — schedule validation mode, expect extra "
                    "compute per step (DESIGN.md §2)")
    cell = resolve_cell(mdef, shape, data_size=data_size,
                        model_size=model_size, overrides=overrides or None)
    if args.msp and cell.plan.pp == 1:
        ap.error("--msp needs a pipeline (resolved plan has pp=1); "
                 "pass --pp > 1 or a mesh/shape that maps to pp > 1")
    log.info("plan: %s  chunks=%s alphas=%s", cell.plan, cell.sched.lengths,
             [round(a, 3) for a in cell.alphas])

    if args.audit:
        # preflight contract audit (DESIGN.md §17): trace-only, so a broken
        # offload/pipeline dataflow fails here before any memory is spent
        from repro.analysis.audit import audit_cell
        from repro.analysis.report import format_report

        rep = audit_cell(cell, data_size=data_size, model_size=model_size,
                         name=f"{args.arch}/cli_train")
        print(format_report(rep))
        if not rep.clean:
            raise SystemExit(2)
        log.info("audit clean: %s", ", ".join(rep.traces))

    params, pspecs, pshard = build_params(cell, mesh)
    opt_dtype = (jnp.bfloat16 if cell.plan.opt_dtype == "bfloat16"
                 else jnp.float32)
    # moments are born in host memory when the plan offloads them — no
    # device-side opt_dtype copy of the params ever materializes at init
    opt_state = adamw.init_state(
        params, opt_dtype, offload_moments=cell.plan.offload_moments,
        moments_dtype=cell.plan.moments_dtype)
    if cell.plan.offload_moments:
        from repro.runtime import hostmem
        log.info("optimizer moments host-resident (kind=%s, mode=%s, "
                 "dtype=%s)", hostmem.host_memory_kind(),
                 cell.plan.moments_mode, cell.plan.moments_dtype)
    step_fn = jax.jit(
        make_train_step(cell, mesh,
                        lr_kwargs=dict(peak=args.lr, warmup=20,
                                       total=max(args.steps, 100))),
        donate_argnums=(0, 1))

    data = SyntheticLM(cfg.vocab_size, args.seq, args.batch)
    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if ckpt and args.resume == "auto" and ckpt.latest_step() is not None:
        (params, opt_state), start, extra = ckpt.restore((params, opt_state))
        data.load_state_dict(extra.get("data", data.state_dict()))
        log.info("resumed from step %d", start)

    n_active = SP.count_active_params(mdef, cell.plan.pp, data_size)
    meter = Meter(n_chips=data_size * model_size,
                  tokens_per_step=args.batch * args.seq,
                  n_active_params=n_active)
    watchdog = StepWatchdog()
    bstruct, bspecs = batch_struct(cell)
    bshard = {k: NamedSharding(mesh, s) for k, s in bspecs.items()}

    nctx_pad = None
    if cfg.cross_attn is not None:
        n_ctx = (cfg.n_frames if cfg.encoder_layers
                 else cfg.cross_attn.n_context_tokens)
        nctx_pad = -(-n_ctx // cell.plan.sp) * cell.plan.sp

    def loop(resume_step: int):
        nonlocal params, opt_state
        data.state.step = resume_step
        for step in range(resume_step, args.steps):
            tokens, labels = data.sample_step(step)
            batch = shard_batch(tokens, labels, pods=cell.pods,
                                data_size=data_size, pp=cell.plan.pp)
            if nctx_pad is not None:
                batch["context"] = make_context_stub(
                    batch, b_loc=cell.b_loc, pods=cell.pods,
                    data_size=data_size, n_ctx_pad=nctx_pad,
                    d_model=cfg.d_model, seed=step,
                    dtype=np.float32).astype(jnp.bfloat16
                                             if cell.dtype == jnp.bfloat16
                                             else np.float32)
            # transfer-lint: ok (train batch staging onto the mesh)
            batch = {k: jax.device_put(v, bshard[k]) for k, v in batch.items()}
            meter.start()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            rec = meter.stop(step, loss)
            watchdog.observe(step, rec["dt"])
            if step % args.log_every == 0 or step == args.steps - 1:
                log.info("step %4d  loss %.4f  %.2fs  tgs %.1f  mfu %.2e  "
                         "gnorm %.3f", step, loss, rec["dt"], rec["tgs"],
                         rec["mfu"], float(metrics["grad_norm"]))
            if ckpt and ((step + 1) % args.ckpt_every == 0
                         or step == args.steps - 1):
                ckpt.save(step + 1, (params, opt_state),
                          extra={"data": data.state_dict()})
        if ckpt:
            ckpt.wait()

    sup = RestartSupervisor(checkpointer=ckpt) if ckpt else None
    if sup:
        sup.install_signal_handlers()
        sup.run(loop, start)
    else:
        loop(start)
    if args.metrics_out:
        meter.dump(args.metrics_out)
    log.info("done: final loss %.4f (first %.4f)",
             meter.history[-1]["loss"], meter.history[0]["loss"])
    return meter.history


if __name__ == "__main__":
    main()
