import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""CLI for the trace-time contract auditor (DESIGN.md §17).

Audits plan cells WITHOUT running them: each cell's real step functions are
traced over ShapeDtypeStructs and the offload/pipeline dataflow contracts
R1-R5 are proven on the jaxpr.  The audit-gate CI job runs the full sweep
over benchmarks/budgets.json — every train gate at its own pp and at pp=1,
plus the serve gate's prefill — and uploads the JSON findings report.

  PYTHONPATH=src python -m repro.launch.audit --all [--out audit.json]
  PYTHONPATH=src python -m repro.launch.audit --cell sppo-gpt-7b-reduced-pp2
  PYTHONPATH=src python -m repro.launch.audit --cell <name> --pp 1
  PYTHONPATH=src python -m repro.launch.audit --cell <name> --prefetch sync

Exit status: 0 when every report is clean, 1 otherwise.  --prefetch sync is
expected to fail (the sync exposure IS finding R3-overlap-hazard).
"""
import argparse
import json
import sys

from repro.analysis import audit as aud
from repro.analysis.report import format_report, reports_to_json


def load_gates(path: str):
    with open(path) as f:
        return json.load(f)["gates"]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--budgets", default="benchmarks/budgets.json")
    ap.add_argument("--cell", default=None,
                    help="audit one budgets.json gate by name")
    ap.add_argument("--all", action="store_true",
                    help="audit every gate (train gates at their own pp "
                         "AND at pp=1; the serve gate's prefill cell)")
    ap.add_argument("--pp", type=int, default=None,
                    help="override the gate's pipeline depth (train gates)")
    ap.add_argument("--prefetch", default=None, choices=["ahead", "sync"],
                    help="override the reload placement (sync is the "
                         "R3-overlap-hazard exposure and audits dirty)")
    ap.add_argument("--out", default=None,
                    help="write the machine-readable JSON report here")
    args = ap.parse_args(argv)

    gates = load_gates(args.budgets)
    if args.cell is not None:
        gates = [g for g in gates if g["name"] == args.cell]
        if not gates:
            ap.error(f"no gate named {args.cell!r} in {args.budgets}")
    elif not args.all:
        ap.error("pass --cell <name> or --all")

    reports = []
    for gate in gates:
        if gate.get("kind") == "serve":
            reports.append(aud.audit_gate(gate))
            continue
        pps = [args.pp] if args.pp is not None else sorted(
            {gate["pp"], 1} if args.all else {gate["pp"]})
        for pp in pps:
            reports.append(aud.audit_gate(gate, pp=pp,
                                          prefetch=args.prefetch))

    for rep in reports:
        print(format_report(rep))
    if args.out:
        with open(args.out, "w") as f:
            f.write(reports_to_json(reports))
    n_dirty = sum(not r.clean for r in reports)
    print(f"audited {len(reports)} cell(s): "
          f"{len(reports) - n_dirty} clean, {n_dirty} with findings")
    return 1 if n_dirty else 0


if __name__ == "__main__":
    sys.exit(main())
