import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the appropriate step (train_step for train shapes,
prefill_step for prefill, serve_step for decode/long shapes) against
ShapeDtypeStruct inputs on the production mesh, compiles it, and records:

  * memory_analysis()  — per-device bytes (proves the plan fits),
  * cost_analysis()    — HLO FLOPs / bytes accessed,
  * the collective-byte breakdown parsed from the compiled HLO,

into a JSON artifact consumed by benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out f.json]
"""
import argparse
import json
import re
import sys
import time
import traceback
from collections import Counter

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (ASSIGNED_ARCHS, SHAPES, cell_is_runnable,
                                get_config)
from repro.launch.mesh import make_production_mesh, mesh_dims
from repro.parallel import specs as SP
from repro.parallel.runner import (Cell, batch_struct, make_prefill_step,
                                   make_serve_step, make_train_step,
                                   resolve_cell, _serve_state)


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs — no allocation)
# ---------------------------------------------------------------------------


def input_specs(cell: Cell, mesh):
    """ShapeDtypeStruct stand-ins + NamedShardings for one step's inputs."""
    bstruct, bspecs = batch_struct(cell)
    shard = {k: NamedSharding(mesh, s) for k, s in bspecs.items()}
    return bstruct, shard


def param_specs(cell: Cell, mesh):
    struct, spec = SP.param_struct_and_specs(
        cell.mdef, cell.plan.pp, cell.data_size, cell.dtype)
    shards = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec)
    return struct, shards


def collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in the (compiled) HLO."""
    kinds = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
    out = {k: 0 for k in kinds}
    counts = Counter()
    dt_bytes = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f64": 8,
                "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f8e4m3": 1,
                "f8e5m2": 1, "s16": 2, "u16": 2}
    shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"%?[\w.\-]+ = (.*)", ls)
        body = m.group(1) if m else ls
        for k in kinds:
            if f"{k}-start" in body or re.search(rf"\b{k}\b", body.split("(")[0]):
                # output shape(s) at the head of the instruction
                shapes = shape_re.findall(body.split("(")[0])
                b = 0
                for dt, dims in shapes:
                    if dt not in dt_bytes:
                        continue
                    n = 1
                    for dd in dims.split(","):
                        if dd:
                            n *= int(dd)
                    b += n * dt_bytes[dt]
                if b:
                    out[k] += b
                    counts[k] += 1
                break
    out["counts"] = dict(counts)
    return out


def run_cell(arch: str, shape_name: str, mesh, *, verbose=True):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_is_runnable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": why}
    dims = mesh_dims(mesh)
    t0 = time.time()
    cell = resolve_cell(arch, shape, data_size=dims["data"],
                        model_size=dims["model"], pods=dims["pods"])
    pstruct, pshard = param_specs(cell, mesh)
    bstruct, bshard = input_specs(cell, mesh)

    kind = shape.kind
    if kind == "train":
        from repro.optim import adamw
        step = make_train_step(cell, mesh)
        opt_dtype = jnp.bfloat16 if cell.plan.opt_dtype == "bfloat16" else jnp.float32
        ostruct = jax.eval_shape(
            lambda p: adamw.init_state(
                p, opt_dtype, offload_moments=cell.plan.offload_moments),
            pstruct)
        oshard_specs = SP.opt_specs(
            {"stages": SP.stage_specs(cell.mdef, cell.plan.pp),
             "globals": SP.globals_specs(cell.mdef)},
            zero1_pod=cell.plan.zero1 and dims["pods"] > 1,
            param_struct=pstruct, model_size=dims["model"],
            pods=dims["pods"])
        # plan-driven host residency (DESIGN.md §11): the big-model plans
        # set offload_moments, and the dry-run prices the same placement
        # the executed path deploys — the "auto" probe resolves to the
        # backend's supported host kind (pinned_host on the TPU target,
        # unpinned_host on this CPU container), exactly as init_state does
        moment_shard = SP.moment_shardings(
            mesh, oshard_specs,
            offload_moments=cell.plan.offload_moments)
        oshard = type(ostruct)(step=NamedSharding(mesh, P()),
                               m=moment_shard, v=moment_shard)
        args = (pstruct, ostruct, bstruct)
        shards = (pshard, oshard, bshard)
        fn = step
    elif kind == "prefill":
        fn, sstruct, sspecs = make_prefill_step(cell, mesh)
        args = (pstruct, bstruct)
        shards = (pshard, bshard)
    else:  # decode
        fn, _, _ = make_serve_step(cell, mesh)
        _, sstruct_g, sspecs_g = _serve_state(cell)
        sshard = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), sspecs_g)
        args = (pstruct, sstruct_g, bstruct)
        shards = (pshard, sshard, bshard)

    rec = {"arch": arch, "shape": shape_name,
           "mesh": f"{dims['pods']}x{dims['data']}x{dims['model']}"
           if dims["pods"] > 1 else f"{dims['data']}x{dims['model']}",
           "plan": {"dp": cell.plan.dp, "pp": cell.plan.pp,
                    "sp": cell.plan.sp, "n_chunks": cell.sched.n,
                    "grad_accum": cell.plan.grad_accum,
                    "offload": cell.plan.offload,
                    "offload_mode": cell.plan.offload_mode,
                    "prefetch": cell.plan.prefetch},
           "alphas": list(cell.alphas)}
    donate = (0, 1) if kind == "train" else ((1,) if kind == "decode" else ())
    try:
        # jaxpr-level collective accounting: dtype-faithful and scan-exact
        # (compiled-HLO numbers suffer two XLA-CPU artifacts — see
        # launch/jaxpr_cost.py)
        from repro.launch.jaxpr_cost import collective_bytes as _jc
        jc = _jc(fn, *args, axis_sizes={
            "model": dims["model"], "data": dims["data"],
            "pod": dims["pods"]})
        lowered = jax.jit(fn, in_shardings=shards,
                          donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_comp = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        txt = compiled.as_text()
        from repro.launch import hlo_cost
        hc = hlo_cost.analyze(txt)
        # the f32-upcast artifact cannot exceed ~3x the per-device bf16
        # param bytes (f32 copy = 2x + one layout copy) — cap the textual
        # estimate so big f32 activations are never misattributed
        import numpy as _np
        pdev = (sum(int(_np.prod(l.shape)) * l.dtype.itemsize
                    for l in jax.tree_util.tree_leaves(pstruct["stages"]))
                / (dims["data"] * dims["model"])
                + sum(int(_np.prod(l.shape)) * l.dtype.itemsize
                      for l in jax.tree_util.tree_leaves(pstruct["globals"]))
                / dims["model"])
        hc["cpu_upcast_artifact_bytes"] = min(
            hc["cpu_upcast_artifact_bytes"], 3.0 * pdev)
        coll = {k: v for k, v in hc["collectives"].items()}
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_comp, 1),
            "memory": {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "host_temp_bytes": ma.host_temp_size_in_bytes,
                "host_argument_bytes": ma.host_argument_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
            },
            # raw module-level numbers (scan bodies counted ONCE — see
            # launch/hlo_cost.py for why these undercount)
            "flops_module_raw": ca.get("flops", 0.0),
            "bytes_module_raw": ca.get("bytes accessed", 0.0),
            # trip-count-corrected (the roofline inputs)
            "dot_flops": hc["dot_flops"],
            "dot_bytes": hc["dot_bytes"],
            # compiled-HLO collective view (CPU-promoted dtypes)
            "collectives": coll,
            "collective_bytes_hlo": hc["collective_bytes_total"],
            # jaxpr view: dtype-faithful + exact scan trips (roofline input)
            "collectives_jaxpr": jc["kinds"],
            "collective_bytes": jc["total"],
            # XLA-CPU bf16->f32 weight upcasts (absent on TPU): subtract for
            # the TPU-projected device memory (see launch/hlo_cost.py)
            "cpu_upcast_artifact_bytes": hc["cpu_upcast_artifact_bytes"],
        })
        if verbose:
            dev_gb = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                      + ma.output_size_in_bytes - ma.alias_size_in_bytes) / 2**30
            print(f"  OK  lower {t_lower:5.1f}s compile {t_comp:6.1f}s  "
                  f"dot-flops {hc['dot_flops']:.3e}  dev-mem {dev_gb:5.2f} GiB  "
                  f"coll {hc['collective_bytes_total']/2**20:8.1f} MiB")
    except Exception as e:  # noqa
        rec.update({"status": "fail", "error": f"{type(e).__name__}: {e}"})
        if verbose:
            print(f"  FAIL {type(e).__name__}: {str(e)[:300]}")
            traceback.print_exc(limit=8)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="dryrun_results.json")
    args = ap.parse_args()

    records = []
    meshes = []
    if args.both_meshes:
        meshes = [False, True]
    else:
        meshes = [args.multi_pod]

    for mp in meshes:
        mesh = make_production_mesh(multi_pod=mp)
        label = "multi-pod 2x16x16" if mp else "single-pod 16x16"
        print(f"== mesh {label} ==")
        if args.all:
            cells = [(a, s) for a in ASSIGNED_ARCHS for s in SHAPES]
        else:
            cells = [(args.arch, args.shape)]
        for arch, shape in cells:
            print(f"[{label}] {arch} x {shape}")
            rec = run_cell(arch, shape, mesh)
            records.append(rec)

    with open(args.out, "w") as f:
        json.dump(records, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in records)
    n_skip = sum(r["status"] == "skipped" for r in records)
    n_fail = sum(r["status"] == "fail" for r in records)
    print(f"\n{n_ok} ok / {n_skip} skipped / {n_fail} FAILED -> {args.out}")
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
