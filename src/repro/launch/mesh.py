"""Production mesh builders.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(data: int = 4, model: int = 2, pods: int = 1):
    """Small mesh for CPU integration tests."""
    if pods > 1:
        return jax.make_mesh((pods, data, model), ("pod", "data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 3)
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def mesh_dims(mesh) -> dict:
    names = mesh.axis_names
    return {
        "pods": mesh.shape["pod"] if "pod" in names else 1,
        "data": mesh.shape["data"],
        "model": mesh.shape["model"],
    }
