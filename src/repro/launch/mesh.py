"""Production mesh builders.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax


def compat_make_mesh(shape, axes):
    """jax.make_mesh across jax versions: `axis_types` (and AxisType) only
    exist on newer jax; older releases default every axis to Auto anyway."""
    try:
        return jax.make_mesh(shape, axes,
                             axis_types=(jax.sharding.AxisType.Auto,)
                             * len(axes))
    except (AttributeError, TypeError):  # jax < 0.5: no AxisType
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_test_mesh(data: int = 4, model: int = 2, pods: int = 1):
    """Small mesh for CPU integration tests."""
    if pods > 1:
        return compat_make_mesh((pods, data, model),
                                ("pod", "data", "model"))
    return compat_make_mesh((data, model), ("data", "model"))


def mesh_dims(mesh) -> dict:
    names = mesh.axis_names
    return {
        "pods": mesh.shape["pod"] if "pod" in names else 1,
        "data": mesh.shape["data"],
        "model": mesh.shape["model"],
    }
