"""Shared host-memory-kind helpers (DESIGN.md §10/§11).

Both executed-offload paths — activations (core/offload.py) and optimizer
moments (optim/adamw.py) — place tensors into the best host memory space the
backend exposes and move them back with explicit ``device_put`` dataflow:

  * ``pinned_host``   on TPU/GPU (DMA-able, the paper's offload target);
  * ``unpinned_host`` on CPU (XLA folds host into device, but the program
    structure — and therefore the jaxpr accounting — is identical);
  * ``None``          when the backend has no memory kinds at all, in which
    case callers fall back to the barrier-fenced staged-copy emulation
    (``optimization_barrier`` around the named save point) so the graph
    keeps the same shape.

This module is the single home for the memory-kind probe and the D2H/H2D
primitives; it imports nothing from ``repro`` so every layer (core, optim,
runtime, parallel) can use it without cycles.
"""
from __future__ import annotations

from typing import Optional

import jax

try:  # public home moves across jax versions
    from jax.sharding import TransferToMemoryKind
except ImportError:  # pragma: no cover - version-dependent
    try:
        from jax._src.sharding_impls import TransferToMemoryKind
    except ImportError:
        TransferToMemoryKind = None

DEVICE_KIND = "device"
HOST_KIND_PREFERENCE = ("pinned_host", "unpinned_host")

_HOST_KIND_CACHE: dict = {}


def host_memory_kind(backend: Optional[str] = None) -> Optional[str]:
    """Best host memory kind the default device exposes: 'pinned_host'
    (TPU/GPU) > 'unpinned_host' (CPU) > None (no memory-kind support —
    the staged-copy emulation takes over)."""
    key = backend or "default"
    if key in _HOST_KIND_CACHE:
        return _HOST_KIND_CACHE[key]
    kind = None
    if TransferToMemoryKind is not None:
        try:
            dev = jax.devices(backend)[0] if backend else jax.devices()[0]
            kinds = {m.kind for m in dev.addressable_memories()}
            for cand in HOST_KIND_PREFERENCE:
                if cand in kinds:
                    kind = cand
                    break
        except Exception:  # pragma: no cover - backend-dependent
            kind = None
    _HOST_KIND_CACHE[key] = kind
    return kind


def resolve_host_kind(host_kind="auto") -> Optional[str]:
    """'auto' -> probe the backend; anything else passes through (a kind
    string, or None to force the barrier-fenced emulation)."""
    return host_memory_kind() if host_kind == "auto" else host_kind


def _is_traced(t) -> bool:
    return isinstance(t, jax.core.Tracer)


def _default_device_kind(t) -> str:
    """The default (device) memory kind of `t`'s devices — 'device' on
    TPU/GPU, 'unpinned_host' on CPU (host == device there)."""
    try:
        dev = next(iter(t.devices()))
    except Exception:  # pragma: no cover - non-committed values
        dev = jax.devices()[0]
    return dev.default_memory().kind


def to_host(t, kind: Optional[str]):
    """One D2H: place `t` in host memory space (emulation: barrier fence,
    so XLA must materialize the staged buffer instead of fusing it away).
    Inside jit this is the ``TransferToMemoryKind`` device_put form the
    ledger's copy accounting counts; eagerly it commits the concrete
    array's own sharding into the host kind."""
    if kind is None:
        return jax.lax.optimization_barrier(t)
    if _is_traced(t):
        return jax.device_put(t, TransferToMemoryKind(kind))
    return jax.device_put(t, host_sharding_like(t, kind))


def to_device(t, kind: Optional[str]):
    """One H2D: bring a host-resident `t` back to device memory space.
    `kind` is the host kind the value lives in (None = emulation fence)."""
    if kind is None:
        return jax.lax.optimization_barrier(t)
    if _is_traced(t):
        return jax.device_put(t, TransferToMemoryKind(DEVICE_KIND))
    return jax.device_put(t, host_sharding_like(t, _default_device_kind(t)))


def host_sharding_like(arr, kind: str):
    """A sharding placing `arr`'s layout into `kind` host memory: the
    array's own sharding re-kinded when it carries one (NamedSharding /
    SingleDeviceSharding both support with_memory_kind), else a
    single-device host placement."""
    sh = getattr(arr, "sharding", None)
    if sh is not None and hasattr(sh, "with_memory_kind"):
        try:
            return sh.with_memory_kind(kind)
        except Exception:  # pragma: no cover - exotic shardings
            pass
    from jax.sharding import SingleDeviceSharding

    return SingleDeviceSharding(jax.devices()[0], memory_kind=kind)


def row_scale_sharding(p, kind: str):
    """Host sharding for a per-row scale buffer shaped ``p.shape[:-1] + (1,)``:
    `p`'s own sharding with the trailing axis unpartitioned — the scale's
    trailing dim is 1 and cannot carry the payload's last-axis shards (a
    model-sharded (rows, d) param would ask the (rows, 1) scale to split
    its singleton axis)."""
    sh = getattr(p, "sharding", None)
    if sh is not None:
        try:
            from jax.sharding import NamedSharding, PartitionSpec

            if (isinstance(sh, NamedSharding) and p.ndim >= 1
                    and len(sh.spec) == p.ndim and sh.spec[-1] is not None):
                sh = NamedSharding(sh.mesh,
                                   PartitionSpec(*sh.spec[:-1], None))
            return sh.with_memory_kind(kind)
        except Exception:  # pragma: no cover - exotic shardings
            pass
    from jax.sharding import SingleDeviceSharding

    return SingleDeviceSharding(jax.devices()[0], memory_kind=kind)


def host_zeros(shape, dtype, kind: Optional[str], like=None, sharding=None):
    """Zeros born in host memory: the buffer is built host-side (numpy) and
    placed directly into the host memory space, so *no device allocation
    ever happens* — the init_state fix for the step-0 peak spike
    (DESIGN.md §11).  With no memory kinds the plain device zeros are the
    only option (host == device there anyway).  Under abstract tracing
    (eval_shape / jit of init — the dry-run's shape-only path) a concrete
    host buffer must not materialize, so this falls back to traced zeros;
    the real init paths (launch/train.py, memledger) are eager."""
    import numpy as np

    import jax.numpy as jnp

    if kind is None:
        return jnp.zeros(shape, dtype)
    if _is_traced(like):
        # traced zeros, immediately host-placed — the jaxpr keeps the
        # host-residency fact (memledger.init_moment_device_bytes nets
        # host-placed creations out of the device-space count)
        return to_host(jnp.zeros(shape, dtype), kind)
    host = np.zeros(shape, np.dtype(dtype))
    if sharding is None:
        sharding = host_sharding_like(like, kind)
    return jax.device_put(host, sharding)


def memory_kind_of(arr) -> Optional[str]:
    """The committed memory kind of a concrete array (None if unknown)."""
    sh = getattr(arr, "sharding", None)
    return getattr(sh, "memory_kind", None)


# ---------------------------------------------------------------------------
# Compressed host residency: the shared quantize/dequantize primitives
# ---------------------------------------------------------------------------
#
# Both executed offload channels (act_off rows, core/offload.py, and the
# AdamW moments, optim/adamw.py) can cross the host link compressed:
# bf16/fp32 rows quantize to an 8-bit wire dtype with one fp32 scale per
# row of the trailing axis (symmetric absmax scaling), and the backward /
# update H2D dequantizes.  The payload is what lives in host memory and
# crosses PCIe; the scales are tiny (4 bytes per trailing-axis row) and the
# activation channel keeps them device-resident with the keep set
# (DESIGN.md §14).  Zero/constant rows are safe by construction: a row with
# absmax 0 gets scale 1.0, quantizes to exact zeros, and dequantizes to
# exact zeros — no division by zero, no NaN (the offload analogue of the
# PR 2 dead-row m=-inf sanitization).

OFFLOAD_CODECS = ("none", "fp8", "int8")

# symmetric quantization range per codec: fp8_e4m3fn saturates at 448,
# int8 at 127 (the sign-symmetric range, -127..127)
_CODEC_QMAX = {"fp8": 448.0, "int8": 127.0}


def codec_wire_dtype(codec: str):
    """The 1-byte wire dtype of a codec (None for the uncompressed channel)."""
    import jax.numpy as jnp

    if codec in (None, "none"):
        return None
    if codec == "fp8":
        return jnp.float8_e4m3fn
    if codec == "int8":
        return jnp.int8
    raise ValueError(f"unknown offload codec {codec!r}; "
                     f"known: {OFFLOAD_CODECS}")


def codec_itemsize(codec: str, *, default: int = 2) -> int:
    """Wire bytes per element of the compressed payload (`default` — the
    bf16 activation itemsize — for the uncompressed channel)."""
    import numpy as np

    wire = codec_wire_dtype(codec)
    return default if wire is None else np.dtype(wire).itemsize


def quantize(t, codec: str):
    """Per-row symmetric quantization: (payload, scale).

    Rows are the trailing axis (one fp32 scale per [..., 1] slice — per
    head for [B, T, H, hd] attention tensors, per token for [B, T, d_ff]
    MLP hiddens, per matrix row for 2-D moment leaves).  payload is the
    codec's wire dtype; ``dequantize(payload, scale, codec, t.dtype)``
    reconstructs within the codec's resolution.  All-zero rows map to
    (zeros, 1.0) exactly."""
    import jax.numpy as jnp

    wire = codec_wire_dtype(codec)
    assert wire is not None, f"quantize called with codec={codec!r}"
    qmax = _CODEC_QMAX[codec]
    t32 = t.astype(jnp.float32)
    if t.ndim >= 1:
        amax = jnp.max(jnp.abs(t32), axis=-1, keepdims=True)
    else:
        amax = jnp.abs(t32)
    scale = jnp.where(amax > 0.0, amax / qmax, 1.0)
    # saturate BEFORE the wire cast for both codecs: t32/scale can land an
    # ulp above qmax depending on how XLA fuses the division (the AD-traced
    # program rearranges it differently than the plain forward), and
    # float8_e4m3fn has no inf — an overflowing convert produces NaN
    q = jnp.clip(t32 / scale, -qmax, qmax)
    if codec == "int8":
        payload = jnp.round(q).astype(wire)
    else:
        payload = q.astype(wire)
    return payload, scale


def dequantize(payload, scale, codec: str, dtype):
    """Inverse of ``quantize``: payload * scale, cast back to `dtype`."""
    import jax.numpy as jnp

    return (payload.astype(jnp.float32) * scale).astype(dtype)


def to_transport(payload, codec: str):
    """View an int8 payload as an fp8 byte container for channels that must
    carry an inexact dtype (the prefetch seam's link cotangent — JAX gives
    integer outputs a float0 tangent, which cannot transport the reloaded
    bytes).  bitcast is bit-exact both ways; fp8 payloads pass through."""
    import jax
    import jax.numpy as jnp

    if codec == "int8":
        return jax.lax.bitcast_convert_type(payload, jnp.float8_e4m3fn)
    return payload


def from_transport(payload, codec: str):
    """Inverse of ``to_transport``: recover the int8 payload bytes."""
    import jax
    import jax.numpy as jnp

    if codec == "int8":
        return jax.lax.bitcast_convert_type(payload, jnp.int8)
    return payload
