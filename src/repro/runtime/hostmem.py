"""Shared host-memory-kind helpers (DESIGN.md §10/§11).

Both executed-offload paths — activations (core/offload.py) and optimizer
moments (optim/adamw.py) — place tensors into the best host memory space the
backend exposes and move them back with explicit ``device_put`` dataflow:

  * ``pinned_host``   on TPU/GPU (DMA-able, the paper's offload target);
  * ``unpinned_host`` on CPU (XLA folds host into device, but the program
    structure — and therefore the jaxpr accounting — is identical);
  * ``None``          when the backend has no memory kinds at all, in which
    case callers fall back to the barrier-fenced staged-copy emulation
    (``optimization_barrier`` around the named save point) so the graph
    keeps the same shape.

This module is the single home for the memory-kind probe and the D2H/H2D
primitives; it imports nothing from ``repro`` so every layer (core, optim,
runtime, parallel) can use it without cycles.
"""
from __future__ import annotations

from typing import Optional

import jax

try:  # public home moves across jax versions
    from jax.sharding import TransferToMemoryKind
except ImportError:  # pragma: no cover - version-dependent
    try:
        from jax._src.sharding_impls import TransferToMemoryKind
    except ImportError:
        TransferToMemoryKind = None

DEVICE_KIND = "device"
HOST_KIND_PREFERENCE = ("pinned_host", "unpinned_host")

_HOST_KIND_CACHE: dict = {}


def host_memory_kind(backend: Optional[str] = None) -> Optional[str]:
    """Best host memory kind the default device exposes: 'pinned_host'
    (TPU/GPU) > 'unpinned_host' (CPU) > None (no memory-kind support —
    the staged-copy emulation takes over)."""
    key = backend or "default"
    if key in _HOST_KIND_CACHE:
        return _HOST_KIND_CACHE[key]
    kind = None
    if TransferToMemoryKind is not None:
        try:
            dev = jax.devices(backend)[0] if backend else jax.devices()[0]
            kinds = {m.kind for m in dev.addressable_memories()}
            for cand in HOST_KIND_PREFERENCE:
                if cand in kinds:
                    kind = cand
                    break
        except Exception:  # pragma: no cover - backend-dependent
            kind = None
    _HOST_KIND_CACHE[key] = kind
    return kind


def resolve_host_kind(host_kind="auto") -> Optional[str]:
    """'auto' -> probe the backend; anything else passes through (a kind
    string, or None to force the barrier-fenced emulation)."""
    return host_memory_kind() if host_kind == "auto" else host_kind


def _is_traced(t) -> bool:
    return isinstance(t, jax.core.Tracer)


def _default_device_kind(t) -> str:
    """The default (device) memory kind of `t`'s devices — 'device' on
    TPU/GPU, 'unpinned_host' on CPU (host == device there)."""
    try:
        dev = next(iter(t.devices()))
    except Exception:  # pragma: no cover - non-committed values
        dev = jax.devices()[0]
    return dev.default_memory().kind


def to_host(t, kind: Optional[str]):
    """One D2H: place `t` in host memory space (emulation: barrier fence,
    so XLA must materialize the staged buffer instead of fusing it away).
    Inside jit this is the ``TransferToMemoryKind`` device_put form the
    ledger's copy accounting counts; eagerly it commits the concrete
    array's own sharding into the host kind."""
    if kind is None:
        return jax.lax.optimization_barrier(t)
    if _is_traced(t):
        return jax.device_put(t, TransferToMemoryKind(kind))
    return jax.device_put(t, host_sharding_like(t, kind))


def to_device(t, kind: Optional[str]):
    """One H2D: bring a host-resident `t` back to device memory space.
    `kind` is the host kind the value lives in (None = emulation fence)."""
    if kind is None:
        return jax.lax.optimization_barrier(t)
    if _is_traced(t):
        return jax.device_put(t, TransferToMemoryKind(DEVICE_KIND))
    return jax.device_put(t, host_sharding_like(t, _default_device_kind(t)))


def host_sharding_like(arr, kind: str):
    """A sharding placing `arr`'s layout into `kind` host memory: the
    array's own sharding re-kinded when it carries one (NamedSharding /
    SingleDeviceSharding both support with_memory_kind), else a
    single-device host placement."""
    sh = getattr(arr, "sharding", None)
    if sh is not None and hasattr(sh, "with_memory_kind"):
        try:
            return sh.with_memory_kind(kind)
        except Exception:  # pragma: no cover - exotic shardings
            pass
    from jax.sharding import SingleDeviceSharding

    return SingleDeviceSharding(jax.devices()[0], memory_kind=kind)


def host_zeros(shape, dtype, kind: Optional[str], like=None):
    """Zeros born in host memory: the buffer is built host-side (numpy) and
    placed directly into the host memory space, so *no device allocation
    ever happens* — the init_state fix for the step-0 peak spike
    (DESIGN.md §11).  With no memory kinds the plain device zeros are the
    only option (host == device there anyway).  Under abstract tracing
    (eval_shape / jit of init — the dry-run's shape-only path) a concrete
    host buffer must not materialize, so this falls back to traced zeros;
    the real init paths (launch/train.py, memledger) are eager."""
    import numpy as np

    import jax.numpy as jnp

    if kind is None:
        return jnp.zeros(shape, dtype)
    if _is_traced(like):
        # traced zeros, immediately host-placed — the jaxpr keeps the
        # host-residency fact (memledger.init_moment_device_bytes nets
        # host-placed creations out of the device-space count)
        return to_host(jnp.zeros(shape, dtype), kind)
    host = np.zeros(shape, np.dtype(dtype))
    return jax.device_put(host, host_sharding_like(like, kind))


def memory_kind_of(arr) -> Optional[str]:
    """The committed memory kind of a concrete array (None if unknown)."""
    sh = getattr(arr, "sharding", None)
    return getattr(sh, "memory_kind", None)
