"""Fault tolerance & straggler mitigation for long-running training.

Pieces (DESIGN.md §7):
  * ``StepWatchdog`` — rolling-percentile step-time monitor; flags stragglers
    (slow steps attributed to host/stage) and can trip a restart when a step
    exceeds ``timeout_factor`` x the median (hung collective / dead host).
  * ``RestartSupervisor`` — wraps the train loop; on watchdog trip or crash
    it checkpoints (if possible) and re-enters from the latest committed
    checkpoint.  Restart with a different device count re-derives the
    ParallelPlan (elastic dp) — the stage-major layout is dp-invariant.
  * preemption hooks — SIGTERM triggers checkpoint-and-exit (cloud TPU
    maintenance events surface as SIGTERM).
"""
from __future__ import annotations

import collections
import logging
import signal
import statistics
from dataclasses import dataclass, field
from typing import Callable, Optional

log = logging.getLogger("repro.ft")


class StepWatchdog:
    def __init__(self, *, window: int = 50, straggler_factor: float = 1.5,
                 timeout_factor: float = 10.0, min_samples: int = 10):
        self.times = collections.deque(maxlen=window)
        self.straggler_factor = straggler_factor
        self.timeout_factor = timeout_factor
        self.min_samples = min_samples
        self.stragglers = 0
        self.trips = 0

    def observe(self, step: int, dt: float) -> str:
        """Returns 'ok' | 'straggler' | 'timeout'."""
        verdict = "ok"
        if len(self.times) >= self.min_samples:
            med = statistics.median(self.times)
            if dt > self.timeout_factor * med:
                self.trips += 1
                verdict = "timeout"
                log.error("step %d took %.2fs (median %.2fs) — tripping "
                          "restart", step, dt, med)
            elif dt > self.straggler_factor * med:
                self.stragglers += 1
                verdict = "straggler"
                log.warning("step %d straggled: %.2fs vs median %.2fs",
                            step, dt, med)
        self.times.append(dt)
        return verdict


@dataclass
class RestartSupervisor:
    checkpointer: "object"            # checkpoint.Checkpointer
    max_restarts: int = 3
    on_preempt: Optional[Callable] = None
    _preempted: bool = field(default=False, init=False)

    def install_signal_handlers(self):
        def handler(signum, frame):
            log.warning("received signal %s — requesting checkpoint+exit",
                        signum)
            self._preempted = True
        signal.signal(signal.SIGTERM, handler)

    @property
    def preempted(self) -> bool:
        return self._preempted

    def run(self, loop_fn: Callable[[int], None], start_step: int = 0):
        """loop_fn(resume_step) runs the training loop until completion or
        raises; we restart from the latest committed checkpoint."""
        restarts = 0
        step = start_step
        while True:
            try:
                loop_fn(step)
                return
            except KeyboardInterrupt:
                raise
            except Exception as e:  # noqa
                restarts += 1
                if restarts > self.max_restarts:
                    log.error("exceeded max restarts (%d); giving up",
                              self.max_restarts)
                    raise
                latest = self.checkpointer.latest_step()
                step = 0 if latest is None else latest
                log.error("train loop failed (%s); restart %d from step %d",
                          e, restarts, step)
