"""Memory ledger: measured per-tick activation accounting for the executed
offload path (DESIGN.md §10).

Two measurement channels, both taken from the *real* program:

1. **Tagged-byte accounting** — every pipeline tick tags its Type-1
   activations with tick-qualified checkpoint names (``act_off@t3`` /
   ``act_keep@t3``, runner.chunk_tag).  ``tagged_bytes_from_jaxpr`` walks
   the traced jaxpr of the loss (through pjit / shard_map / remat / scan,
   multiplying by scan trip counts) and sums the exact aval bytes behind
   each name.  Shapes are static facts of the executed program, so this is
   exact per-device accounting — not an estimate.

2. **Runtime tick probes** — ``tick_probe`` is a custom_vjp identity the
   runner threads onto the compute path; its fwd/bwd rules fire host
   callbacks recording wall-clock per tick, so the ledger can verify that
   every tick's forward AND backward actually executed, plus coarse
   per-phase wall time.  The callbacks are unordered (ordered effects are
   not supported under shard_map), so cross-tick ordering is telemetry,
   not a contract.  On CPU the host copies are folded into device memory
   by XLA, so *exposed transfer time* is reported as the step-time delta
   against an offload-off run (see ``measure``) — on a TPU backend the
   same probes bracket the real async copies.

The ledger then replays the §5.2 recurrence M_t = M_{t-1} + A_t −
α_{t-1}A_{t-1} over the measured per-tick bytes; CI's memory-gate compares
that measured peak against the simulator's prediction from the analytic
cost model (core/simulate.spmd_tick_peak over costmodel.chunk_act_bytes).
"""
from __future__ import annotations

import csv
import functools
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import offload as ofl

try:  # jax >= 0.4.27
    from jax.experimental import io_callback
except ImportError:  # pragma: no cover - very old jax
    io_callback = None


# ---------------------------------------------------------------------------
# Runtime tick probes
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def tick_probe(x, ledger, tick):
    """Identity on the compute path; records (phase, tick, wall) per device
    into `ledger` when the program actually executes the tick."""
    return x


def _probe_fwd(x, ledger, tick):
    if io_callback is not None:
        io_callback(lambda: ledger.record_runtime("fwd", tick), None,
                    ordered=False)
    return x, None


def _probe_bwd(ledger, tick, res, g):
    if io_callback is not None:
        io_callback(lambda: ledger.record_runtime("bwd", tick), None,
                    ordered=False)
    return (g,)


tick_probe.defvjp(_probe_fwd, _probe_bwd)


# ---------------------------------------------------------------------------
# Jaxpr walk: exact tagged bytes per tick
# ---------------------------------------------------------------------------


def _aval_bytes(aval) -> int:
    try:
        size = 1
        for s in aval.shape:
            size *= int(s)
        return size * aval.dtype.itemsize
    except Exception:  # pragma: no cover - abstract tokens etc.
        return 0


def _walk(jaxpr, mult: int, out: Dict[str, int]) -> None:
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "name":
            nm = eqn.params.get("name", "")
            out[nm] = out.get(nm, 0) + mult * sum(
                _aval_bytes(v.aval) for v in eqn.invars)
            continue
        m = mult
        if eqn.primitive.name == "scan":
            m = mult * int(eqn.params.get("length", 1))
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                _walk(sub, m, out)


def _sub_jaxprs(v):
    core = jax.core
    if isinstance(v, core.ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, core.Jaxpr):
        yield v
    elif isinstance(v, (list, tuple)):
        for item in v:
            yield from _sub_jaxprs(item)


def tagged_bytes_from_jaxpr(closed_jaxpr) -> Dict[str, Dict[str, int]]:
    """{suffix: {"off": bytes, "keep": bytes}} from a traced (forward)
    jaxpr.  Walk the *forward-only* trace — under grad the remat'd backward
    repeats the name equations and would double-count."""
    raw: Dict[str, int] = {}
    _walk(closed_jaxpr.jaxpr, 1, raw)
    per: Dict[str, Dict[str, int]] = {}
    for nm, nbytes in raw.items():
        for base, kind in ((ofl.OFF_NAME, "off"), (ofl.KEEP_NAME, "keep")):
            if nm.startswith(base):
                suffix = nm[len(base):]
                per.setdefault(suffix, {"off": 0, "keep": 0})
                per[suffix][kind] += nbytes
                break
    return per


# ---------------------------------------------------------------------------
# The ledger
# ---------------------------------------------------------------------------


@dataclass
class TickRow:
    tick: int
    chunk: int            # chunk fed at this tick (last chunk on drain ticks)
    valid: bool           # False for the SPMD drain ticks (masked compute)
    alpha: float
    mat_bytes: int        # tagged bytes materialized this tick (off + keep)
    off_bytes: int        # ... of which routed to host
    resident: int = 0     # §5.2 recurrence replay, after materialization
    fwd_t: Optional[float] = None   # runtime probe wall-clock (first sample)
    bwd_t: Optional[float] = None


@dataclass
class MemLedger:
    """Measured per-tick ledger for one (cell, step) execution."""

    alphas: Tuple[float, ...] = ()
    ticks: List[TickRow] = field(default_factory=list)
    runtime_events: List[Tuple[str, int, float]] = field(default_factory=list)
    exposed_transfer_s: Optional[float] = None  # offload-on minus offload-off
    step_time_s: Optional[float] = None

    # -- runtime channel ----------------------------------------------------
    def record_runtime(self, phase: str, tick: int) -> None:
        self.runtime_events.append((phase, int(tick), time.perf_counter()))

    # -- byte channel -------------------------------------------------------
    def load_tagged(self, per_suffix: Dict[str, Dict[str, int]],
                    events, pp: int, alphas) -> None:
        """Fold jaxpr-measured per-tick bytes + the feed schedule into tick
        rows and replay the §5.2 recurrence."""
        self.alphas = tuple(float(a) for a in alphas)
        n_ticks = len(events) + pp - 1
        rows = []
        for t in range(n_ticks):
            e = min(t, len(events) - 1)
            chunk = events[e][0]
            key = f"@t{t}" if pp > 1 else f"@c{chunk}"
            got = per_suffix.get(key, {"off": 0, "keep": 0})
            rows.append(TickRow(
                tick=t, chunk=chunk, valid=t < len(events),
                alpha=self.alphas[chunk],
                mat_bytes=got["off"] + got["keep"],
                off_bytes=got["off"]))
        # M_t = M_{t-1} + A_t − off_{t-1}: the previous tick's offload
        # drains while tick t computes (§5.2, tick granularity)
        m = 0
        prev_off = 0
        for r in rows:
            m += r.mat_bytes
            r.resident = m
            m -= prev_off
            prev_off = r.off_bytes
        self.ticks = rows
        self._fold_runtime()

    def _fold_runtime(self) -> None:
        firsts: Dict[Tuple[str, int], float] = {}
        for phase, tick, t in self.runtime_events:
            key = (phase, tick)
            firsts[key] = min(firsts.get(key, t), t)
        for r in self.ticks:
            r.fwd_t = firsts.get(("fwd", r.tick))
            r.bwd_t = firsts.get(("bwd", r.tick))

    # -- derived ------------------------------------------------------------
    @property
    def peak_bytes(self) -> int:
        return max((r.resident for r in self.ticks), default=0)

    @property
    def host_bytes(self) -> int:
        """Total bytes placed in host memory across the forward."""
        return sum(r.off_bytes for r in self.ticks)

    def runtime_coverage_ok(self, *, require_bwd: bool = True) -> bool:
        """Every tick produced forward (and backward) probe samples — the
        evidence that each tick's fwd and bwd actually executed.  Exact
        cross-tick ordering is deliberately NOT asserted: the probes are
        unordered host callbacks and may drain late relative to the XLA
        schedule (DESIGN.md §10)."""
        return all(r.fwd_t is not None for r in self.ticks) and (
            not require_bwd or all(r.bwd_t is not None for r in self.ticks))

    def to_csv(self, path: str) -> None:
        with open(path, "w", newline="") as f:
            w = csv.writer(f)
            w.writerow(["tick", "chunk", "valid", "alpha", "mat_bytes",
                        "off_bytes", "resident_bytes", "fwd_t", "bwd_t"])
            for r in self.ticks:
                w.writerow([r.tick, r.chunk, int(r.valid),
                            f"{r.alpha:.4f}", r.mat_bytes, r.off_bytes,
                            r.resident,
                            "" if r.fwd_t is None else f"{r.fwd_t:.6f}",
                            "" if r.bwd_t is None else f"{r.bwd_t:.6f}"])
            w.writerow([])
            w.writerow(["peak_bytes", self.peak_bytes])
            w.writerow(["host_bytes", self.host_bytes])
            if self.step_time_s is not None:
                w.writerow(["step_time_s", f"{self.step_time_s:.6f}"])
            if self.exposed_transfer_s is not None:
                w.writerow(["exposed_transfer_s",
                            f"{self.exposed_transfer_s:.6f}"])


# ---------------------------------------------------------------------------
# Measured run driver (CPU-runnable; the memory-gate entry point)
# ---------------------------------------------------------------------------


def _drain_callbacks() -> None:
    """Wait for all pending host callbacks (the unordered tick probes) —
    jax.block_until_ready only waits on array outputs."""
    barrier = getattr(jax, "effects_barrier", None)
    if barrier is not None:
        barrier()


def build_step(cell, *, data_size: int, model_size: int, tokens=None,
               labels=None, seed: int = 0, ledger=None,
               with_grad: bool = True):
    """The shared shard_map'd step scaffold over `cell`'s mesh layout:
    params stacked stage-major, the dp-major batch layout, and the
    pipeline loss (plus psum'd stage grads when `with_grad`), with
    optional ledger probes on the compute path.

    Returns ``(fn, (g_stage, globals, batch))``.  The measurement harness
    (``measure``), the memory-gate, and the honesty tests all build their
    executable here, so what the gate measures is by construction the same
    program the tests assert on."""
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import compat_make_mesh
    from repro.parallel.runner import (_in_specs_for_params, batch_struct,
                                       run_pipeline, shard_map)

    plan = cell.plan
    mdef, cfg = cell.mdef, cell.cfg
    mesh = compat_make_mesh((data_size, model_size), ("data", "model"))
    key = jax.random.PRNGKey(seed)
    stages = [mdef.init_stage_params(key, s, plan.pp, cell.dtype)
              for s in range(plan.pp)]
    g_stage = jax.tree_util.tree_map(
        lambda *ls: jnp.stack([ls[i % plan.pp] for i in range(data_size)]),
        *stages)
    gl = mdef.init_globals(key, cell.dtype)
    if tokens is None:
        tokens = jax.random.randint(
            key, (cell.b_loc * plan.dp, cell.shape.seq_len), 0,
            cfg.vocab_size)
    if labels is None:
        labels = jnp.roll(tokens, -1, axis=1)
    b_loc = tokens.shape[0] // plan.dp

    def lay(x):
        return jnp.stack([x[(i // plan.pp) * b_loc:
                            (i // plan.pp + 1) * b_loc]
                          for i in range(data_size)])[None]

    batch = {"tokens": lay(tokens), "labels": lay(labels)}
    pspecs = _in_specs_for_params(cell)
    _, bspecs = batch_struct(cell)

    def body(stage_p, g, b):
        ctx = cell.ctx()
        stage_p = jax.tree_util.tree_map(
            lambda a: a.reshape(a.shape[1:]), stage_p)
        tok = b["tokens"].reshape(b["tokens"].shape[2:])
        lab = b["labels"].reshape(b["labels"].shape[2:])

        def loss(stage_p, g):
            out = run_pipeline(cell, ctx, stage_p, g, tok, lab,
                               None, with_loss=True, ledger=ledger)
            num = ctx.psum_loss_all(out["loss"])
            den = ctx.psum_loss_all(out["denom"])
            return num / jnp.maximum(den, 1.0)

        if with_grad:
            l, gr = jax.value_and_grad(loss, argnums=(0, 1))(stage_p, g)
            gs = jax.tree_util.tree_map(lambda a: a[None],
                                        ctx.psum_grads(gr[0]))
            return l, gs
        return (loss(stage_p, g),
                jax.tree_util.tree_map(lambda a: a[None], stage_p))

    fn = shard_map(body, mesh,
                   in_specs=(pspecs["stages"], pspecs["globals"], bspecs),
                   out_specs=(P(), pspecs["stages"]))
    return fn, (g_stage, gl, batch)


def predicted_spmd_peak(cell) -> float:
    """The simulator's predicted §5.2 peak for `cell`'s executed form:
    analytic tagged bytes (costmodel.chunk_act_bytes, scaled from the
    bf16 estimate to the cell's activation dtype) played through
    simulate.spmd_tick_peak over the runner's feed events.  The single
    formula behind the CI memory-gate, the honesty tests, and the
    ablation example."""
    from repro.core import costmodel as cm
    from repro.core import simulate as sim
    from repro.parallel import runner

    events = runner.pipeline_feed_events(cell.plan, cell.sched.n)
    acts = cm.chunk_act_bytes(cell.cfg, cell.sched.lengths,
                              batch=cell.b_loc, pp=cell.plan.pp,
                              sp=cell.plan.sp,
                              grad_accum=cell.plan.grad_accum)
    scale = jnp.dtype(cell.dtype).itemsize / cm.ACT_ITEMSIZE
    peak, _ = sim.spmd_tick_peak(events, pp=cell.plan.pp,
                                 chunk_acts=[a * scale for a in acts],
                                 alphas=cell.alphas)
    return peak


def measure(cell, *, data_size: int, model_size: int, seed: int = 0,
            baseline: bool = True) -> MemLedger:
    """Execute one real train-grad step of `cell` on an emulated mesh with
    the ledger attached, measure the tagged bytes from the traced jaxpr,
    and (optionally) time an offload-off baseline for the exposed-transfer
    estimate.  Requires grad_accum == 1 (the jaxpr scan walk would otherwise
    multiply the per-microbatch bytes by the accumulation factor)."""
    import dataclasses

    from repro.parallel import runner

    plan = cell.plan
    assert plan.grad_accum == 1, "measure() needs grad_accum == 1"
    ledger = MemLedger()
    mk = dict(data_size=data_size, model_size=model_size, seed=seed)
    fn_grad, args = build_step(cell, ledger=ledger, with_grad=True, **mk)
    fn_fwd, _ = build_step(cell, ledger=None, with_grad=False, **mk)

    # 1) exact tagged bytes from the forward-only trace (no remat dup)
    per_suffix = tagged_bytes_from_jaxpr(jax.make_jaxpr(fn_fwd)(*args))

    # 2) executed step with runtime probes
    exe = jax.jit(fn_grad)
    jax.block_until_ready(exe(*args))
    _drain_callbacks()
    ledger.runtime_events.clear()      # drop compile-run samples
    t0 = time.perf_counter()
    jax.block_until_ready(exe(*args))
    ledger.step_time_s = time.perf_counter() - t0
    _drain_callbacks()                 # probes may land after the arrays

    events = runner.pipeline_feed_events(plan, cell.sched.n)
    ledger.load_tagged(per_suffix, events, plan.pp, cell.alphas)

    # 3) offload-off baseline: the exposed-transfer estimate
    if baseline and plan.offload:
        cell_off = dataclasses.replace(
            cell, plan=dataclasses.replace(plan, offload=False),
            alphas=tuple(0.0 for _ in cell.alphas))
        fn_off, args_off = build_step(cell_off, ledger=None,
                                      with_grad=True, **mk)
        exe_off = jax.jit(fn_off)
        jax.block_until_ready(exe_off(*args_off))
        t0 = time.perf_counter()
        jax.block_until_ready(exe_off(*args_off))
        ledger.exposed_transfer_s = max(
            0.0, ledger.step_time_s - (time.perf_counter() - t0))
    return ledger
