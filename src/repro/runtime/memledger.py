"""Memory ledger: measured per-tick activation + optimizer-state accounting
for the executed offload paths (DESIGN.md §10/§11).

Measurement channels, all taken from the *real* program:

1. **Tagged-byte accounting** — every pipeline tick tags its Type-1
   activations with tick-qualified checkpoint names (``act_off@t3`` /
   ``act_keep@t3``, runner.chunk_tag).  ``tagged_bytes_from_jaxpr`` walks
   the traced jaxpr of the loss (through pjit / shard_map / remat / scan,
   multiplying by scan trip counts) and sums the exact aval bytes behind
   each name.  Shapes are static facts of the executed program, so this is
   exact per-device accounting — not an estimate.

2. **Runtime tick probes** — ``tick_probe`` is a custom_vjp identity the
   runner threads onto the compute path; its fwd/bwd rules fire host
   callbacks recording wall-clock per tick, so the ledger can verify that
   every tick's forward AND backward actually executed, plus coarse
   per-phase wall time.  The callbacks are unordered (ordered effects are
   not supported under shard_map), so cross-tick ordering is telemetry,
   not a contract.  On CPU the host copies are folded into device memory
   by XLA, so *exposed transfer time* is reported as the step-time delta
   against an offload-off run (see ``measure``) — on a TPU backend the
   same probes bracket the real async copies.

3. **Moments channel** (PR 4) — when the plan offloads optimizer state,
   ``apply_update`` names every host-resident AdamW moment leaf
   (``opt_m@<i>`` / ``opt_v@<i>``, optim/adamw.py) and stages exactly one
   H2D per leaf into the device update.  ``moment_bytes_from_jaxpr`` walks
   the traced update for those names, ``device_put_kinds`` counts the
   explicit H2D/D2H copies per memory kind, and ``update_probe`` is the
   update-phase runtime-evidence hook.  The measured numbers must match
   the cost model's closed form (``costmodel.moment_bytes_per_param``) and
   the one-H2D-per-leaf contract (tests/test_opt_offload.py).

4. **H2D channel** (PR 5, DESIGN.md §12) — ``price_h2d`` replays the
   backward reload lane over the measured per-tick off-bytes and the
   measured backward windows (bwd probe wall clocks), under the plan's
   ``prefetch`` placement: "ahead" exposes only the reload time that
   overflows the next tick's backward window, "sync" (autodiff placement)
   exposes every reload in full.  Per-tick ``h2d_stall_s`` CSV column plus
   ``h2d_exposed_s``/``prefetch_ahead`` summary rows; the memgate's
   prefetch ablation gates the strict ahead-vs-sync reduction.

0. **Pool channel** (Type-0, DESIGN.md §16) — serving has no activation
   recurrence; its device-memory story is the paged KV pool
   (``runtime/kvpool.py``).  ``PoolChannel`` records the measured per-rank
   bytes of the real pool arrays against the cost model's closed form
   (``costmodel.kv_pool_bytes``), plus the host allocator's peak / lifetime
   block counts as the recycling evidence.  CI's serve half of the
   memory-gate holds the measured/predicted ratio to the same 1.1x honesty
   band the train channels get.

5. **Compressed channel** (DESIGN.md §14) — when the plan sets
   ``offload_dtype``, the traced ``act_off@…`` names carry the 1-byte
   codec payload and ``act_scale@…`` names the device-resident per-row
   fp32 scales.  The ledger keeps ``off_bytes`` in *raw* device units
   (what the §5.2 recurrence drains — elems × the activation itemsize)
   and reports the honest host/wire side separately as
   ``off_wire_bytes`` plus ``scale_bytes``; ``price_h2d`` prices the
   reload lane over the wire form.

The ledger then replays the §5.2 recurrence M_t = M_{t-1} + A_t −
α_{t-1}A_{t-1} over the measured per-tick bytes; CI's memory-gate compares
that measured peak — plus the device-resident moments term — against the
simulator's prediction from the analytic cost model
(core/simulate.spmd_tick_peak over costmodel.chunk_act_bytes with
row-quantized alphas, plus costmodel.moment_bytes_per_param for the
opt-state gates).
"""
from __future__ import annotations

import csv
import functools
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import offload as ofl

try:  # jax >= 0.4.27
    from jax.experimental import io_callback
except ImportError:  # pragma: no cover - very old jax
    io_callback = None


# ---------------------------------------------------------------------------
# Runtime tick probes
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def tick_probe(x, ledger, tick):
    """Identity on the compute path; records (phase, tick, wall) per device
    into `ledger` when the program actually executes the tick."""
    return x


def _probe_fwd(x, ledger, tick):
    if io_callback is not None:
        io_callback(lambda: ledger.record_runtime("fwd", tick), None,
                    ordered=False)
    return x, None


def _probe_bwd(ledger, tick, res, g):
    if io_callback is not None:
        io_callback(lambda: ledger.record_runtime("bwd", tick), None,
                    ordered=False)
    return (g,)


tick_probe.defvjp(_probe_fwd, _probe_bwd)


# ---------------------------------------------------------------------------
# Jaxpr walk: exact tagged bytes per tick
# ---------------------------------------------------------------------------


# The traversal itself lives in analysis/dataflow.py (DESIGN.md §17) — one
# shared walker serves the ledger's byte/copy accounting and the static
# contract auditor.  The underscore aliases are kept because the honesty
# tests reach for them when sizing expected buffers.
from repro.analysis import dataflow as _df  # noqa: E402

_DTYPE_BITS = _df.DTYPE_BITS
_aval_elems = _df.aval_elems
_aval_bytes = _df.aval_bytes
_sub_jaxprs = _df.sub_jaxprs


def tagged_bytes_from_jaxpr(closed_jaxpr) -> Dict[str, Dict[str, int]]:
    """{suffix: {"off": bytes, "off_elems": n, "keep": bytes,
    "scale": bytes}} from a traced (forward) jaxpr.  Walk the
    *forward-only* trace — under grad the remat'd backward repeats the
    name equations and would double-count.

    "off" is the bytes of the named host rows *as traced* — under a
    compressed plan (DESIGN.md §14) that is the wire/host payload;
    "off_elems" is the element count behind the same names, so callers can
    reconstruct the raw device bytes the §5.2 recurrence drains (elems ×
    the activation itemsize) independent of the transport dtype.  "scale"
    is the device-resident per-row codec scales (``act_scale@…``), zero on
    uncompressed plans."""
    raw, elems = _df.walk_named(closed_jaxpr)
    per: Dict[str, Dict[str, int]] = {}
    bases = ((ofl.OFF_NAME, "off"), (ofl.KEEP_NAME, "keep"),
             (ofl.SCALE_NAME, "scale"))
    for nm, nbytes in raw.items():
        for base, kind in bases:
            if nm.startswith(base):
                suffix = nm[len(base):]
                per.setdefault(suffix, {"off": 0, "off_elems": 0,
                                        "keep": 0, "scale": 0})
                per[suffix][kind] += nbytes
                if kind == "off":
                    per[suffix]["off_elems"] += elems.get(nm, 0)
                break
    return per


# ---------------------------------------------------------------------------
# Moments channel: optimizer-state bytes + explicit-copy accounting
# ---------------------------------------------------------------------------


def moment_bytes_from_jaxpr(closed_jaxpr) -> Dict[str, object]:
    """{"m": bytes, "v": bytes, "leaves": {name: bytes}} from the traced
    optimizer update: the aval bytes behind every leaf-qualified
    ``opt_m@<i>`` / ``opt_v@<i>`` checkpoint name (optim/adamw.py).  Like
    the activation walk, shapes are static facts of the executed program —
    exact accounting, not an estimate."""
    from repro.optim.adamw import OPT_M_NAME, OPT_V_NAME

    raw, _ = _df.walk_named(closed_jaxpr)
    leaves = {nm: b for nm, b in raw.items()
              if nm.startswith(OPT_M_NAME + "@")
              or nm.startswith(OPT_V_NAME + "@")}
    m_b = sum(b for nm, b in leaves.items() if nm.startswith(OPT_M_NAME))
    v_b = sum(b for nm, b in leaves.items() if nm.startswith(OPT_V_NAME))
    # compressed residency (§14): the per-row fp32 scales are host leaves
    # of their own, named opt_{m,v}_scale@<i> — deliberately NOT under the
    # opt_m@/opt_v@ prefixes, so m/v stay payload-only sums
    scales = {nm: b for nm, b in raw.items()
              if nm.startswith(OPT_M_NAME + "_scale@")
              or nm.startswith(OPT_V_NAME + "_scale@")}
    return {"m": m_b, "v": v_b, "scale": sum(scales.values()),
            "leaves": leaves, "scale_leaves": scales}


def device_put_kinds(closed_jaxpr) -> Dict[str, int]:
    """{memory_kind: count} of explicit ``device_put`` equations in a
    traced program — ``counts["device"]`` is the H2D copies, host kinds
    are the D2H side.  The explicit moments path must show exactly one H2D
    per moment leaf per step (the one-copy contract, DESIGN.md §11).
    Equations are counted once regardless of scan nesting (per-step
    contract accounting, not per-execution)."""
    return _df.walk_device_puts(closed_jaxpr)


def init_moment_device_bytes(params, opt_dtype, *, offload_moments: bool,
                             host_kind="auto",
                             moments_dtype: str = "none") -> int:
    """Bytes of moment zeros that end up resident in *device* memory space
    after ``adamw.init_state``, from the traced init: creation equations
    (``broadcast_in_dim`` — jnp.zeros) allocate in the default device
    space; creations that are immediately host-placed (hostmem.host_zeros
    emits zeros → host-kind device_put under tracing, and a numpy buffer →
    host placement eagerly) are netted out.  The step-0 peak regression
    (tests/test_opt_offload.py) asserts this is 0 when moments are
    offloaded."""
    from repro.optim import adamw
    from repro.runtime import hostmem

    cjx = jax.make_jaxpr(lambda ps: adamw.init_state(
        ps, opt_dtype, offload_moments=offload_moments,
        host_kind=host_kind, moments_dtype=moments_dtype))(params)
    created: Dict[object, int] = {}
    dev = 0
    for eqn in cjx.jaxpr.eqns:
        if eqn.primitive.name == "broadcast_in_dim":
            nbytes = sum(_aval_bytes(v.aval) for v in eqn.outvars)
            dev += nbytes
            for v in eqn.outvars:
                created[v] = _aval_bytes(v.aval)
        elif eqn.primitive.name == "device_put":
            kinds = [getattr(d, "memory_kind", None)
                     for d in eqn.params.get("devices", ())]
            if kinds and all(k not in (None, hostmem.DEVICE_KIND)
                             for k in kinds):
                for v in eqn.invars:
                    dev -= created.pop(v, 0)
    return dev


@dataclass
class MomentChannel:
    """Measured optimizer-state residency for one cell's update step."""

    offloaded: bool
    mode: str                      # moments_mode: explicit | xla
    opt_dtype: str
    host_kind: Optional[str]
    m_bytes: int                   # real state buffers (Σ leaf nbytes)
    v_bytes: int
    n_leaves: int                  # leaves per moment tree
    max_pair_bytes: int            # largest single-leaf m+v pair
    named_bytes: int               # jaxpr walk over opt_m@/opt_v@ names
    h2d_count: int                 # explicit copies into device space
    d2h_count: int                 # explicit copies into host kinds
    init_dev_bytes: int            # device-materialized zeros at init

    @property
    def total_bytes(self) -> int:
        return self.m_bytes + self.v_bytes

    @property
    def host_bytes(self) -> int:
        """Bytes resident in host memory between steps."""
        return self.total_bytes if self.offloaded else 0

    @property
    def dev_resident_bytes(self) -> int:
        """Bytes resident in device memory through the whole step."""
        return 0 if self.offloaded else self.total_bytes

    @property
    def dev_peak_bytes(self) -> int:
        """Device-memory contribution at the step peak: the full set when
        moments live on device; the per-leaf staging pair when offloaded
        (the one-H2D-per-leaf contract bounds what the update stages —
        actual concurrency is the hardware scheduler's, DESIGN.md §11)."""
        return self.max_pair_bytes if self.offloaded else self.total_bytes


@dataclass
class PoolChannel:
    """Measured paged-KV pool residency for one serve engine (Type-0).

    ``measured_bytes`` is the per-rank device footprint of the real pool
    arrays; ``predicted_bytes`` the cost model's closed form
    (``costmodel.kv_pool_bytes``).  ``peak_blocks``/``total_blocks`` come
    from the host allocator over a served trace: lifetime allocations
    exceeding the physical block count while the peak stays within it is
    the evidence that freed blocks are actually recycled."""

    n_blocks: int
    block_tokens: int
    n_layers: int
    measured_bytes: int
    predicted_bytes: int
    peak_blocks: int = 0
    total_blocks: int = 0

    @property
    def ratio(self) -> float:
        return self.measured_bytes / max(self.predicted_bytes, 1)


# ---------------------------------------------------------------------------
# The ledger
# ---------------------------------------------------------------------------


@dataclass
class TickRow:
    tick: int
    chunk: int            # chunk fed at this tick (last chunk on drain ticks)
    valid: bool           # False for the SPMD drain ticks (masked compute)
    alpha: float
    mat_bytes: int        # tagged bytes materialized this tick (off + keep)
    off_bytes: int        # ... of which routed to host, in RAW device bytes
    resident: int = 0     # §5.2 recurrence replay, after materialization
    fwd_t: Optional[float] = None   # runtime probe wall-clock (first sample)
    bwd_t: Optional[float] = None
    h2d_stall_s: Optional[float] = None  # exposed reload time (price_h2d)
    # compressed channel (DESIGN.md §14): the bytes that actually cross the
    # wire / sit in host memory (codec payload; None = raw, == off_bytes)
    # and the device-resident per-row scale bytes that ride the keep set.
    # off_bytes deliberately stays in raw device units — the §5.2 recurrence
    # drains full activation rows from device memory regardless of how few
    # bytes their host copy takes.
    off_wire_bytes: Optional[int] = None
    scale_bytes: int = 0


@dataclass
class MemLedger:
    """Measured per-tick ledger for one (cell, step) execution."""

    alphas: Tuple[float, ...] = ()
    ticks: List[TickRow] = field(default_factory=list)
    runtime_events: List[Tuple[str, int, float]] = field(default_factory=list)
    exposed_transfer_s: Optional[float] = None  # offload-on minus offload-off
    step_time_s: Optional[float] = None
    moments: Optional[MomentChannel] = None     # opt-state channel (§11)
    pool: Optional[PoolChannel] = None          # Type-0 KV pool (§16)
    opt_time_s: Optional[float] = None          # measured update wall time
    prefetch: str = "ahead"                     # plan's reload placement
    h2d_exposed_s: Optional[float] = None       # Σ per-tick h2d_stall_s
    offload_codec: str = "none"                 # act-channel codec (§14)

    # -- runtime channel ----------------------------------------------------
    def record_runtime(self, phase: str, tick: int) -> None:
        self.runtime_events.append((phase, int(tick), time.perf_counter()))

    # -- byte channel -------------------------------------------------------
    def load_tagged(self, per_suffix: Dict[str, Dict[str, int]],
                    events, pp: int, alphas,
                    act_itemsize: Optional[int] = None) -> None:
        """Fold jaxpr-measured per-tick bytes + the feed schedule into tick
        rows and replay the §5.2 recurrence.

        ``act_itemsize`` converts the walked off-channel element counts
        back to raw device bytes; under a compressed plan the traced off
        names carry the 1-byte payload, so ``off_bytes`` (what the device
        recurrence drains) and ``off_wire_bytes`` (what the host/link
        carries) diverge.  Without it (or without element counts in
        ``per_suffix``) the traced bytes are used for both — exact for
        uncompressed plans."""
        self.alphas = tuple(float(a) for a in alphas)
        n_ticks = len(events) + pp - 1
        rows = []
        for t in range(n_ticks):
            e = min(t, len(events) - 1)
            chunk = events[e][0]
            key = f"@t{t}" if pp > 1 else f"@c{chunk}"
            got = per_suffix.get(key, {})
            wire = got.get("off", 0)
            n_el = got.get("off_elems")
            raw_off = (n_el * act_itemsize
                       if act_itemsize is not None and n_el is not None
                       else wire)
            scale = got.get("scale", 0)
            rows.append(TickRow(
                tick=t, chunk=chunk, valid=t < len(events),
                alpha=self.alphas[chunk],
                mat_bytes=raw_off + got.get("keep", 0) + scale,
                off_bytes=raw_off,
                off_wire_bytes=wire,
                scale_bytes=scale))
        # M_t = M_{t-1} + A_t − off_{t-1}: the previous tick's offload
        # drains while tick t computes (§5.2, tick granularity).  Only the
        # raw activation rows drain — the codec scales stay device-resident
        # with the keep set until the backward consumes them.
        m = 0
        prev_off = 0
        for r in rows:
            m += r.mat_bytes
            r.resident = m
            m -= prev_off
            prev_off = r.off_bytes
        self.ticks = rows
        self._fold_runtime()

    def _fold_runtime(self) -> None:
        firsts: Dict[Tuple[str, int], float] = {}
        for phase, tick, t in self.runtime_events:
            key = (phase, tick)
            firsts[key] = min(firsts.get(key, t), t)
        for r in self.ticks:
            r.fwd_t = firsts.get(("fwd", r.tick))
            r.bwd_t = firsts.get(("bwd", r.tick))

    # -- h2d channel --------------------------------------------------------
    def price_h2d(self, *, bw: float, prefetch: Optional[str] = None) -> float:
        """Exposed-H2D replay over the *measured* per-tick bytes and
        backward windows (DESIGN.md §12): the per-tick reload volume is the
        ledger's measured ``off_bytes``, the hiding window is the measured
        backward duration of the next tick (from the bwd probe wall clocks
        — the backward runs ticks in reverse, so tick t's reload can hide
        under tick t+1's backward, whose duration is
        ``bwd_t[t] − bwd_t[t+1]``), and the transfer is priced at `bw`.

        prefetch="ahead" exposes only the part of each reload that does not
        fit its window; "sync" exposes every reload in full (the autodiff
        placement serializes it into its own backward).  Passing an
        explicit `prefetch` prices the counterfactual placement *without*
        touching the ledger's stored per-tick/summary fields — those always
        reflect ``self.prefetch``, the mode the step actually ran.  Like
        the exposed-transfer channel, this is the honest CPU-runnable form
        of the measurement (§9): bytes and windows are measured, the link
        bandwidth is the cost model's — real async-copy overlap is a TPU
        validation item (ROADMAP)."""
        mode = prefetch if prefetch is not None else self.prefetch
        rows = self.ticks
        total = 0.0
        for i, r in enumerate(rows):
            # the reload lane carries the host copy: the codec payload
            # under a compressed plan (off_wire_bytes), raw rows otherwise
            vol = (r.off_wire_bytes if r.off_wire_bytes is not None
                   else r.off_bytes)
            rld = vol / bw if bw else 0.0
            if mode == "sync":
                stall = rld
            else:
                window = 0.0
                if (i + 1 < len(rows) and r.bwd_t is not None
                        and rows[i + 1].bwd_t is not None):
                    window = max(0.0, r.bwd_t - rows[i + 1].bwd_t)
                stall = max(0.0, rld - window)
            if mode == self.prefetch:
                r.h2d_stall_s = stall
            total += stall
        if mode == self.prefetch:
            self.h2d_exposed_s = total
        return total

    # -- derived ------------------------------------------------------------
    @property
    def peak_bytes(self) -> int:
        return max((r.resident for r in self.ticks), default=0)

    @property
    def host_bytes(self) -> int:
        """Total bytes placed in host memory across the forward — the wire
        form when the act channel is compressed (§14)."""
        return sum((r.off_wire_bytes if r.off_wire_bytes is not None
                    else r.off_bytes) for r in self.ticks)

    @property
    def off_bytes_total(self) -> int:
        """Raw device bytes the offload channel drained (codec-independent)."""
        return sum(r.off_bytes for r in self.ticks)

    @property
    def off_wire_bytes_total(self) -> int:
        return sum((r.off_wire_bytes if r.off_wire_bytes is not None
                    else r.off_bytes) for r in self.ticks)

    @property
    def scale_bytes_total(self) -> int:
        """Device-resident codec scale bytes across the forward (§14)."""
        return sum(r.scale_bytes for r in self.ticks)

    @property
    def combined_peak_bytes(self) -> int:
        """Device peak with the optimizer-state term folded in: the §5.2
        activation peak plus the moments' device contribution (full set
        when device-resident; the per-leaf staging pair when offloaded).
        Equals ``peak_bytes`` when no moments channel was measured."""
        mom = self.moments.dev_peak_bytes if self.moments else 0
        return self.peak_bytes + mom

    def runtime_coverage_ok(self, *, require_bwd: bool = True,
                            require_update: Optional[bool] = None) -> bool:
        """Every tick produced forward (and backward) probe samples — the
        evidence that each tick's fwd and bwd actually executed — and,
        when the moments channel is measured (require_update defaults to
        that), at least one update-phase probe fired.  Exact cross-tick
        ordering is deliberately NOT asserted: the probes are unordered
        host callbacks and may drain late relative to the XLA schedule
        (DESIGN.md §10)."""
        if require_update is None:
            require_update = self.moments is not None
        ok = all(r.fwd_t is not None for r in self.ticks) and (
            not require_bwd or all(r.bwd_t is not None for r in self.ticks))
        if require_update:
            ok = ok and any(p == "upd" for p, _, _ in self.runtime_events)
        return ok

    def to_csv(self, path: str) -> None:
        mom = self.moments
        with open(path, "w", newline="") as f:
            w = csv.writer(f)
            w.writerow(["tick", "chunk", "valid", "alpha", "mat_bytes",
                        "off_bytes", "off_wire_bytes", "scale_bytes",
                        "resident_bytes", "moments_dev_bytes",
                        "h2d_stall_s", "fwd_t", "bwd_t"])
            for r in self.ticks:
                w.writerow([r.tick, r.chunk, int(r.valid),
                            f"{r.alpha:.4f}", r.mat_bytes, r.off_bytes,
                            ("" if r.off_wire_bytes is None
                             else r.off_wire_bytes),
                            r.scale_bytes,
                            r.resident,
                            "" if mom is None else mom.dev_resident_bytes,
                            ("" if r.h2d_stall_s is None
                             else f"{r.h2d_stall_s:.9f}"),
                            "" if r.fwd_t is None else f"{r.fwd_t:.6f}",
                            "" if r.bwd_t is None else f"{r.bwd_t:.6f}"])
            w.writerow([])
            w.writerow(["peak_bytes", self.peak_bytes])
            w.writerow(["host_bytes", self.host_bytes])
            w.writerow(["offload_codec", self.offload_codec])
            w.writerow(["off_bytes_total", self.off_bytes_total])
            w.writerow(["off_wire_bytes_total", self.off_wire_bytes_total])
            w.writerow(["scale_bytes_total", self.scale_bytes_total])
            w.writerow(["prefetch_ahead", int(self.prefetch == "ahead")])
            if self.h2d_exposed_s is not None:
                w.writerow(["h2d_exposed_s", f"{self.h2d_exposed_s:.9f}"])
            if self.step_time_s is not None:
                w.writerow(["step_time_s", f"{self.step_time_s:.6f}"])
            if self.exposed_transfer_s is not None:
                w.writerow(["exposed_transfer_s",
                            f"{self.exposed_transfer_s:.6f}"])
            if mom is not None:
                w.writerow(["moments_offloaded", int(mom.offloaded)])
                w.writerow(["moments_total_bytes", mom.total_bytes])
                w.writerow(["moments_host_bytes", mom.host_bytes])
                w.writerow(["moments_dev_peak_bytes", mom.dev_peak_bytes])
                w.writerow(["moments_named_bytes", mom.named_bytes])
                w.writerow(["moments_h2d_per_step", mom.h2d_count])
                w.writerow(["combined_peak_bytes", self.combined_peak_bytes])
                if self.opt_time_s is not None:
                    w.writerow(["opt_time_s", f"{self.opt_time_s:.6f}"])
            if self.pool is not None:
                w.writerow(["kv_pool_bytes", self.pool.measured_bytes])
                w.writerow(["kv_pool_predicted_bytes",
                            self.pool.predicted_bytes])
                w.writerow(["kv_pool_blocks", self.pool.n_blocks])
                w.writerow(["kv_pool_block_tokens", self.pool.block_tokens])
                w.writerow(["kv_pool_layers", self.pool.n_layers])
                w.writerow(["kv_pool_peak_blocks", self.pool.peak_blocks])
                w.writerow(["kv_pool_total_blocks", self.pool.total_blocks])


def read_csv(path: str) -> Dict[str, object]:
    """Round-trip reader for ``MemLedger.to_csv``: returns
    {"rows": [per-tick dicts], "summary": {key: number}}.  The per-tick
    section ends at the blank line; summary lines are key/value pairs.
    Used by the CSV round-trip tests and by offline analysis of the CI
    memledger artifacts."""
    rows: List[Dict[str, object]] = []
    summary: Dict[str, float] = {}
    with open(path, newline="") as f:
        r = csv.reader(f)
        header = next(r)
        in_rows = True
        for line in r:
            if not line:
                in_rows = False
                continue
            if in_rows:
                row: Dict[str, object] = {}
                for k, val in zip(header, line):
                    if val == "":
                        row[k] = None
                    elif k == "alpha" or k.endswith("_t") or k.endswith("_s"):
                        row[k] = float(val)
                    else:
                        row[k] = int(val)
                rows.append(row)
            else:
                key, val = line[0], line[1]
                # try-int / try-float / else-string: summary values are
                # mostly numeric, but e.g. offload_codec is a plain string
                try:
                    summary[key] = int(val)
                except ValueError:
                    try:
                        summary[key] = float(val)
                    except ValueError:
                        summary[key] = val
    return {"rows": rows, "summary": summary}


def update_probe(ledger):
    """Identity hook for ``adamw.apply_update(probe=...)``: fires an
    unordered host callback when the update phase actually executes — the
    moments-channel analogue of ``tick_probe``'s fwd/bwd evidence."""
    def hook(step):
        if io_callback is not None:
            io_callback(lambda: ledger.record_runtime("upd", 0), None,
                        ordered=False)
        return step
    return hook


# ---------------------------------------------------------------------------
# Measured run driver (CPU-runnable; the memory-gate entry point)
# ---------------------------------------------------------------------------


def _drain_callbacks() -> None:
    """Wait for all pending host callbacks (the unordered tick probes) —
    jax.block_until_ready only waits on array outputs."""
    barrier = getattr(jax, "effects_barrier", None)
    if barrier is not None:
        barrier()


def step_fn(cell, *, data_size: int, model_size: int, ledger=None,
            with_grad: bool = True):
    """Just the shard_map'd step function of ``build_step`` — no argument
    arrays are created, so the static auditor (analysis/audit.py) can
    ``jax.make_jaxpr`` it over ShapeDtypeStructs without allocating."""
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import compat_make_mesh
    from repro.parallel.runner import (_in_specs_for_params, batch_struct,
                                       run_pipeline, shard_map)

    mesh = compat_make_mesh((data_size, model_size), ("data", "model"))
    pspecs = _in_specs_for_params(cell)
    _, bspecs = batch_struct(cell)

    def body(stage_p, g, b):
        ctx = cell.ctx()
        stage_p = jax.tree_util.tree_map(
            lambda a: a.reshape(a.shape[1:]), stage_p)
        tok = b["tokens"].reshape(b["tokens"].shape[2:])
        lab = b["labels"].reshape(b["labels"].shape[2:])
        ds = (b["doc_start"].reshape(b["doc_start"].shape[2:])
              if "doc_start" in b else None)

        def loss(stage_p, g):
            out = run_pipeline(cell, ctx, stage_p, g, tok, lab,
                               None, with_loss=True, ledger=ledger,
                               doc_start=ds)
            num = ctx.psum_loss_all(out["loss"])
            den = ctx.psum_loss_all(out["denom"])
            return num / jnp.maximum(den, 1.0)

        if with_grad:
            l, gr = jax.value_and_grad(loss, argnums=(0, 1))(stage_p, g)
            gs = jax.tree_util.tree_map(lambda a: a[None],
                                        ctx.psum_grads(gr[0]))
            return l, gs
        return (loss(stage_p, g),
                jax.tree_util.tree_map(lambda a: a[None], stage_p))

    return shard_map(body, mesh,
                     in_specs=(pspecs["stages"], pspecs["globals"], bspecs),
                     out_specs=(P(), pspecs["stages"]))


def build_step(cell, *, data_size: int, model_size: int, tokens=None,
               labels=None, doc_start=None, seed: int = 0, ledger=None,
               with_grad: bool = True):
    """The shared shard_map'd step scaffold over `cell`'s mesh layout:
    params stacked stage-major, the dp-major batch layout, and the
    pipeline loss (plus psum'd stage grads when `with_grad`), with
    optional ledger probes on the compute path.

    Returns ``(fn, (g_stage, globals, batch))``.  The measurement harness
    (``measure``), the memory-gate, and the honesty tests all build their
    executable here, so what the gate measures is by construction the same
    program the tests assert on — and ``step_fn`` is the same program the
    static auditor traces."""
    plan = cell.plan
    mdef, cfg = cell.mdef, cell.cfg
    key = jax.random.PRNGKey(seed)
    stages = [mdef.init_stage_params(key, s, plan.pp, cell.dtype)
              for s in range(plan.pp)]
    g_stage = jax.tree_util.tree_map(
        lambda *ls: jnp.stack([ls[i % plan.pp] for i in range(data_size)]),
        *stages)
    gl = mdef.init_globals(key, cell.dtype)
    if cell.varlen and tokens is None:
        # deterministic packed batch from the cell's document histogram:
        # the same corpus the budget-cell / varlen tests run against
        from repro.data import pipeline as dpipe

        pb = dpipe.packed_batch_for(cell.doc_lens, cell.shape.seq_len,
                                    rows=cell.b_loc * plan.dp,
                                    vocab_size=cfg.vocab_size, seed=seed)
        tokens = jnp.asarray(pb.tokens)
        labels = jnp.asarray(pb.labels)
        doc_start = jnp.asarray(pb.doc_start)
    if tokens is None:
        tokens = jax.random.randint(
            key, (cell.b_loc * plan.dp, cell.shape.seq_len), 0,
            cfg.vocab_size)
    if labels is None:
        labels = jnp.roll(tokens, -1, axis=1)
    b_loc = tokens.shape[0] // plan.dp

    def lay(x):
        return jnp.stack([x[(i // plan.pp) * b_loc:
                            (i // plan.pp + 1) * b_loc]
                          for i in range(data_size)])[None]

    batch = {"tokens": lay(tokens), "labels": lay(labels)}
    if cell.varlen:
        assert doc_start is not None, "varlen cell needs a doc_start array"
        batch["doc_start"] = lay(jnp.asarray(doc_start))
    fn = step_fn(cell, data_size=data_size, model_size=model_size,
                 ledger=ledger, with_grad=with_grad)
    return fn, (g_stage, gl, batch)


def predicted_spmd_peak(cell) -> float:
    """The simulator's predicted §5.2 peak for `cell`'s executed form:
    analytic tagged bytes (costmodel.chunk_act_bytes, scaled from the
    bf16 estimate to the cell's activation dtype) played through
    simulate.spmd_tick_peak over the runner's feed events, with each
    chunk's α discretized to the row split the tags actually deploy
    (``offload.quantized_alpha`` over the chunk's local row count) so the
    prediction cannot drift from the executed program at small shapes.
    The single formula behind the CI memory-gate, the honesty tests, and
    the ablation example."""
    from repro.core import costmodel as cm
    from repro.core import simulate as sim
    from repro.parallel import runner

    events = runner.pipeline_feed_events(cell.plan, cell.sched.n)
    acts = cm.chunk_act_bytes(cell.cfg, cell.sched.lengths,
                              batch=cell.b_loc, pp=cell.plan.pp,
                              sp=cell.plan.sp,
                              grad_accum=cell.plan.grad_accum)
    scale = jnp.dtype(cell.dtype).itemsize / cm.ACT_ITEMSIZE
    alphas_q = [ofl.quantized_alpha(ln // cell.plan.sp, a)
                for ln, a in zip(cell.sched.lengths, cell.alphas)]
    chunk_scales = None
    if cell.plan.offload_dtype not in (None, "none"):
        # compressed plans keep the per-row fp32 scales device-resident
        # with the keep set (§14): they enter the peak with the chunk and
        # never drain; only the offloaded row fraction has scales
        sb = cm.chunk_scale_bytes(cell.cfg, cell.sched.lengths,
                                  batch=cell.b_loc, pp=cell.plan.pp,
                                  sp=cell.plan.sp,
                                  grad_accum=cell.plan.grad_accum,
                                  offload_dtype=cell.plan.offload_dtype)
        chunk_scales = [b * a for b, a in zip(sb, alphas_q)]
    peak, _ = sim.spmd_tick_peak(events, pp=cell.plan.pp,
                                 chunk_acts=[a * scale for a in acts],
                                 alphas=alphas_q,
                                 chunk_scales=chunk_scales)
    return peak


def predicted_moment_bytes(cell, *, data_size: int) -> Tuple[float, float]:
    """(total, max_staged_pair) closed-form optimizer-state bytes for the
    measured step's stacked stage-param tree:
    ``costmodel.moment_bytes_per_param(opt_dtype)`` over the eval-shape
    param counts — the analytic side the moments channel is gated
    against.  Scope matches ``measure``'s subject: the stage-parameter
    moments (the depth-scaling term); the dp-replicated globals are
    outside the §5.2 device-budget subject."""
    import numpy as np

    from repro.core import costmodel as cm
    from repro.parallel import specs as SP

    st = SP.stage_struct(cell.mdef, cell.plan.pp, data_size, cell.dtype)
    shapes = [tuple(l.shape) for l in jax.tree_util.tree_leaves(st)]
    dt = cell.plan.opt_dtype
    mdt = getattr(cell.plan, "moments_dtype", "none")
    if mdt not in (None, "none"):
        # compressed residency (§14): per-leaf bytes = payload + per-row
        # scales, for both moments; the staged pair mirrors the measured
        # zip over the flattened (payload, scale) host leaves
        per_leaf = [cm.moment_bytes_from_shapes([s], dt, mdt)
                    for s in shapes]
        pairs = []
        for s in shapes:
            n = int(np.prod(s)) if s else 1
            rows = int(np.prod(s[:-1])) if len(s) >= 1 else 1
            pairs.append(max(2 * n, 2 * rows * cm.SCALE_ITEMSIZE))
        return sum(per_leaf), max(pairs)
    leaves = [int(np.prod(s)) for s in shapes]
    return cm.opt_state_bytes(sum(leaves), dt), cm.opt_state_bytes(
        max(leaves), dt)


def predicted_combined_peak(cell, *, data_size: int) -> float:
    """Predicted activations+moments device peak: the §5.2 tick-loop peak
    plus the moments' device term (full set when device-resident; the
    per-leaf staging pair when the plan offloads them).  The opt-state
    memory-gate's analytic side."""
    total, max_pair = predicted_moment_bytes(cell, data_size=data_size)
    mom = max_pair if cell.plan.offload_moments else total
    return predicted_spmd_peak(cell) + mom


def _measure_opt(cell, ledger: MemLedger, params, grads) -> None:
    """Measure the moments channel: trace + execute one real AdamW update
    over the measured step's stage params/grads with the plan's offload
    knobs, walk the update jaxpr for the opt_m@/opt_v@ names and the
    explicit device_put copies, and record update-phase probe evidence."""
    from repro.optim import adamw
    from repro.runtime import hostmem

    plan = cell.plan
    opt_dtype = (jnp.bfloat16 if plan.opt_dtype == "bfloat16"
                 else jnp.float32)
    kind = hostmem.host_memory_kind() if plan.offload_moments else None
    # the grads land committed to the emulated mesh (shard_map outputs);
    # co-locate the params so the update runs on the same device set, as
    # the real train_step's optimizer does
    params = jax.tree_util.tree_map(
        # transfer-lint: ok (device->device re-shard, no host copy)
        lambda p, g: jax.device_put(p, g.sharding), params, grads)
    moments_dtype = getattr(plan, "moments_dtype", "none")
    state = adamw.init_state(params, opt_dtype,
                             offload_moments=plan.offload_moments,
                             moments_dtype=moments_dtype)
    probe = update_probe(ledger)

    def opt_fn(p, g, s):
        return adamw.apply_update(
            p, g, s, lr=1e-3, offload_moments=plan.offload_moments,
            moments_mode=plan.moments_mode, probe=probe,
            moments_dtype=moments_dtype)

    cjx = jax.make_jaxpr(opt_fn)(params, grads, state)
    named = moment_bytes_from_jaxpr(cjx)
    kinds = device_put_kinds(cjx)
    leaves_m = jax.tree_util.tree_leaves(state.m)
    leaves_v = jax.tree_util.tree_leaves(state.v)
    pairs = [int(m.nbytes) + int(v.nbytes)
             for m, v in zip(leaves_m, leaves_v)]
    init_dev = init_moment_device_bytes(
        params, opt_dtype, offload_moments=plan.offload_moments,
        moments_dtype=moments_dtype)

    exe = jax.jit(opt_fn)
    jax.block_until_ready(exe(params, grads, state))
    _drain_callbacks()
    t0 = time.perf_counter()
    jax.block_until_ready(exe(params, grads, state))
    ledger.opt_time_s = time.perf_counter() - t0
    _drain_callbacks()

    ledger.moments = MomentChannel(
        offloaded=plan.offload_moments,
        mode=plan.moments_mode,
        opt_dtype=plan.opt_dtype,
        host_kind=kind,
        m_bytes=sum(int(m.nbytes) for m in leaves_m),
        v_bytes=sum(int(v.nbytes) for v in leaves_v),
        n_leaves=len(leaves_m),
        max_pair_bytes=max(pairs) if pairs else 0,
        named_bytes=named["m"] + named["v"] + named.get("scale", 0),
        h2d_count=kinds.get(hostmem.DEVICE_KIND, 0),
        d2h_count=sum(c for k, c in kinds.items()
                      if k != hostmem.DEVICE_KIND),
        init_dev_bytes=init_dev)


def measure(cell, *, data_size: int, model_size: int, seed: int = 0,
            baseline: bool = True, opt: bool = False,
            d2h_bw: Optional[float] = None, tokens=None, labels=None,
            doc_start=None) -> MemLedger:
    """Execute one real train-grad step of `cell` on an emulated mesh with
    the ledger attached, measure the tagged bytes from the traced jaxpr,
    and (optionally) time an offload-off baseline for the exposed-transfer
    estimate.  With ``opt`` the optimizer update is measured too (the
    moments channel, §11): one real AdamW step over the measured grads
    with the plan's ``offload_moments``/``moments_mode``.  ``d2h_bw``
    prices the exposed-H2D channel (§12); pass the bandwidth of the
    hardware profile the cell was resolved against when it is not the
    default V5E.  Requires grad_accum == 1 (the jaxpr scan walk would
    otherwise multiply the per-microbatch bytes by the accumulation
    factor)."""
    import dataclasses

    from repro.parallel import runner

    plan = cell.plan
    assert plan.grad_accum == 1, "measure() needs grad_accum == 1"
    ledger = MemLedger()
    mk = dict(data_size=data_size, model_size=model_size, seed=seed,
              tokens=tokens, labels=labels, doc_start=doc_start)
    fn_grad, args = build_step(cell, ledger=ledger, with_grad=True, **mk)
    fn_fwd, _ = build_step(cell, ledger=None, with_grad=False, **mk)

    # 1) exact tagged bytes from the forward-only trace (no remat dup)
    per_suffix = tagged_bytes_from_jaxpr(jax.make_jaxpr(fn_fwd)(*args))

    # 2) executed step with runtime probes
    exe = jax.jit(fn_grad)
    jax.block_until_ready(exe(*args))
    _drain_callbacks()
    ledger.runtime_events.clear()      # drop compile-run samples
    t0 = time.perf_counter()
    step_out = exe(*args)
    jax.block_until_ready(step_out)
    ledger.step_time_s = time.perf_counter() - t0
    _drain_callbacks()                 # probes may land after the arrays

    events = runner.pipeline_feed_events(plan, cell.sched.n)
    ledger.offload_codec = plan.offload_dtype
    ledger.load_tagged(per_suffix, events, plan.pp, cell.alphas,
                       act_itemsize=jnp.dtype(cell.dtype).itemsize)

    # 2c) priced exposed-H2D over the measured bytes/windows (§12)
    from repro.core import costmodel as _cm

    ledger.prefetch = plan.prefetch
    ledger.price_h2d(bw=d2h_bw if d2h_bw is not None else _cm.V5E.d2h_bw)

    # 2b) optimizer-state channel over the measured grads
    if opt:
        _measure_opt(cell, ledger, args[0], step_out[1])

    # 3) offload-off baseline: the exposed-transfer estimate
    if baseline and plan.offload:
        cell_off = dataclasses.replace(
            cell, plan=dataclasses.replace(plan, offload=False),
            alphas=tuple(0.0 for _ in cell.alphas))
        fn_off, args_off = build_step(cell_off, ledger=None,
                                      with_grad=True, **mk)
        exe_off = jax.jit(fn_off)
        jax.block_until_ready(exe_off(*args_off))
        t0 = time.perf_counter()
        jax.block_until_ready(exe_off(*args_off))
        ledger.exposed_transfer_s = max(
            0.0, ledger.step_time_s - (time.perf_counter() - t0))
    return ledger
