"""Paged/blocked KV-cache pool for continuous-batching decode (DESIGN.md §16).

The static serve path gives every request a private, maximum-length cache
row.  The pool instead shares one physical buffer per (data, model) rank and
layer — ``[P_loc, Hkv, hd]`` with ``P_loc = n_blocks * block_tokens`` — and
maps each request slot's *logical* cache through a host-managed block table,
so slots of different lengths share device memory and freed blocks are
recycled across requests.

Geometry (all per model rank; the model axis keeps its sequence sharding):

  * prompts are right-aligned into a fixed bucket of ``s_bucket`` tokens, so
    the prefill region of every request occupies logical slots
    ``[0, base)`` with ``base = s_bucket // sp`` — exactly the prefill cell's
    chunk-contiguous layout, which lets ingest copy cache rows by identity;
  * decode token ``d`` lives on rank ``d % sp`` at logical slot
    ``base + d // sp`` (the striped layout of ``make_serve_step``);
  * logical slot ``j`` therefore has a *static* global position — the
    per-rank ``pos_map`` — shared by every request, so the pool needs no
    per-slot position tags: a slot beyond a request's write frontier holds
    garbage, but its position exceeds the causal horizon and the kernel
    masks it (allocation covers the full budget up front, see below).

Allocation is per admission, wholesale: a request gets
``blocks_for(max_new)`` blocks when it is admitted and returns all of them
on eviction.  No mid-flight growth means the block table pushed at admission
stays valid for the request's whole lifetime, which is what keeps the decode
loop free of host round trips.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

import numpy as np

from repro.core import costmodel as cm


@dataclass(frozen=True)
class PoolGeometry:
    """Static shape of the pool on one (data, model) rank."""

    s_bucket: int       # padded prompt bucket, global tokens
    sp: int             # model-axis size (sequence shards)
    max_new: int        # decode budget per request, global tokens
    block_tokens: int   # logical slots per block (per rank)
    n_blocks: int       # physical blocks (per rank)
    n_slots: int        # request slots (engine batch)

    def __post_init__(self):
        assert self.s_bucket % self.sp == 0, (
            f"s_bucket {self.s_bucket} must divide by sp {self.sp}")
        assert self.block_tokens >= 1 and self.n_blocks >= 1
        assert self.max_new >= 1

    @property
    def base(self) -> int:
        """Prefill logical slots per rank."""
        return self.s_bucket // self.sp

    @property
    def dec_loc(self) -> int:
        """Decode logical slots per rank at the full budget."""
        return -(-self.max_new // self.sp)

    @property
    def l_loc(self) -> int:
        """Logical cache length per request per rank (the gather extent)."""
        return self.base + self.dec_loc

    @property
    def max_blocks(self) -> int:
        """Block-table width: blocks per request at the full budget."""
        return -(-self.l_loc // self.block_tokens)

    @property
    def p_loc(self) -> int:
        """Physical pool slots per rank."""
        return self.n_blocks * self.block_tokens

    def blocks_for(self, max_new: int) -> int:
        """Blocks a request decoding <= max_new tokens needs (prompt included)."""
        assert 1 <= max_new <= self.max_new, (
            f"max_new {max_new} exceeds pool decode budget {self.max_new}")
        return -(-(self.base + -(-max_new // self.sp)) // self.block_tokens)

    def pool_bytes(self, cfg, n_layers: int,
                   itemsize: int = cm.ACT_ITEMSIZE) -> int:
        """Device bytes of the pool arrays on one rank (the Type-0 channel)."""
        return int(cm.kv_pool_bytes(cfg, self.n_blocks, self.block_tokens,
                                    n_layers, itemsize=itemsize))


def pos_map(geo: PoolGeometry, sched) -> np.ndarray:
    """[sp, l_loc] int32: global position of logical slot j on each rank.

    The prefill region mirrors the prefill cell's chunk-contiguous layout
    (chunk at offset ``off`` with local length ``lloc`` puts rank r's shard
    at positions ``off + r*lloc + arange(lloc)``); the decode region is the
    striped layout of the static serve path.
    """
    sp = geo.sp
    out = np.empty((sp, geo.l_loc), np.int32)
    covered = 0
    for off, ln in zip(sched.offsets, sched.lengths):
        if off >= geo.s_bucket:
            break
        ln = min(ln, geo.s_bucket - off)
        assert ln % sp == 0, f"chunk length {ln} not divisible by sp {sp}"
        lloc = ln // sp
        j0 = off // sp
        for r in range(sp):
            out[r, j0:j0 + lloc] = off + r * lloc + np.arange(lloc)
        covered += ln
    assert covered == geo.s_bucket, (
        f"schedule covers {covered} tokens, bucket is {geo.s_bucket}")
    for r in range(sp):
        e = np.arange(geo.dec_loc)
        out[r, geo.base:] = geo.s_bucket + e * sp + r
    return out


class BlockPool:
    """Host-side free-list allocator over the physical blocks of one pool.

    Tracks peak concurrent usage and lifetime allocation volume so tests can
    assert that freed blocks are actually recycled (total allocated over a
    trace exceeding ``n_blocks`` while peak stays within it).
    """

    def __init__(self, n_blocks: int):
        self.n_blocks = n_blocks
        self._free: List[int] = list(range(n_blocks - 1, -1, -1))
        self.peak_used = 0
        self.total_allocated = 0

    @property
    def used(self) -> int:
        return self.n_blocks - len(self._free)

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise MemoryError(
                f"pool exhausted: need {n} blocks, {len(self._free)} free "
                f"of {self.n_blocks}")
        blocks = [self._free.pop() for _ in range(n)]
        self.total_allocated += n
        self.peak_used = max(self.peak_used, self.used)
        return blocks

    def free(self, blocks: Sequence[int]):
        for b in blocks:
            assert 0 <= b < self.n_blocks and b not in self._free, (
                f"double free of block {b}")
            self._free.append(b)


def concurrent_peak(intervals: Sequence[Tuple[int, int, int]]) -> int:
    """Analytic peak of ``sum(weight)`` over overlapping [start, end)
    intervals — the bound a BlockPool trace replay must not exceed."""
    events: List[Tuple[int, int]] = []
    for start, end, weight in intervals:
        events.append((start, weight))
        events.append((end, -weight))
    peak = cur = 0
    for _, delta in sorted(events, key=lambda e: (e[0], e[1])):
        cur += delta
        peak = max(peak, cur)
    return peak


def block_table_row(geo: PoolGeometry, blocks: Sequence[int]) -> np.ndarray:
    """[max_blocks] int32 row for one request: its blocks in logical order,
    -1 beyond its allocation (the device side clamps and causally masks)."""
    row = np.full((geo.max_blocks,), -1, np.int32)
    row[:len(blocks)] = np.asarray(blocks, np.int32)
    return row
