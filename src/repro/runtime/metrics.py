"""Training metrics: TGS (paper's metric), MFU, step-time stats."""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from typing import Optional

from repro.core.costmodel import Hardware, V5E


@dataclass
class Meter:
    n_chips: int
    tokens_per_step: int
    n_active_params: int
    hw: Hardware = V5E
    history: list = field(default_factory=list)
    _t0: Optional[float] = None

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self, step: int, loss: float) -> dict:
        dt = time.perf_counter() - self._t0
        tgs = self.tokens_per_step / dt / self.n_chips  # tokens/chip/s (§7)
        mfu = (6 * self.n_active_params * self.tokens_per_step / dt
               / (self.n_chips * self.hw.peak_flops_bf16))
        rec = {"step": step, "loss": float(loss), "dt": dt,
               "tgs": tgs, "mfu": mfu}
        self.history.append(rec)
        return rec

    def dump(self, path: str):
        with open(path, "w") as f:
            json.dump(self.history, f, indent=1)
