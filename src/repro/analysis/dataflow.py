"""Shared jaxpr def-use walker (DESIGN.md §17).

One traversal serves every jaxpr consumer in the repo: the memory ledger's
tagged-byte / device_put accounting (runtime/memledger.py) and the static
contract auditor (analysis/audit.py).  The walker is deliberately dumb and
total — it visits every equation of every sub-jaxpr (pjit / shard_map /
scan / remat / custom_vjp bodies, wherever a ``Jaxpr`` or ``ClosedJaxpr``
hides in an equation's params) exactly once, carrying:

  * ``path``  — the primitive names of the enclosing higher-order equations
    (e.g. ``("shard_map", "scan", "remat2")``), the scope evidence the
    overlap-hazard rule R3 keys on;
  * ``mult``  — the product of enclosing ``scan`` trip counts, so byte
    accounting over a scanned body charges every iteration.

Shapes and dtypes are static facts of the traced program, so everything
computed here is exact accounting, not an estimate.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import jax

# Bit widths of the sub-byte ml_dtypes: numpy's ``dtype.itemsize`` reports a
# full byte for them (packed XLA buffers hold 2 int4s per byte), so
# itemsize*8 would double-count every int4/fp4 tensor.  Anything not listed
# really is itemsize*8 bits.
DTYPE_BITS = {
    "int2": 2, "uint2": 2,
    "int4": 4, "uint4": 4,
    "float4_e2m1fn": 4,
}

# Primitives that only relabel / relay data — the backward producer walk
# (``first_real_producer``) looks straight through them.
LAYOUT_PRIMS = frozenset({
    "reshape", "broadcast_in_dim", "squeeze", "expand_dims", "transpose",
    "convert_element_type", "copy", "stop_gradient", "name",
    "optimization_barrier",
})

# Higher-order primitives whose body executes *sequentially* with respect to
# the surrounding program: an explicit copy nested inside one of these scopes
# cannot be hoisted ahead by the scheduler — it serializes into the scope's
# own execution (the R3 overlap-hazard evidence).
SEQUENTIAL_SCOPES = frozenset({"scan", "while", "remat2", "remat",
                               "checkpoint"})


def aval_elems(aval) -> int:
    try:
        size = 1
        for s in aval.shape:
            size *= int(s)
        return size
    except Exception:  # pragma: no cover - abstract tokens etc.
        return 0


def aval_bytes(aval) -> int:
    try:
        bits = DTYPE_BITS.get(aval.dtype.name, aval.dtype.itemsize * 8)
        return (aval_elems(aval) * bits + 7) // 8
    except Exception:  # pragma: no cover - abstract tokens etc.
        return 0


def sub_jaxprs(v) -> Iterator[object]:
    """Yield every (open) Jaxpr reachable from one equation-param value."""
    core = jax.core
    if isinstance(v, core.ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, core.Jaxpr):
        yield v
    elif isinstance(v, (list, tuple)):
        for item in v:
            yield from sub_jaxprs(item)


def eqn_sub_jaxprs(eqn) -> Iterator[object]:
    for v in eqn.params.values():
        yield from sub_jaxprs(v)


@dataclass(frozen=True)
class Site:
    """One equation, located: the scope jaxpr it lives in, its index there,
    the enclosing higher-order primitive names, and the scan multiplier."""

    path: Tuple[str, ...]
    jaxpr: object
    index: int
    eqn: object
    mult: int

    @property
    def scope(self) -> str:
        return "/".join(self.path) or "top"

    @property
    def in_sequential_scope(self) -> bool:
        return any(p in SEQUENTIAL_SCOPES for p in self.path)


def _as_jaxpr(closed_or_jaxpr):
    return getattr(closed_or_jaxpr, "jaxpr", closed_or_jaxpr)


def iter_sites(closed_or_jaxpr, *, path: Tuple[str, ...] = (),
               mult: int = 1) -> Iterator[Site]:
    """DFS over every equation of every nested sub-jaxpr, exactly once."""
    jaxpr = _as_jaxpr(closed_or_jaxpr)
    for i, eqn in enumerate(jaxpr.eqns):
        yield Site(path=path, jaxpr=jaxpr, index=i, eqn=eqn, mult=mult)
        m = mult
        if eqn.primitive.name == "scan":
            m = mult * int(eqn.params.get("length", 1))
        sub_path = path + (eqn.primitive.name,)
        for sub in eqn_sub_jaxprs(eqn):
            yield from iter_sites(sub, path=sub_path, mult=m)


def device_put_kinds_of(eqn):
    """Memory-kind list of one ``device_put`` equation (may be empty when
    the put carries no explicit placement)."""
    return [k for k in (getattr(d, "memory_kind", None)
                        for d in eqn.params.get("devices", ()))
            if k is not None]


def walk_named(closed_or_jaxpr) -> Tuple[Dict[str, int], Dict[str, int]]:
    """{checkpoint name: bytes}, {checkpoint name: elems} over the whole
    trace, with enclosing scan trip counts multiplied in — the byte channel
    behind ``memledger.tagged_bytes_from_jaxpr`` and the moments walk."""
    out: Dict[str, int] = {}
    elems: Dict[str, int] = {}
    for site in iter_sites(closed_or_jaxpr):
        eqn = site.eqn
        if eqn.primitive.name != "name":
            continue
        nm = eqn.params.get("name", "")
        out[nm] = out.get(nm, 0) + site.mult * sum(
            aval_bytes(v.aval) for v in eqn.invars)
        elems[nm] = elems.get(nm, 0) + site.mult * sum(
            aval_elems(v.aval) for v in eqn.invars)
    return out, elems


def walk_device_puts(closed_or_jaxpr) -> Dict[str, int]:
    """{memory_kind: equation count} of explicit ``device_put`` equations.

    Counts equations, not executions: a put nested in a scan body counts
    once (parity with the ledger's one-copy contract accounting, which
    compares against per-step equation counts)."""
    out: Dict[str, int] = {}
    for site in iter_sites(closed_or_jaxpr):
        if site.eqn.primitive.name != "device_put":
            continue
        for kind in device_put_kinds_of(site.eqn):
            out[kind] = out.get(kind, 0) + 1
    return out


# ---------------------------------------------------------------------------
# Scope-local def-use lookups (the audit rules' walking primitives)
# ---------------------------------------------------------------------------


def producers(jaxpr) -> Dict[object, object]:
    """{var: producing eqn} within one scope (invars/constvars absent)."""
    jaxpr = _as_jaxpr(jaxpr)
    out: Dict[object, object] = {}
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            out[v] = eqn
    return out


def first_real_producer(jaxpr, var, prods: Optional[Dict] = None,
                        *, through=LAYOUT_PRIMS):
    """Walk backward from ``var`` through pure layout/relabel equations and
    return the first producing eqn that actually computes something — or
    None when the chain bottoms out at a scope input/constant (a value that
    was never written in this scope)."""
    if prods is None:
        prods = producers(jaxpr)
    seen = 0
    while True:
        if isinstance(var, jax.core.Literal):
            return None
        eqn = prods.get(var)
        if eqn is None:
            return None
        if eqn.primitive.name not in through:
            return eqn
        var = eqn.invars[0]
        seen += 1
        if seen > 10000:  # pragma: no cover - malformed graph guard
            return eqn


def ancestor_prims(jaxpr, var, prods: Optional[Dict] = None,
                   *, limit: int = 2000) -> set:
    """Primitive names of every equation reachable backward from ``var``
    within this scope (bounded) — provenance evidence, e.g. "does this
    select predicate derive from ``axis_index``?"."""
    if prods is None:
        prods = producers(jaxpr)
    prims: set = set()
    frontier = [var]
    visited = set()
    while frontier and len(visited) < limit:
        v = frontier.pop()
        if isinstance(v, jax.core.Literal) or id(v) in visited:
            continue
        visited.add(id(v))
        eqn = prods.get(v)
        if eqn is None:
            continue
        prims.add(eqn.primitive.name)
        frontier.extend(eqn.invars)
    return prims


_WRAPPER_PRIMS = ("pjit", "shard_map", "remat2", "custom_vjp_call_jaxpr",
                  "custom_jvp_call", "closed_call")


def _wrapper_body(eqn):
    """The single body jaxpr of a wrapper equation, or None."""
    for v in eqn.params.values():
        subs = list(sub_jaxprs(v))
        if len(subs) == 1:
            return subs[0]
    return None


def outvar_frames(closed_or_jaxpr, index: int):
    """Resolve output ``index`` of a traced program through wrapper
    equations (pjit / shard_map / remat) and pure layout equations to the
    scope that actually computes it.

    Returns ``(frames, scope_jaxpr, var)`` where ``frames`` is the wrapper
    chain walked through, outermost first, as ``(parent_jaxpr, wrapper_eqn)``
    pairs — the evidence needed to chase provenance of a value back OUT of
    the final scope (see ``cross_scope_ancestor_prims``)."""
    jaxpr = _as_jaxpr(closed_or_jaxpr)
    var = jaxpr.outvars[index]
    frames = []
    steps = 0
    while steps < 10000:
        steps += 1
        if isinstance(var, jax.core.Literal):
            return frames, jaxpr, var
        prods = producers(jaxpr)
        eqn = prods.get(var)
        if eqn is None:
            return frames, jaxpr, var
        if eqn.primitive.name in LAYOUT_PRIMS:
            var = eqn.invars[0]
            continue
        if eqn.primitive.name not in _WRAPPER_PRIMS:
            return frames, jaxpr, var
        inner = _wrapper_body(eqn)
        if inner is None or len(inner.outvars) != len(eqn.outvars):
            return frames, jaxpr, var
        pos = list(eqn.outvars).index(var)
        frames.append((jaxpr, eqn))
        jaxpr, var = inner, inner.outvars[pos]
    return frames, jaxpr, var  # pragma: no cover - malformed graph guard


def descend_outvar(closed_or_jaxpr, index: int):
    """``outvar_frames`` without the frame evidence — ``(scope_jaxpr, var)``."""
    _, jaxpr, var = outvar_frames(closed_or_jaxpr, index)
    return jaxpr, var


def cross_scope_ancestor_prims(frames, jaxpr, var, *, limit: int = 2000):
    """Primitive names reachable backward from ``var``, hopping OUT of the
    current scope through the wrapper ``frames`` when the chain bottoms out
    at a scope input (a value computed by the caller and passed in).

    Position mapping assumes the wrapper's operands align 1:1 with the body
    jaxpr's invars (true for pjit / shard_map / remat2); when they don't,
    the hop is skipped and provenance is simply truncated there."""
    prims: set = set()
    stack = list(frames)
    vars_here = [var]
    budget = limit
    while vars_here and budget > 0:
        jx = _as_jaxpr(jaxpr)
        prods = producers(jx)
        frontier = list(vars_here)
        visited = set()
        hit_invars = []
        while frontier and budget > 0:
            v = frontier.pop()
            if isinstance(v, jax.core.Literal) or id(v) in visited:
                continue
            visited.add(id(v))
            budget -= 1
            eqn = prods.get(v)
            if eqn is None:
                if v in jx.invars:
                    hit_invars.append(jx.invars.index(v))
                continue
            prims.add(eqn.primitive.name)
            frontier.extend(eqn.invars)
        if not hit_invars or not stack:
            break
        parent, weqn = stack.pop()
        offset = len(weqn.invars) - len(jx.invars)
        if offset < 0:
            break
        jaxpr = parent
        vars_here = [weqn.invars[p + offset] for p in hit_invars
                     if p + offset < len(weqn.invars)]
    return prims
