"""Machine-readable audit findings (DESIGN.md §17).

A ``Finding`` is one provable contract violation located in one traced
program; an ``AuditReport`` is the outcome of auditing one plan cell (its
findings plus the counters the rules derived, kept so a clean report is
still reviewable evidence rather than a bare "ok").  The audit-gate CI job
serializes reports with ``reports_to_json`` and uploads the file as an
artifact on every run, pass or fail.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass(frozen=True)
class Finding:
    """One contract violation.

    id       — stable rule-scoped identifier (``R3-overlap-hazard``); tests
               and CI assert on this, never on the message text.
    rule     — the rule family (``R1`` … ``R5``).
    message  — human-readable explanation with the counted evidence inline.
    trace    — which traced program it was found in (``train-grad``,
               ``prefill``, ``opt-update``, ``opt-init``).
    subject  — the named value or site at fault (``act_off@t3``), when one
               exists.
    scope    — the jaxpr scope path of the offending equation
               (``shard_map/scan/remat2``), when locatable.
    """

    id: str
    rule: str
    message: str
    trace: str = ""
    subject: str = ""
    scope: str = ""

    def __str__(self) -> str:
        loc = " ".join(x for x in (self.trace, self.subject, self.scope) if x)
        return f"[{self.id}] {self.message}" + (f"  ({loc})" if loc else "")


@dataclass
class AuditReport:
    """Audit outcome for one cell: findings plus the counted evidence."""

    cell: str
    pp: int = 1
    prefetch: str = ""
    findings: List[Finding] = field(default_factory=list)
    # rule-derived counters (d2h/h2d/pair counts, moment leaves, ...) kept
    # for the artifact so clean runs still document what was proven
    counters: Dict[str, int] = field(default_factory=dict)
    traces: List[str] = field(default_factory=list)
    error: Optional[str] = None

    @property
    def clean(self) -> bool:
        return not self.findings and self.error is None

    def add(self, finding: Finding) -> None:
        self.findings.append(finding)

    def finding_ids(self) -> List[str]:
        return [f.id for f in self.findings]

    def to_dict(self) -> dict:
        return {
            "cell": self.cell,
            "pp": self.pp,
            "prefetch": self.prefetch,
            "clean": self.clean,
            "findings": [dataclasses.asdict(f) for f in self.findings],
            "counters": dict(self.counters),
            "traces": list(self.traces),
            "error": self.error,
        }


def reports_to_json(reports: List[AuditReport]) -> str:
    payload = {
        "schema": "repro-audit-report/1",
        "clean": all(r.clean for r in reports),
        "reports": [r.to_dict() for r in reports],
    }
    return json.dumps(payload, indent=2, sort_keys=False) + "\n"


def format_report(report: AuditReport) -> str:
    """One terminal block per cell, findings first."""
    head = f"audit {report.cell} (pp={report.pp}"
    if report.prefetch:
        head += f", prefetch={report.prefetch}"
    head += ")"
    lines = [head]
    if report.error is not None:
        lines.append(f"  ERROR: {report.error}")
    for f in report.findings:
        lines.append(f"  FAIL {f}")
    if report.clean:
        proven = ", ".join(f"{k}={v}" for k, v in sorted(report.counters.items()))
        lines.append("  ok" + (f" — {proven}" if proven else ""))
    return "\n".join(lines)
