"""Trace-time contract auditor (DESIGN.md §17).

Traces a plan cell's real step functions over ``ShapeDtypeStruct`` inputs —
``jax.make_jaxpr`` / ``jax.eval_shape`` only, so nothing is allocated,
compiled, or executed — and proves the offload/pipeline dataflow contracts
on the jaxpr itself:

  R1  transfer counts — exactly one D2H per tagged ``act_off`` capture and
      one H2D per backward replay (the counts the runtime ledger's
      ``device_put_kinds`` later measures); one H2D + one D2H per moment
      leaf on the explicit opt-state path.
  R2  placement — ``act_scale@`` stays device-side; moment zeros never
      materialize in device memory at init.
  R3  overlap hazard — an H2D nested inside a sequential scope (scan /
      while / remat) serializes into that scope's own backward instead of
      overlapping it (the PR 5 "sync" exposure, now a named finding).
  R4  masked state — every pipeline-state output of the pp>1 prefill must
      pass through a tick-validity ``select`` keyed on the stage index
      (the PR 9 drain-tick KV clobber class).
  R5  codec pairing — every captured quantized payload has a reachable
      ``act_scale@`` name, and no inexact (sub-fp32 float) payload is ever
      named inside a remat/scan scope (the PR 7 NaN trap).

Each rule's evidence is recorded in ``AuditReport.counters`` even when it
passes, so a clean report documents what was proven.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Set, Tuple

import jax
import jax.numpy as jnp

from repro.analysis import dataflow as df
from repro.analysis.report import AuditReport, Finding
from repro.core import offload as ofl
from repro.runtime import hostmem

# dtypes that cannot ride a differentiated residual in the open (PR 7):
# quantized payloads must cross remat boundaries bitcast to an exact
# integer container, else the remat replay re-derives cotangents for an
# inexact value and NaN-poisons the backward
_INEXACT_WIRE_PREFIXES = ("float8", "float4")


# ---------------------------------------------------------------------------
# Trace facts: one walk, every rule's raw evidence
# ---------------------------------------------------------------------------


@dataclass
class TraceFacts:
    d2h: int = 0                    # device_put eqns into host kinds
    h2d: int = 0                    # device_put eqns into device kind
    capture_pairs: int = 0          # host-put → act_off name, same scope
    paired_off_names: Set[str] = field(default_factory=set)
    names: Set[str] = field(default_factory=set)
    h2d_hazards: List[df.Site] = field(default_factory=list)   # R3 evidence
    inexact_named: List[Tuple[str, str, str]] = field(
        default_factory=list)       # (name, dtype, scope) inside seq scopes
    scale_host: List[Tuple[str, str]] = field(default_factory=list)  # R2


def scan_trace(closed_jaxpr) -> TraceFacts:
    """Single pass over every equation of a traced program, collecting the
    raw facts the rules judge.  Per-scope producer maps are built lazily —
    only scopes that contain checkpoint names pay for one."""
    facts = TraceFacts()
    prod_cache: Dict[int, Dict] = {}

    def prods_for(jaxpr):
        key = id(jaxpr)
        if key not in prod_cache:
            prod_cache[key] = df.producers(jaxpr)
        return prod_cache[key]

    for site in df.iter_sites(closed_jaxpr):
        eqn = site.eqn
        prim = eqn.primitive.name
        if prim == "device_put":
            kinds = df.device_put_kinds_of(eqn)
            for kind in kinds:
                if kind == hostmem.DEVICE_KIND:
                    facts.h2d += 1
                    if site.in_sequential_scope:
                        facts.h2d_hazards.append(site)
                else:
                    facts.d2h += 1
        elif prim == "name":
            nm = eqn.params.get("name", "")
            facts.names.add(nm)
            if nm.startswith(ofl.SCALE_NAME):
                pe = df.first_real_producer(site.jaxpr, eqn.invars[0],
                                            prods_for(site.jaxpr))
                if pe is not None and pe.primitive.name == "device_put":
                    kinds = df.device_put_kinds_of(pe)
                    if kinds and all(k != hostmem.DEVICE_KIND
                                     for k in kinds):
                        facts.scale_host.append((nm, site.scope))
            elif nm.startswith(ofl.OFF_NAME):
                dt = eqn.invars[0].aval.dtype.name
                if (site.in_sequential_scope
                        and dt.startswith(_INEXACT_WIRE_PREFIXES)):
                    facts.inexact_named.append((nm, dt, site.scope))
                # a capture pair: the name's input was produced, in this
                # same scope, by an explicit host-kind device_put — the
                # D2H half of one offload site
                pe = prods_for(site.jaxpr).get(eqn.invars[0])
                if pe is not None and pe.primitive.name == "device_put":
                    kinds = df.device_put_kinds_of(pe)
                    if kinds and all(k != hostmem.DEVICE_KIND
                                     for k in kinds):
                        facts.capture_pairs += 1
                        facts.paired_off_names.add(nm)
    return facts


# ---------------------------------------------------------------------------
# Rules over one activation trace (train-grad / prefill)
# ---------------------------------------------------------------------------


def _audit_act_trace(rep: AuditReport, closed_jaxpr, trace: str,
                     *, codec: str) -> TraceFacts:
    facts = scan_trace(closed_jaxpr)
    rep.counters[f"{trace}.d2h"] = facts.d2h
    rep.counters[f"{trace}.h2d"] = facts.h2d
    rep.counters[f"{trace}.offload_sites"] = facts.capture_pairs

    # R1: the trace's own capture pairs fix the expected transfer budget —
    # one D2H per tagged site, one H2D per replay.  Deriving the expectation
    # from the trace (not from plan math) keeps the rule exact under
    # alpha-quantization and reserve-last zeroing.
    if facts.d2h != facts.capture_pairs:
        rep.add(Finding(
            id="R1-d2h-count", rule="R1", trace=trace,
            message=(f"{facts.d2h} host-kind device_puts for "
                     f"{facts.capture_pairs} tagged offload sites "
                     "(expected exactly one D2H per site)")))
    if facts.h2d != facts.capture_pairs:
        rep.add(Finding(
            id="R1-h2d-count", rule="R1", trace=trace,
            message=(f"{facts.h2d} device-kind device_puts for "
                     f"{facts.capture_pairs} tagged offload sites "
                     "(expected exactly one H2D per replay)")))

    # R3: an H2D inside a scan/while/remat scope is consumed by that
    # scope's own execution — the reload cannot be hoisted ahead of the
    # backward that needs it, so the transfer time is fully exposed.
    for site in facts.h2d_hazards:
        rep.add(Finding(
            id="R3-overlap-hazard", rule="R3", trace=trace,
            scope=site.scope,
            message=("H2D reload issued inside a sequential scope — the "
                     "copy serializes into the issuing chunk's own "
                     "backward instead of overlapping it")))

    # R2: codec scales must stay device-side (the backward dequantizes
    # with them immediately; a host-resident scale adds a blocking reload
    # on the critical path and un-pairs the payload).
    for nm, scope in facts.scale_host:
        rep.add(Finding(
            id="R2-scale-placement", rule="R2", trace=trace, subject=nm,
            scope=scope,
            message=f"codec scale {nm} was placed in host memory "
                    "(scales must stay device-resident)"))

    # R5a: quantized payload ↔ scale pairing.
    if codec not in (None, "none"):
        for nm in sorted(facts.paired_off_names):
            if ofl.scale_name_for(nm) not in facts.names:
                rep.add(Finding(
                    id="R5-codec-pairing", rule="R5", trace=trace,
                    subject=nm,
                    message=(f"quantized payload {nm} has no reachable "
                             f"{ofl.scale_name_for(nm)} — the backward "
                             "cannot dequantize it")))

    # R5b: inexact payloads named inside remat/scan scopes (the PR 7 trap).
    for nm, dt, scope in facts.inexact_named:
        rep.add(Finding(
            id="R5-inexact-residual", rule="R5", trace=trace, subject=nm,
            scope=scope,
            message=(f"residual {nm} is named as {dt} inside a remat/scan "
                     "scope — quantized payloads must cross remat "
                     "boundaries in an exact integer container")))
    return facts


# ---------------------------------------------------------------------------
# R4: masked pipeline state on the pp>1 prefill
# ---------------------------------------------------------------------------


def _audit_state_mask(rep: AuditReport, closed_jaxpr, n_state: int) -> None:
    rep.counters["prefill.state_leaves"] = n_state
    for i in range(n_state):
        frames, scope, var = df.outvar_frames(closed_jaxpr, i)
        prods = df.producers(scope)
        pe = df.first_real_producer(scope, var, prods)
        if pe is None:
            # never written in the traced step — nothing to clobber
            continue
        if pe.primitive.name != "select_n":
            rep.add(Finding(
                id="R4-unmasked-state", rule="R4", trace="prefill",
                subject=f"state[{i}]",
                message=(f"pipeline-state output {i} is written by "
                         f"`{pe.primitive.name}` with no tick-validity "
                         "select — warmup/drain ticks clobber it "
                         "(the pp>1 KV-cache corruption class)")))
            continue
        pred_prims = df.cross_scope_ancestor_prims(
            frames, scope, pe.invars[0])
        if "axis_index" not in pred_prims:
            rep.add(Finding(
                id="R4-mask-predicate", rule="R4", trace="prefill",
                subject=f"state[{i}]",
                message=(f"pipeline-state output {i} is select-guarded, "
                         "but the predicate does not derive from the "
                         "stage index (axis_index) — it cannot encode "
                         "tick validity")))


# ---------------------------------------------------------------------------
# Moments channel (R1/R2 on the optimizer update + init)
# ---------------------------------------------------------------------------


def _audit_moments(rep: AuditReport, cell, pstruct) -> None:
    from repro.optim import adamw
    from repro.runtime import memledger as ml

    plan = cell.plan
    opt_dtype = (jnp.bfloat16 if plan.opt_dtype == "bfloat16"
                 else jnp.float32)
    moments_dtype = getattr(plan, "moments_dtype", "none")
    state = jax.eval_shape(
        lambda p: adamw.init_state(p, opt_dtype, offload_moments=True,
                                   moments_dtype=moments_dtype), pstruct)

    def opt_fn(p, g, s):
        return adamw.apply_update(p, g, s, lr=1e-3, offload_moments=True,
                                  moments_mode=plan.moments_mode,
                                  moments_dtype=moments_dtype)

    cjx = jax.make_jaxpr(opt_fn)(pstruct, pstruct, state)
    rep.traces.append("opt-update")
    facts = scan_trace(cjx)
    n_leaves = (len(jax.tree_util.tree_leaves(state.m))
                + len(jax.tree_util.tree_leaves(state.v)))
    rep.counters["opt-update.d2h"] = facts.d2h
    rep.counters["opt-update.h2d"] = facts.h2d
    rep.counters["opt-update.moment_leaves"] = n_leaves

    if plan.moments_mode == "explicit":
        # one H2D into the staged update and one D2H back per host leaf —
        # the one-copy contract (DESIGN.md §11)
        if facts.h2d != n_leaves or facts.d2h != n_leaves:
            rep.add(Finding(
                id="R1-moment-copy-count", rule="R1", trace="opt-update",
                message=(f"explicit moments update shows {facts.h2d} H2D "
                         f"/ {facts.d2h} D2H for {n_leaves} host moment "
                         "leaves (expected exactly one each per leaf)")))
    for site in facts.h2d_hazards:
        rep.add(Finding(
            id="R3-overlap-hazard", rule="R3", trace="opt-update",
            scope=site.scope,
            message="moment H2D issued inside a sequential scope"))

    init_dev = ml.init_moment_device_bytes(
        pstruct, opt_dtype, offload_moments=True,
        moments_dtype=moments_dtype)
    rep.counters["opt-init.device_bytes"] = init_dev
    if init_dev:
        rep.add(Finding(
            id="R2-moment-init-device", rule="R2", trace="opt-init",
            message=(f"{init_dev} bytes of moment zeros materialize in "
                     "device memory at init (offloaded moments must be "
                     "born host-resident)")))


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def audit_cell(cell, *, data_size: int, model_size: int,
               name: str = "") -> AuditReport:
    """Audit one resolved plan cell.  Traces the cell's real step functions
    (the same builders CI measures and serves with) over struct inputs and
    applies every applicable rule.  Returns the report; never raises on a
    finding — tracing errors are captured in ``report.error``."""
    from repro.launch.mesh import compat_make_mesh
    from repro.parallel import runner
    from repro.parallel import specs as SP
    from repro.runtime import memledger as ml

    plan = cell.plan
    rep = AuditReport(cell=name or cell.shape.name, pp=plan.pp,
                      prefetch=plan.prefetch)
    train = cell.shape.kind == "train"
    assert plan.grad_accum == 1, "audit_cell needs grad_accum == 1 (the " \
        "scan walk would fold the accumulation factor into the counts)"

    g_stage = SP.stage_struct(cell.mdef, plan.pp, cell.data_size, cell.dtype)
    gl = SP.globals_struct(cell.mdef, cell.dtype)
    bstruct, _ = runner.batch_struct(cell)

    if train:
        fn = ml.step_fn(cell, data_size=data_size, model_size=model_size,
                        with_grad=True)
        cjx = jax.make_jaxpr(fn)(g_stage, gl, bstruct)
        rep.traces.append("train-grad")
        _audit_act_trace(rep, cjx, "train-grad", codec=plan.offload_dtype)

    if (not train) or plan.pp > 1:
        mesh = compat_make_mesh((data_size, model_size), ("data", "model"))
        pre_fn, sstruct, _ = runner.make_prefill_step(cell, mesh)
        pstruct = {"stages": g_stage, "globals": gl}
        cjx_pre = jax.make_jaxpr(pre_fn)(pstruct, bstruct)
        rep.traces.append("prefill")
        if not train:
            # serve cells must show a transfer-free prefill (offload is
            # rejected for them at resolve time; this proves it held)
            _audit_act_trace(rep, cjx_pre, "prefill", codec="none")
        if plan.pp > 1:
            _audit_state_mask(rep, cjx_pre,
                              len(jax.tree_util.tree_leaves(sstruct)))

    if train and plan.offload_moments:
        _audit_moments(rep, cell, g_stage)
    return rep


DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}


def resolve_gate_cell(gate: dict, *, pp: int = None, prefetch: str = None):
    """Resolve one budgets.json *train* gate to the cell the memory-gate
    measures (mirrors benchmarks/memgate.run_gate), with optional pp /
    prefetch overrides for the audit sweep.  Returns (cell, data_size,
    model_size)."""
    from repro.configs.base import ShapeConfig, get_config
    from repro.models.model_zoo import build_model
    from repro.parallel import runner

    cfg = get_config(gate["arch"])
    if gate.get("reduced", True):
        cfg = cfg.reduced()
    mdef = build_model(cfg)
    shape = ShapeConfig(gate["name"], gate["seq"], gate["batch"], "train")
    doc_lens = None
    if gate.get("doc_lens"):
        from repro.data import pipeline as dpipe

        doc_lens = [int(x) for x in
                    dpipe.sample_doc_lengths(**gate["doc_lens"])]
    use_pp = gate["pp"] if pp is None else pp
    overrides = dict(pp=use_pp, dp=gate["data_size"] // use_pp,
                     n_chunks=gate["n_chunks"], grad_accum=1,
                     partition="length", offload=True,
                     msp=gate.get("msp", False),
                     offload_moments=bool(gate.get("offload_moments",
                                                   False)),
                     opt_dtype=gate.get("opt_dtype", "float32"),
                     offload_dtype=gate.get("offload_dtype", "none"),
                     moments_dtype=gate.get("moments_dtype", "none"))
    if prefetch is not None:
        overrides["prefetch"] = prefetch
    cell = runner.resolve_cell(
        mdef, shape, data_size=gate["data_size"],
        model_size=gate["model_size"], overrides=overrides,
        doc_lens=doc_lens)
    cell = dataclasses.replace(
        cell, dtype=DTYPES[gate.get("dtype", "bfloat16")])
    return cell, gate["data_size"], gate["model_size"]


def resolve_serve_gate_cell(gate: dict):
    """Resolve a budgets.json serve gate to the engine's prefill cell
    (mirrors launch/serve.ServeEngine's resolution — the decode cell has
    its own offload-rejection asserts at resolve time)."""
    from repro.configs.base import ShapeConfig, get_config
    from repro.models.model_zoo import build_model
    from repro.parallel import runner

    cfg = get_config(gate["arch"])
    if gate.get("reduced", True):
        cfg = cfg.reduced()
    mdef = build_model(cfg)
    data_size, model_size = gate["data_size"], gate["model_size"]
    kg = gate["slots"] * data_size
    pre_shape = ShapeConfig("engine_prefill", gate["s_bucket"], kg,
                            "prefill")
    cell = runner.resolve_cell(
        mdef, pre_shape, data_size=data_size, model_size=model_size,
        overrides=dict(n_chunks=max(1, gate["s_bucket"] // 64),
                       offload=False, remat="none", pp=1, dp=data_size))
    return cell, data_size, model_size


def audit_gate(gate: dict, *, pp: int = None,
               prefetch: str = None) -> AuditReport:
    """Audit one budgets.json gate (train or serve)."""
    label = gate["name"] + (f"@pp{pp}" if pp is not None else "")
    try:
        if gate.get("kind") == "serve":
            cell, ds, ms = resolve_serve_gate_cell(gate)
        else:
            cell, ds, ms = resolve_gate_cell(gate, pp=pp, prefetch=prefetch)
        return audit_cell(cell, data_size=ds, model_size=ms, name=label)
    except Exception as e:  # noqa: BLE001 - a broken trace IS a finding
        rep = AuditReport(cell=label, pp=pp or gate.get("pp", 1))
        rep.error = f"{type(e).__name__}: {e}"
        return rep
