"""Trace-time static analysis of the executed SPPO programs (DESIGN.md §17).

``dataflow``   — the shared jaxpr walker (scoped equation iteration with scan
                 trip multipliers, named-value byte accounting, device_put
                 memory-kind counting, def-use lookups).  The memory ledger's
                 traversals (runtime/memledger.py) delegate here.
``report``     — machine-readable findings (``Finding`` / ``AuditReport``)
                 plus the JSON serialization the audit-gate CI job uploads.
``audit``      — the rule engine: traces a plan cell's train / prefill /
                 optimizer-update steps over ShapeDtypeStructs (nothing is
                 compiled or executed) and proves the offload/pipeline
                 dataflow contracts R1–R5 on the jaxpr.

Import ``repro.analysis.audit`` explicitly — it pulls in the runner and the
ledger, and keeping it out of the package root lets those modules import
``repro.analysis.dataflow`` without a cycle.
"""
