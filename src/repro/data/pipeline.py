"""Data pipeline: deterministic synthetic LM streams, packing, sharded host
feeding.

Real corpora plug in through the same ``Batcher`` interface (an iterator of
token arrays); the synthetic stream is a seeded Zipfian sampler with
document boundaries, so loss curves are reproducible across restarts and
the pipeline state (step counter + seed) checkpoints in a few bytes.

Layouts match parallel/specs.py: tokens/labels are [pods, data, B_loc, S]
with row (p, i) holding the batch shard of dp group (p, i // pp) —
duplicated across the pp stages of each dp group (stage-major layout).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import partition as part

# Sentinel start-position for padding slots: matches the attention PAD
# position (models/attention.PAD), so a padding query's visibility window
# `kv_pos >= doc_start` is empty against every real kv slot.
PAD_START = 2 ** 30
# Label sentinel: slots with label < 0 carry zero loss weight (padding and
# each document's final token, which has no in-document successor).
IGNORE_LABEL = -1


@dataclass
class DataState:
    """Checkpointable pipeline position."""

    seed: int
    step: int


class SyntheticLM:
    """Zipfian token stream with document structure + packing."""

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 *, seed: int = 0, zipf_a: float = 1.2,
                 mean_doc_len: int = 512, bos_id: int = 1):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = global_batch
        self.state = DataState(seed=seed, step=0)
        self.zipf_a = zipf_a
        self.mean_doc = mean_doc_len
        self.bos = bos_id

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.state.seed, step]))

    def sample_step(self, step: Optional[int] = None
                    ) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (tokens, labels) of shape [global_batch, seq]."""
        step = self.state.step if step is None else step
        rng = self._rng(step)
        # zipf over the real vocab (capped), packed documents
        toks = rng.zipf(self.zipf_a, size=(self.batch, self.seq + 1))
        toks = np.minimum(toks + 1, self.vocab - 1).astype(np.int32)
        # insert document boundaries (bos) at geometric intervals
        n_docs = max(1, int(self.seq / self.mean_doc))
        for b in range(self.batch):
            cuts = rng.integers(0, self.seq, size=n_docs)
            toks[b, cuts] = self.bos
        tokens, labels = toks[:, :-1], toks[:, 1:]
        return tokens, np.ascontiguousarray(labels)

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        while True:
            yield self.sample_step()
            self.state.step += 1

    # --- checkpointing -----------------------------------------------------
    def state_dict(self) -> dict:
        return dataclasses.asdict(self.state)

    def load_state_dict(self, d: dict) -> None:
        self.state = DataState(**d)


# ---------------------------------------------------------------------------
# Packed variable-length batches (DESIGN.md §13)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PackedBatch:
    """A packed variable-length batch: documents laid out contiguously in
    fixed-width rows with tail padding only.

    - ``tokens``   [B, S] int32, padding slots hold ``pad_id``
    - ``labels``   [B, S] int32, in-document next token; ``IGNORE_LABEL`` on
      each document's last token and on padding
    - ``seg_ids``  [B, S] int32, global document index per slot, -1 on padding
    - ``doc_start``[B, S] int32, row position where the slot's document
      starts (the attention q_start window), ``PAD_START`` on padding
    - ``spans``    tuple of (row, start, end, doc_idx) per placed document
    """

    tokens: np.ndarray
    labels: np.ndarray
    seg_ids: np.ndarray
    doc_start: np.ndarray
    spans: tuple

    @property
    def n_real_tokens(self) -> int:
        return int((self.seg_ids >= 0).sum())


def sample_doc_lengths(n_docs: int, *, seed: int = 0, dist: str = "zipf",
                       zipf_a: float = 1.6, mean_len: int = 64,
                       sigma: float = 1.0, min_len: int = 2,
                       max_len: Optional[int] = None) -> np.ndarray:
    """Seeded skewed document-length histogram (most docs short, a few
    long) — ``dist`` is "zipf" (heavy tail, rescaled to ``mean_len``) or
    "lognormal" (median ``mean_len``, log-σ ``sigma``)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0xD0C5]))
    if dist == "zipf":
        raw = rng.zipf(zipf_a, size=n_docs).astype(np.float64)
        raw *= mean_len / raw.mean()
    elif dist == "lognormal":
        raw = rng.lognormal(np.log(max(mean_len, 1)), sigma, size=n_docs)
    else:
        raise ValueError(f"unknown length distribution {dist!r}")
    lens = np.maximum(np.round(raw).astype(np.int64), min_len)
    if max_len is not None:
        lens = np.minimum(lens, max_len)
    return lens


def sample_corpus(n_docs: int, *, vocab_size: int, seed: int = 0,
                  dist: str = "zipf", zipf_a: float = 1.6,
                  mean_len: int = 64, sigma: float = 1.0,
                  max_len: Optional[int] = None,
                  bos_id: int = 1) -> List[np.ndarray]:
    """Seeded synthetic corpus with a skewed length histogram: one int32
    token array per document, bos-led."""
    lens = sample_doc_lengths(n_docs, seed=seed, dist=dist, zipf_a=zipf_a,
                              mean_len=mean_len, sigma=sigma, max_len=max_len)
    docs = []
    for i, ln in enumerate(lens):
        rng = np.random.default_rng(np.random.SeedSequence([seed, 1, i]))
        d = rng.integers(2, vocab_size, size=int(ln)).astype(np.int32)
        d[0] = bos_id
        docs.append(d)
    return docs


def pack_documents(docs: Sequence[np.ndarray], seq_len: int, *,
                   rows: Optional[int] = None, pad_id: int = 0
                   ) -> PackedBatch:
    """Greedy first-fit-decreasing packer: every document lands contiguously
    in exactly one row (no token dropped, duplicated, or split).  ``rows``
    forces the batch row count (must be >= the packed row count; extra rows
    are all-padding)."""
    lengths = [len(d) for d in docs]
    layout = part.pack_lengths(lengths, seq_len)
    n_rows = len(layout) if rows is None else rows
    assert n_rows >= len(layout), \
        f"corpus needs {len(layout)} rows, got rows={rows}"
    tokens = np.full((n_rows, seq_len), pad_id, np.int32)
    labels = np.full((n_rows, seq_len), IGNORE_LABEL, np.int32)
    seg_ids = np.full((n_rows, seq_len), -1, np.int32)
    doc_start = np.full((n_rows, seq_len), PAD_START, np.int32)
    spans = []
    for row, doc_ids in enumerate(layout):
        pos = 0
        for di in doc_ids:
            d = np.asarray(docs[di], np.int32)
            ln = len(d)
            tokens[row, pos:pos + ln] = d
            labels[row, pos:pos + ln - 1] = d[1:]
            seg_ids[row, pos:pos + ln] = di
            doc_start[row, pos:pos + ln] = pos
            spans.append((row, pos, pos + ln, di))
            pos += ln
    return PackedBatch(tokens, labels, seg_ids, doc_start, tuple(spans))


def pad_to_max(docs: Sequence[np.ndarray], seq_len: int, *,
               rows: Optional[int] = None, pad_id: int = 0,
               at_packed_offsets: Optional[PackedBatch] = None
               ) -> PackedBatch:
    """Pad-to-max oracle: one document per row of width ``seq_len``.  With
    ``at_packed_offsets`` each document sits at the same row positions it
    occupies in the packed layout (positions — hence RoPE angles and causal
    windows — are bit-identical between the two layouts, so packed loss and
    grads must match this oracle to fp32 reduction-order tolerance).
    Otherwise documents start at position 0 (the plain SFT baseline)."""
    starts = {}
    if at_packed_offsets is not None:
        starts = {di: s for (_, s, _, di) in at_packed_offsets.spans}
    n_rows = len(docs) if rows is None else rows
    assert n_rows >= len(docs)
    tokens = np.full((n_rows, seq_len), pad_id, np.int32)
    labels = np.full((n_rows, seq_len), IGNORE_LABEL, np.int32)
    seg_ids = np.full((n_rows, seq_len), -1, np.int32)
    doc_start = np.full((n_rows, seq_len), PAD_START, np.int32)
    spans = []
    for row, d in enumerate(docs):
        d = np.asarray(d, np.int32)
        ln = len(d)
        assert ln <= seq_len, f"doc {row} length {ln} > {seq_len}"
        s = starts.get(row, 0)
        tokens[row, s:s + ln] = d
        labels[row, s:s + ln - 1] = d[1:]
        seg_ids[row, s:s + ln] = row
        doc_start[row, s:s + ln] = s
        spans.append((row, s, s + ln, row))
    return PackedBatch(tokens, labels, seg_ids, doc_start, tuple(spans))


def packed_batch_for(doc_lens: Sequence[int], seq_len: int, *, rows: int,
                     vocab_size: int, seed: int = 0,
                     bos_id: int = 1) -> PackedBatch:
    """Deterministic packed batch for a fixed length histogram (the varlen
    budget cell / memledger path): token content seeded per document."""
    docs = []
    for i, ln in enumerate(doc_lens):
        rng = np.random.default_rng(np.random.SeedSequence([seed, 1, i]))
        d = rng.integers(2, vocab_size, size=int(ln)).astype(np.int32)
        d[0] = bos_id
        docs.append(d)
    return pack_documents(docs, seq_len, rows=rows)


def shard_batch(tokens: np.ndarray, labels: np.ndarray, *, pods: int,
                data_size: int, pp: int,
                doc_start: Optional[np.ndarray] = None) -> dict:
    """[B, S] -> the stage-major [pods, data, B_loc, S] layout.  A packed
    batch's ``doc_start`` rides along under the same layout."""
    B, S = tokens.shape
    dp = data_size // pp
    b_loc = B // (pods * dp)

    def lay(x):
        out = np.empty((pods, data_size, b_loc, S), x.dtype)
        for p in range(pods):
            for i in range(data_size):
                g = i // pp
                lo = (p * dp + g) * b_loc
                out[p, i] = x[lo:lo + b_loc]
        return out

    batch = {"tokens": lay(tokens), "labels": lay(labels)}
    if doc_start is not None:
        batch["doc_start"] = lay(doc_start)
    return batch


def make_context_stub(batch: dict, *, b_loc: int, pods: int, data_size: int,
                      n_ctx_pad: int, d_model: int, seed: int = 0,
                      dtype=np.float32) -> np.ndarray:
    """Stub modality frontend: precomputed frame/patch embeddings."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((pods, data_size, b_loc, n_ctx_pad, d_model))
    return (x * 0.02).astype(dtype)
