"""Data pipeline: deterministic synthetic LM streams, packing, sharded host
feeding.

Real corpora plug in through the same ``Batcher`` interface (an iterator of
token arrays); the synthetic stream is a seeded Zipfian sampler with
document boundaries, so loss curves are reproducible across restarts and
the pipeline state (step counter + seed) checkpoints in a few bytes.

Layouts match parallel/specs.py: tokens/labels are [pods, data, B_loc, S]
with row (p, i) holding the batch shard of dp group (p, i // pp) —
duplicated across the pp stages of each dp group (stage-major layout).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Iterator, Optional, Tuple

import numpy as np


@dataclass
class DataState:
    """Checkpointable pipeline position."""

    seed: int
    step: int


class SyntheticLM:
    """Zipfian token stream with document structure + packing."""

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 *, seed: int = 0, zipf_a: float = 1.2,
                 mean_doc_len: int = 512, bos_id: int = 1):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = global_batch
        self.state = DataState(seed=seed, step=0)
        self.zipf_a = zipf_a
        self.mean_doc = mean_doc_len
        self.bos = bos_id

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.state.seed, step]))

    def sample_step(self, step: Optional[int] = None
                    ) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (tokens, labels) of shape [global_batch, seq]."""
        step = self.state.step if step is None else step
        rng = self._rng(step)
        # zipf over the real vocab (capped), packed documents
        toks = rng.zipf(self.zipf_a, size=(self.batch, self.seq + 1))
        toks = np.minimum(toks + 1, self.vocab - 1).astype(np.int32)
        # insert document boundaries (bos) at geometric intervals
        n_docs = max(1, int(self.seq / self.mean_doc))
        for b in range(self.batch):
            cuts = rng.integers(0, self.seq, size=n_docs)
            toks[b, cuts] = self.bos
        tokens, labels = toks[:, :-1], toks[:, 1:]
        return tokens, np.ascontiguousarray(labels)

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        while True:
            yield self.sample_step()
            self.state.step += 1

    # --- checkpointing -----------------------------------------------------
    def state_dict(self) -> dict:
        return dataclasses.asdict(self.state)

    def load_state_dict(self, d: dict) -> None:
        self.state = DataState(**d)


def shard_batch(tokens: np.ndarray, labels: np.ndarray, *, pods: int,
                data_size: int, pp: int) -> dict:
    """[B, S] -> the stage-major [pods, data, B_loc, S] layout."""
    B, S = tokens.shape
    dp = data_size // pp
    b_loc = B // (pods * dp)

    def lay(x):
        out = np.empty((pods, data_size, b_loc, S), x.dtype)
        for p in range(pods):
            for i in range(data_size):
                g = i // pp
                lo = (p * dp + g) * b_loc
                out[p, i] = x[lo:lo + b_loc]
        return out

    return {"tokens": lay(tokens), "labels": lay(labels)}


def make_context_stub(batch: dict, *, b_loc: int, pods: int, data_size: int,
                      n_ctx_pad: int, d_model: int, seed: int = 0,
                      dtype=np.float32) -> np.ndarray:
    """Stub modality frontend: precomputed frame/patch embeddings."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((pods, data_size, b_loc, n_ctx_pad, d_model))
    return (x * 0.02).astype(dtype)
