"""Shared model layers: norms, RoPE, MLPs, embeddings, vocab-parallel loss.

Conventions (see DESIGN.md §4):
  * Activations are sequence-sharded over the `model` axis: x is
    [B, T_local, d_model] with full d_model per rank.
  * Weights arrive here already *gathered* (full) — storage sharding and the
    per-layer all-gather happen in the runner.  Exceptions (embedding table,
    LM head, MoE experts) stay sharded and use the collective helpers below.
  * Norm/softmax math in fp32; matmul I/O in the model dtype (bf16 target).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel.ctx import Ctx

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def apply_norm(x, p, kind: str):
    if kind == "rmsnorm":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["bias"])


# ---------------------------------------------------------------------------
# RoPE (positions given explicitly — chunked execution needs global offsets)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float, fraction: float = 1.0):
    rot = int(head_dim * fraction) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv, rot


def apply_rope(x, positions, theta: float, fraction: float = 1.0):
    """x: [B, T, H, hd]; positions: [B, T] or [T] int32 global positions."""
    hd = x.shape[-1]
    inv, rot = rope_freqs(hd, theta, fraction)
    if rot == 0:
        return x
    pos = positions.astype(jnp.float32)
    if pos.ndim == 1:
        pos = pos[None, :]
    ang = pos[..., None] * inv[None, None, :]          # [B, T, rot/2]
    cos = jnp.cos(ang)[:, :, None, :]                  # [B, T, 1, rot/2]
    sin = jnp.sin(ang)[:, :, None, :]
    xr = x[..., :rot].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape[:-1] + (rot,))
    if rot < hd:
        out = jnp.concatenate([out, xr_pass := x[..., rot:].astype(jnp.float32)], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations / MLP
# ---------------------------------------------------------------------------


def _act(h, kind: str):
    if kind == "gelu":
        return jax.nn.gelu(h)
    if kind == "relu2":
        r = jax.nn.relu(h)
        return r * r
    raise ValueError(kind)


def mlp(x, p, act: str, *, name_tag=None):
    """Standard transformer MLP. Gated (swiglu/geglu) uses w1 (gate) + w3 (up).

    name_tag: optional fn applied to the big [.., d_ff] intermediate so the
    SPPO offload policy can route it (two-level activation management).
    """
    if act in ("swiglu", "geglu"):
        g = x @ p["w1"]
        u = x @ p["w3"]
        h = (jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)) * u
    else:
        h = x @ p["w1"]
        if "b1" in p:
            h = h + p["b1"]
        h = _act(h, act)
    if name_tag is not None:
        h = name_tag(h)
    y = h @ p["w2"]
    if "b2" in p:
        y = y + p["b2"]
    return y


# ---------------------------------------------------------------------------
# Vocab-parallel embedding (table sharded on vocab over `model` axis)
# ---------------------------------------------------------------------------


def pad_vocab(v: int, multiple: int = 128) -> int:
    return (v + multiple - 1) // multiple * multiple


def embed_tokens(ids, table_local, ctx: Ctx, *, out_dtype=jnp.bfloat16):
    """ids: [B, T] global token ids; table_local: [Vp/sp, d] this rank's rows.

    Returns the *sequence shard* [B, T/sp, d]: masked local gather followed by
    a reduce-scatter over the sequence dim (one collective, half the bytes of
    a psum).  Single-device: plain gather, full sequence.
    """
    vloc = table_local.shape[0]
    lo = ctx.model_index() * vloc
    idx = jnp.clip(ids - lo, 0, vloc - 1)
    hit = ((ids >= lo) & (ids < lo + vloc))[..., None]
    out = jnp.where(hit, jnp.take(table_local, idx, axis=0), 0).astype(out_dtype)
    return ctx.reduce_scatter_model(out, axis=1)


# ---------------------------------------------------------------------------
# Vocab-parallel LM head + cross entropy
# ---------------------------------------------------------------------------


def vocab_parallel_xent(x_local, head_local, labels, mask, ctx: Ctx,
                        *, real_vocab: int):
    """x_local: [B, T/sp, d] sequence shard (full d); head_local: [d, Vp/sp];
    labels/mask: [B, T] for the full (chunk) sequence.

    All-gathers x over the sequence (cheap: d-sized), computes the local
    vocab-shard logits, and reduces scalar statistics — the full-vocab logits
    tensor never materializes on any device (Megatron vocab-parallel CE).
    Returns (sum_loss, sum_correct_logits_grad_path) summed over tokens.
    """
    x = ctx.all_gather_model(x_local, axis=1)            # [B, T, d]
    logits = (x @ head_local).astype(jnp.float32)        # [B, T, Vp/sp]
    vloc = logits.shape[-1]
    lo = ctx.model_index() * vloc
    # mask out padded vocab columns
    col = lo + jnp.arange(vloc)
    logits = jnp.where(col[None, None, :] < real_vocab, logits, -1e30)

    # max statistic is gradient-frozen (cancels in the softmax ratio; pmax
    # has no VJP)
    m = jax.lax.stop_gradient(
        ctx.pmax_model(jax.lax.stop_gradient(jnp.max(logits, axis=-1))))
    z = jnp.exp(logits - m[..., None])
    l = ctx.psum_model(jnp.sum(z, axis=-1))              # [B, T]
    idx = jnp.clip(labels - lo, 0, vloc - 1)
    hit_mask = (labels >= lo) & (labels < lo + vloc)
    picked = jnp.take_along_axis(logits, idx[..., None], axis=-1)[..., 0]
    hit = ctx.psum_model(jnp.where(hit_mask, picked, 0.0))  # [B, T]

    tok_loss = (jnp.log(l) + m - hit) * mask
    return jnp.sum(tok_loss), jnp.sum(mask)


# ---------------------------------------------------------------------------
# Initialization helpers
# ---------------------------------------------------------------------------


def trunc_normal(key, shape, std, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def dense_init(key, d_in, d_out, dtype, *, std: Optional[float] = None):
    std = std if std is not None else 1.0 / math.sqrt(d_in)
    return trunc_normal(key, (d_in, d_out), std, dtype)
