"""Family slot programs + the scanned stage engine.

A model is a sequence of uniform "slots" (a layer, or a homogeneous layer
group) scanned per pipeline stage.  Every family provides:

  slot_fn(cfg, p, s, x, ctx, meta, extras) -> (x, s, aux)

with per-slot params p (already *gathered* for "ag" leaves), per-slot state s
(KV caches / SSM states), sequence-sharded activations x, and chunk metadata
(positions, cache offsets, offload tag).  Ghost slots (pipeline padding)
carry gate=0 and reduce to identity.  The engine ``stage_apply`` runs the
slot scan with SPPO's two-level checkpoint policy around each slot.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import offload as offload_mod
from repro.core.offload import checkpoint_block
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.parallel.ctx import Ctx


class ChunkMeta(NamedTuple):
    q_pos: Any          # [T_loc] global positions of this rank's chunk shard
    cache_off: Any      # local cache write offset (static or traced int)
    kv_view: int        # STATIC visible local cache length after append
    tag: Any            # offload tag fn (core.offload.make_tag/make_exec_tag)
    decode: bool = False
    my_slot: Any = None  # decode: striped cache write slot or -1
    # (off, keep) checkpoint names the tag uses — per-tick qualified in the
    # pipeline loops so the memledger can attribute saved bytes exactly
    names: Any = (offload_mod.OFF_NAME, offload_mod.KEEP_NAME)
    # packed variable-length batches: [B, T_loc] int32 document-start window
    # per query token (attention masks kv_pos < q_start); None = unpacked
    q_start: Any = None
    # paged continuous-batching decode: an attention.PagedMeta routing the
    # slot's KV through the block-table pool (runtime/kvpool.py); None keeps
    # the static striped-cache decode path
    paged: Any = None


ZERO = jnp.float32(0.0)


def _res(x, delta, gate):
    """Gated residual add — ghost slots (gate=0) become identity.
    The gate is a structural constant (pipeline padding), not trainable."""
    return x + jax.lax.stop_gradient(gate).astype(x.dtype) * delta


# ---------------------------------------------------------------------------
# Dense transformer layer (qwen2 / glm4 / nemotron / starcoder2 / gpt)
# ---------------------------------------------------------------------------


def dense_slot(cfg, p, s, x, ctx: Ctx, meta: ChunkMeta, extras=None):
    h = L.apply_norm(x, p["ln1"], cfg.norm)
    if meta.decode and meta.paged is not None:
        a, kv = A.gqa_paged_decode_attention(h, p["attn"], cfg, ctx, s["kv"],
                                             meta.paged)
    elif meta.decode:
        a, kv = A.gqa_decode_attention(h, p["attn"], cfg, ctx, s["kv"],
                                       meta.q_pos[0], meta.my_slot)
    else:
        a, kv = A.gqa_self_attention(h, p["attn"], cfg, ctx, s["kv"],
                                     meta.q_pos, meta.cache_off, meta.kv_view,
                                     name_tag=meta.tag,
                                     q_start=meta.q_start)
    x = _res(x, a, p["gate"])
    h2 = L.apply_norm(x, p["ln2"], cfg.norm)
    m = L.mlp(h2, p["mlp"], cfg.act, name_tag=meta.tag)
    x = _res(x, m, p["gate"])
    return x, {"kv": kv}, ZERO


# ---------------------------------------------------------------------------
# MoE layer (granite: GQA + MoE; deepseek: MLA + MoE + shared expert)
# ---------------------------------------------------------------------------


def moe_slot(cfg, p, s, x, ctx: Ctx, meta: ChunkMeta, extras=None):
    h = L.apply_norm(x, p["ln1"], cfg.norm)
    if cfg.mla is not None:
        a, kv = A.mla_attention(h, p["attn"], cfg, ctx, s["kv"], meta.q_pos,
                                meta.cache_off, meta.kv_view,
                                name_tag=meta.tag, decode=meta.decode,
                                my_slot=meta.my_slot,
                                q_start=meta.q_start)
    elif meta.decode:
        a, kv = A.gqa_decode_attention(h, p["attn"], cfg, ctx, s["kv"],
                                       meta.q_pos[0], meta.my_slot)
    else:
        a, kv = A.gqa_self_attention(h, p["attn"], cfg, ctx, s["kv"],
                                     meta.q_pos, meta.cache_off, meta.kv_view,
                                     name_tag=meta.tag,
                                     q_start=meta.q_start)
    x = _res(x, a, p["gate"])
    h2 = L.apply_norm(x, p["ln2"], cfg.norm)
    m, aux = M.moe_block(h2, p["moe"], cfg, ctx, name_tag=meta.tag)
    x = _res(x, m, p["gate"])
    return x, {"kv": kv}, aux * p["gate"]


# ---------------------------------------------------------------------------
# VLM group (llama-3.2-vision): `every` self layers + 1 cross-attn layer
# ---------------------------------------------------------------------------


def vlm_group_slot(cfg, p, s, x, ctx: Ctx, meta: ChunkMeta, extras=None):
    n_self = cfg.cross_attn.every
    kvs = []
    for i in range(n_self):
        pi = jax.tree_util.tree_map(lambda a: a[i], p["self"])
        si = jax.tree_util.tree_map(lambda a: a[i], s["self"])
        h = L.apply_norm(x, pi["ln1"], cfg.norm)
        if meta.decode:
            a, kv = A.gqa_decode_attention(h, pi["attn"], cfg, ctx, si,
                                           meta.q_pos[0], meta.my_slot)
        else:
            a, kv = A.gqa_self_attention(h, pi["attn"], cfg, ctx, si,
                                         meta.q_pos, meta.cache_off,
                                         meta.kv_view, name_tag=meta.tag,
                                         q_start=meta.q_start)
        x = _res(x, a, pi["gate"])
        h2 = L.apply_norm(x, pi["ln2"], cfg.norm)
        m = L.mlp(h2, pi["mlp"], cfg.act, name_tag=meta.tag)
        x = _res(x, m, pi["gate"])
        kvs.append(kv)
    # cross-attention sub-layer (gated, as in llama-3.2)
    h = L.apply_norm(x, p["xln1"], cfg.norm)
    a = A.cross_attention(h, p["xattn"], cfg, ctx, s["xkv"], name_tag=meta.tag)
    x = _res(x, jnp.tanh(p["xgate_attn"]).astype(x.dtype) * a, p["gate"])
    h2 = L.apply_norm(x, p["xln2"], cfg.norm)
    m = L.mlp(h2, p["xmlp"], cfg.act, name_tag=meta.tag)
    x = _res(x, jnp.tanh(p["xgate_mlp"]).astype(x.dtype) * m, p["gate"])
    s_new = {"self": jax.tree_util.tree_map(lambda *a: jnp.stack(a), *kvs),
             "xkv": s["xkv"]}
    return x, s_new, ZERO


# ---------------------------------------------------------------------------
# Zamba2 group: `every` Mamba2 mixers + the weight-shared attention block
# ---------------------------------------------------------------------------


def zamba_group_slot(cfg, p, s, x, ctx: Ctx, meta: ChunkMeta, extras=None):
    n_m = cfg.shared_attn_every
    states = []
    for i in range(n_m):
        pi = jax.tree_util.tree_map(lambda a: a[i], p["mamba"])
        si = jax.tree_util.tree_map(lambda a: a[i], s["mamba"])
        h = L.apply_norm(x, pi["ln"], cfg.norm)
        y, st = S.mamba2_mixer(h, pi["mix"], cfg, ctx, si, name_tag=meta.tag,
                               pre_gathered=meta.decode)
        x = _res(x, y, pi["gate"])
        states.append(st)
    # shared transformer block (params in extras — weight-tied across groups)
    sp_ = extras["shared"]
    h = L.apply_norm(x, sp_["ln1"], cfg.norm)
    if meta.decode:
        a, kv = A.gqa_decode_attention(h, sp_["attn"], cfg, ctx, s["shared_kv"],
                                       meta.q_pos[0], meta.my_slot)
    else:
        a, kv = A.gqa_self_attention(h, sp_["attn"], cfg, ctx, s["shared_kv"],
                                     meta.q_pos, meta.cache_off, meta.kv_view,
                                     name_tag=meta.tag,
                                     q_start=meta.q_start)
    x = _res(x, a, p["gate_shared"])
    h2 = L.apply_norm(x, sp_["ln2"], cfg.norm)
    m = L.mlp(h2, sp_["mlp"], cfg.act, name_tag=meta.tag)
    x = _res(x, m, p["gate_shared"])
    s_new = {"mamba": jax.tree_util.tree_map(lambda *a: jnp.stack(a), *states),
             "shared_kv": kv}
    return x, s_new, ZERO


# ---------------------------------------------------------------------------
# RWKV6 layer: time-mix + channel-mix
# ---------------------------------------------------------------------------


def rwkv_slot(cfg, p, s, x, ctx: Ctx, meta: ChunkMeta, extras=None):
    st: S.RWKVState = s["rwkv"]
    h = L.apply_norm(x, p["ln1"], cfg.norm)
    y, st = S.rwkv6_time_mix(h, p["tmix"], cfg, ctx, st, name_tag=meta.tag,
                             pre_gathered=meta.decode)
    x = _res(x, y, p["gate"])
    h2 = L.apply_norm(x, p["ln2"], cfg.norm)
    y2, st = S.rwkv6_channel_mix(h2, p["cmix"], cfg, ctx, st,
                                 name_tag=meta.tag, pre_gathered=meta.decode)
    x = _res(x, y2, p["gate"])
    return x, {"rwkv": st}, ZERO


# ---------------------------------------------------------------------------
# Whisper: decoder slot (self + cross + mlp) and encoder layer
# ---------------------------------------------------------------------------


def whisper_dec_slot(cfg, p, s, x, ctx: Ctx, meta: ChunkMeta, extras=None):
    h = L.apply_norm(x, p["ln1"], cfg.norm)
    if meta.decode:
        a, kv = A.gqa_decode_attention(h, p["attn"], cfg, ctx, s["kv"],
                                       meta.q_pos[0], meta.my_slot)
    else:
        a, kv = A.gqa_self_attention(h, p["attn"], cfg, ctx, s["kv"],
                                     meta.q_pos, meta.cache_off, meta.kv_view,
                                     name_tag=meta.tag,
                                     q_start=meta.q_start)
    x = _res(x, a, p["gate"])
    hx = L.apply_norm(x, p["xln"], cfg.norm)
    a2 = A.cross_attention(hx, p["xattn"], cfg, ctx, s["xkv"],
                           name_tag=meta.tag)
    x = _res(x, a2, p["gate"])
    h2 = L.apply_norm(x, p["ln2"], cfg.norm)
    m = L.mlp(h2, p["mlp"], cfg.act, name_tag=meta.tag)
    x = _res(x, m, p["gate"])
    return x, {"kv": kv, "xkv": s["xkv"]}, ZERO


def encoder_layer(cfg, p, x_loc, ctx: Ctx, n_valid: int):
    """Bidirectional encoder layer over the (stub-embedded) frame sequence."""
    B, Tl, _ = x_loc.shape
    H, hd = cfg.n_heads, cfg.hd
    h = L.apply_norm(x_loc, p["ln1"], cfg.norm)
    q = (h @ p["attn"]["wq"]).reshape(B, Tl, H, hd)
    k = (h @ p["attn"]["wk"]).reshape(B, Tl, cfg.n_kv_heads, hd)
    v = (h @ p["attn"]["wv"]).reshape(B, Tl, cfg.n_kv_heads, hd)
    if cfg.qkv_bias:
        q = q + p["attn"]["bq"].reshape(H, hd)
        k = k + p["attn"]["bk"].reshape(cfg.n_kv_heads, hd)
        v = v + p["attn"]["bv"].reshape(cfg.n_kv_heads, hd)
    gidx = ctx.model_index() * Tl + jnp.arange(Tl, dtype=jnp.int32)
    pos = jnp.where(gidx < n_valid, gidx, A.PAD)
    out = A.dist_attention(q, k, v, pos, pos, ctx, causal=False)
    a = out.reshape(B, Tl, H * hd) @ p["attn"]["wo"]
    x = x_loc + a
    h2 = L.apply_norm(x, p["ln2"], cfg.norm)
    m = L.mlp(h2, p["mlp"], cfg.act)
    return x + m


SLOT_FNS = {
    "dense": dense_slot,
    "moe": moe_slot,
    "vlm": vlm_group_slot,
    "hybrid": zamba_group_slot,
    "ssm": rwkv_slot,
    "audio": whisper_dec_slot,
}


# ---------------------------------------------------------------------------
# The stage engine: scan slots with weight-gather + SPPO checkpointing
# ---------------------------------------------------------------------------


def gather_params(p_slot, shard_dims, ctx: Ctx):
    """All-gather "ag" leaves (int marker = gather dim) over the model axis;
    "rep"/"keepN" string markers pass through unchanged.  With
    ctx.grad_compress, the gather's transpose (the weight-grad
    reduce-scatter) runs in bf16 (§Perf)."""
    def g(leaf, dim):
        if isinstance(dim, int):
            return ctx.all_gather_param(leaf, axis=dim)
        return leaf
    return jax.tree_util.tree_map(g, p_slot, shard_dims)


def stage_apply(cfg, family: str, stage_params, shard_dims, state, x, ctx: Ctx,
                meta: ChunkMeta, extras=None, *, offload=True, remat="sppo",
                offload_mode="explicit", offload_dtype="none"):
    """Run one pipeline stage (a stack of slots) on one chunk.

    stage_params: pytree with leading slot dim (local shards);
    state: matching pytree of per-slot caches/states.
    Returns (x, new_state, aux_sum)."""
    slot = SLOT_FNS[family]

    def body(carry, ps):
        xx = carry
        p_slot, s_slot = ps

        def inner(p_l, s_l, x_l):
            p_full = gather_params(p_l, shard_dims, ctx)
            return slot(cfg, p_full, s_l, x_l, ctx, meta, extras)

        fn = checkpoint_block(inner, offload=offload, remat=remat,
                              mode=offload_mode, names=meta.names,
                              codec=offload_dtype)
        xx, s_new, aux = fn(p_slot, s_slot, xx)
        return xx, (s_new, aux)

    x, (state_new, auxs) = jax.lax.scan(body, x, (stage_params, state))
    return x, state_new, jnp.sum(auxs)


def stage_apply_capture(cfg, family: str, stage_params, shard_dims, state, x,
                        ctx: Ctx, meta: ChunkMeta, alpha: float, extras=None,
                        *, offload_dtype="none"):
    """Prefetch-'ahead' forward of one stage (DESIGN.md §12): the slot scan
    runs *unwrapped* — the tick-level custom_vjp seam above discards every
    intermediate, so per-slot checkpointing is moot — with a capture tag
    collecting the (off, keep) row split of each tagged tensor as extra
    scan outputs, stacked over the slot dim.

    Returns (x, state', aux_sum, off_acts, keep_acts, scales) where
    off_acts / keep_acts are tuples of [n_slots, ...] arrays in
    tag-traversal order — the residual sets whose placement the seam owns.
    With a codec the off entries are the quantized wire payloads and
    `scales` the matching per-row fp32 scales (empty tuple uncompressed)."""
    slot = SLOT_FNS[family]

    def body(carry, ps):
        xx = carry
        p_slot, s_slot = ps
        collector: list = []
        meta_c = meta._replace(
            tag=offload_mod.make_capture_tag(alpha, collector,
                                             codec=offload_dtype))
        p_full = gather_params(p_slot, shard_dims, ctx)
        xx, s_new, aux = slot(cfg, p_full, s_slot, xx, ctx, meta_c, extras)
        off = tuple(t for k, t in collector if k == "off")
        keep = tuple(t for k, t in collector if k == "keep")
        scales = tuple(t for k, t in collector if k == "scale")
        return xx, (s_new, aux, off, keep, scales)

    x, (state_new, auxs, off_acts, keep_acts, scales) = jax.lax.scan(
        body, x, (stage_params, state))
    return x, state_new, jnp.sum(auxs), off_acts, keep_acts, scales


def stage_apply_inject(cfg, family: str, stage_params, shard_dims, state, x,
                       ctx: Ctx, meta: ChunkMeta, alpha: float,
                       off_acts, keep_acts, extras=None, *,
                       offload_dtype="none", scales=()):
    """Prefetch-'ahead' backward replay of one stage: the same slot scan,
    consuming the staged residuals (off rows reloaded one event ahead by
    the seam, keep rows passed through on device) as per-slot scan inputs;
    the inject tag substitutes them at the original tag sites.  Each slot
    runs under ``save_only_these_names`` so the replay's own residual set
    is exactly the substituted values — no second materialization.  With a
    codec the off inputs are reloaded wire payloads and `scales` joins the
    scan inputs so the inject tag can reconstruct rows at each site."""
    slot = SLOT_FNS[family]
    save_names = list(meta.names)
    if offload_dtype not in (None, "none"):
        save_names.append(offload_mod.scale_name_for(meta.names[0]))

    def body(carry, ps):
        xx = carry
        p_slot, s_slot, off_slot, keep_slot, scale_slot = ps

        def inner(p_l, s_l, x_l, off_l, keep_l, scale_l):
            p_full = gather_params(p_l, shard_dims, ctx)
            meta_i = meta._replace(tag=offload_mod.make_inject_tag(
                alpha, off_l, keep_l, names=meta.names,
                codec=offload_dtype, scales=scale_l))
            return slot(cfg, p_full, s_l, x_l, ctx, meta_i, extras)

        fn = jax.checkpoint(
            inner, policy=jax.checkpoint_policies.save_only_these_names(
                *save_names))
        xx, s_new, aux = fn(p_slot, s_slot, xx, off_slot, keep_slot,
                            scale_slot)
        return xx, (s_new, aux)

    x, (state_new, auxs) = jax.lax.scan(
        body, x, (stage_params, state, off_acts, keep_acts, tuple(scales)))
    return x, state_new, jnp.sum(auxs)
