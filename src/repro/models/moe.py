"""Mixture-of-Experts with expert parallelism over the `model` axis.

Token-choice top-k routing with capacity-bounded, all_to_all dispatch:

  1. route local tokens (router weight is gathered — it's tiny);
  2. scatter token copies into per-destination-rank send buffers
     [sp, C, d] (C = capacity per src->dst pair, static);
  3. all_to_all over the model axis (the EP dispatch collective);
  4. second-level scatter into per-local-expert capacity buffers and one
     batched matmul per expert stack [E_loc, C_e, *];
  5. inverse all_to_all, weighted combine of the top-k returns.

Over-capacity token copies are dropped (standard capacity-factor semantics);
tests pin cf high enough to verify exact equivalence with the dense oracle.
DeepSeek's shared expert runs densely on the local shard.  The auxiliary
load-balancing loss (Switch-style f·P) is returned for the trainer.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.parallel.ctx import Ctx


def moe_dims(cfg, sp: int):
    E = cfg.moe.num_experts
    assert E % sp == 0, f"experts {E} must divide model axis {sp}"
    return E, E // sp


def moe_block(x_loc, p, cfg, ctx: Ctx, *, name_tag=None) -> Tuple[jax.Array, jax.Array]:
    """x_loc: [B, T_loc, d] sequence shard. Returns (y [B,T_loc,d], aux)."""
    moe = cfg.moe
    B, Tl, d = x_loc.shape
    sp = ctx.sp
    E, E_loc = moe_dims(cfg, sp)
    K = moe.top_k
    n_tok = B * Tl
    xt = x_loc.reshape(n_tok, d)

    # ---- routing (fp32) ----------------------------------------------------
    logits = (xt @ p["router"]).astype(jnp.float32)          # [n, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                   # [n, K]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)   # renormalize
    # Switch aux loss: E * mean(f_e * P_e)
    f_e = jnp.mean(jnp.sum(jax.nn.one_hot(top_e, E), axis=1), axis=0)
    P_e = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f_e * P_e)

    # ---- level-1 dispatch: per-destination-rank send buffers ---------------
    flat_e = top_e.reshape(-1)                               # [n*K]
    flat_w = top_p.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(n_tok), K)
    dst = flat_e // E_loc                                    # [n*K] in [0,sp)
    C = max(1, math.ceil(n_tok * K / sp * moe.capacity_factor))
    one = jax.nn.one_hot(dst, sp, dtype=jnp.int32)           # [n*K, sp]
    pos = jnp.sum(jnp.cumsum(one, axis=0) * one, axis=-1) - 1  # pos in dst buf
    keep = pos < C
    dst_c = jnp.where(keep, dst, sp - 1)
    pos_c = jnp.where(keep, pos, C)                          # C = trash slot
    send = jnp.zeros((sp, C + 1, d), x_loc.dtype)
    send = send.at[dst_c, pos_c].set(xt[flat_tok], mode="drop")
    send_eid = jnp.full((sp, C + 1), -1, jnp.int32)
    send_eid = send_eid.at[dst_c, pos_c].set(
        jnp.where(keep, flat_e % E_loc, -1), mode="drop")
    send, send_eid = send[:, :C], send_eid[:, :C]

    # ---- all_to_all over the model axis ------------------------------------
    recv = ctx.all_to_all_model(send, split_axis=0, concat_axis=0)
    recv_eid = ctx.all_to_all_model(send_eid[..., None], 0, 0)[..., 0]
    rt = recv.reshape(sp * C, d)
    re = recv_eid.reshape(sp * C)

    # ---- level-2 dispatch into per-expert capacity buffers -----------------
    Ce = max(1, math.ceil(sp * C / E_loc * moe.capacity_factor))
    valid = re >= 0
    eid = jnp.where(valid, re, 0)
    one2 = jax.nn.one_hot(eid, E_loc, dtype=jnp.int32) * valid[:, None]
    pos2 = jnp.sum(jnp.cumsum(one2, axis=0) * one2, axis=-1) - 1
    pos2 = jnp.where(valid, pos2, Ce)
    keep2 = (pos2 < Ce) & valid
    eid_c = jnp.where(keep2, eid, 0)
    pos2_c = jnp.where(keep2, pos2, Ce)
    buf = jnp.zeros((E_loc, Ce + 1, d), x_loc.dtype)
    buf = buf.at[eid_c, pos2_c].set(jnp.where(keep2[:, None], rt, 0),
                                    mode="drop")
    buf = buf[:, :Ce]

    # ---- expert FFNs (batched over the local expert stack) -----------------
    h_g = jnp.einsum("ecd,edf->ecf", buf, p["w1"])
    h_u = jnp.einsum("ecd,edf->ecf", buf, p["w3"])
    h = jax.nn.silu(h_g) * h_u
    if name_tag is not None:
        h = name_tag(h)
    out = jnp.einsum("ecf,efd->ecd", h, p["w2"])             # [E_loc, Ce, d]

    # ---- undispatch + return + combine --------------------------------------
    back = out[eid_c, pos2_c] * keep2[:, None].astype(out.dtype)
    back = back.reshape(sp, C, d)
    ret = ctx.all_to_all_model(back, split_axis=0, concat_axis=0)
    got = ret[dst_c, pos_c] * keep[:, None].astype(ret.dtype)  # [n*K, d]
    y = jnp.zeros((n_tok, d), jnp.float32)
    y = y.at[flat_tok].add(got.astype(jnp.float32)
                           * flat_w[:, None].astype(jnp.float32))
    y = y.astype(x_loc.dtype)

    # ---- shared experts (dense, deepseek) -----------------------------------
    if moe.n_shared_experts:
        g = xt @ p["ws1"]
        u = xt @ p["ws3"]
        hs = jax.nn.silu(g) * u
        if name_tag is not None:
            hs = name_tag(hs)
        y = y + hs @ p["ws2"]

    return y.reshape(B, Tl, d), aux
