"""SSM mixers: Mamba2 (SSD chunked) and RWKV6 (Finch, data-dependent decay).

Sharding over the `model` axis (DESIGN.md §5):
  * Mamba2: *head-parallel* — heads (d_inner/head_dim = 112 for zamba2)
    divide the axis; each rank processes the full chunk for its head shard
    (the chunk is all-gathered, Megatron-SP-style, and the output
    reduce-scattered back to sequence shards).  SSM state is carried across
    SPPO subsequences and is naturally head-sharded — the hybrid arch has
    *no* Type-0 KV growth.
  * RWKV6: heads (40) do not divide 16, so RWKV stays fully
    *sequence-sharded*: projections run on the local token shard with
    gathered weights (zero duplicated FLOPs); the WKV recurrence runs on
    local tokens and ranks are stitched together by an associative
    state-composition pass (all-gather of tiny per-rank (decay, state)
    summaries, prefix-composed locally).  Token shift crosses rank
    boundaries with a single ppermute and chunk boundaries with carried
    tail state.

Both carry fp32 recurrent state across chunks/decode steps; both use a
sub-chunk parallel scan (quadratic-in-P dual form, P<=128) inside a chunk.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.parallel.ctx import Ctx


# ---------------------------------------------------------------------------
# Mamba2 (SSD) — head-parallel
# ---------------------------------------------------------------------------


class MambaState(NamedTuple):
    ssm: jax.Array    # [B, H_loc, hd, ds] fp32
    conv: jax.Array   # [B, W-1, conv_ch_loc] carried conv tail


def mamba2_dims(cfg, sp: int):
    d_in = cfg.ssm.expand * cfg.d_model
    H = d_in // cfg.ssm.head_dim
    assert H % sp == 0, f"mamba heads {H} must divide model axis {sp}"
    return d_in, H, H // sp


def mamba2_init_state(cfg, batch: int, sp: int) -> MambaState:
    d_in, H, Hl = mamba2_dims(cfg, sp)
    ds, w = cfg.ssm.d_state, cfg.ssm.conv_width
    conv_ch = d_in // sp + 2 * ds
    return MambaState(
        ssm=jnp.zeros((batch, Hl, cfg.ssm.head_dim, ds), jnp.float32),
        conv=jnp.zeros((batch, w - 1, conv_ch), jnp.float32),
    )


def _causal_conv(x, conv_tail, kernel):
    """Depthwise causal conv. x: [B, T, C]; conv_tail: [B, W-1, C];
    kernel: [W, C].  Returns (y [B,T,C], new_tail [B,W-1,C] fp32)."""
    W = kernel.shape[0]
    xx = jnp.concatenate([conv_tail.astype(x.dtype), x], axis=1)
    y = sum(xx[:, i:i + x.shape[1]] * kernel[i][None, None, :]
            for i in range(W))
    new_tail = xx[:, -(W - 1):].astype(jnp.float32)
    return y, new_tail


def pick_subchunk(t: int, cap: int = 128) -> int:
    """Largest power-of-two divisor of t, capped (sub-chunk scan width)."""
    p = 1
    while p * 2 <= cap and t % (p * 2) == 0:
        p *= 2
    return p


def _segsum_decay(a):
    """a: [..., P] per-step log-decay. L[..., t, s] = exp(sum_{s<j<=t} a_j)
    for s <= t else 0.  The mask is applied *inside* the exp so the masked
    entries (whose raw diff is +large) neither overflow nor poison gradients
    with inf*0 -> NaN."""
    P = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    tri = jnp.tril(jnp.ones((P, P), bool))
    return jnp.exp(jnp.where(tri, diff, -1e30))


def mamba2_mixer(x_loc, p, cfg, ctx: Ctx, state: MambaState, *,
                 name_tag=None, pre_gathered=False, subchunk=128):
    """x_loc: [B, T_loc, d] sequence shard (or [B, T, d] replicated when
    pre_gathered — the decode path).  Returns (y same sharding, new state)."""
    ssm = cfg.ssm
    d_in, H, Hl = mamba2_dims(cfg, ctx.sp)
    hd, ds = ssm.head_dim, ssm.d_state
    d_in_loc = d_in // ctx.sp

    x = x_loc if pre_gathered else ctx.all_gather_model(x_loc, axis=1)
    B, T, _ = x.shape
    # projections: head-sharded x/z/dt ("keep" weights), replicated B/C
    xs = x @ p["in_x"]                    # [B,T,d_in/sp]
    bc = x @ p["in_bc"]                   # [B,T,2*ds]   (replicated)
    dt = x @ p["in_dt"] + p["dt_bias"]    # [B,T,Hl]
    z = x @ p["in_z"]                     # [B,T,d_in/sp]
    conv_in = jnp.concatenate([xs, bc], axis=-1)
    conv_k = jnp.concatenate([p["conv_x"], p["conv_bc"]], axis=-1)
    conv_out, new_tail = _causal_conv(conv_in, state.conv, conv_k)
    conv_out = jax.nn.silu(conv_out)
    xs = conv_out[..., :d_in_loc]
    Bm = conv_out[..., d_in_loc:d_in_loc + ds]
    Cm = conv_out[..., d_in_loc + ds:]
    if name_tag is not None:
        xs = name_tag(xs)

    dt = jax.nn.softplus(dt.astype(jnp.float32))               # [B,T,Hl]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))               # [Hl]
    da = dt * A[None, None, :]                                 # log-decay
    xh = xs.reshape(B, T, Hl, hd).astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)

    # sub-chunk scan: quadratic dual form inside P, state across sub-chunks
    P = pick_subchunk(T, subchunk)
    nc = T // P
    xh = xh.reshape(B, nc, P, Hl, hd)
    Bc = Bf.reshape(B, nc, P, ds)
    Cc = Cf.reshape(B, nc, P, ds)
    dac = da.reshape(B, nc, P, Hl)
    dtc = dt.reshape(B, nc, P, Hl)

    def step(S, blk):
        xb, bb, cb, ab, dtb = blk                         # [B,P,...]
        Lmat = _segsum_decay(ab.transpose(0, 2, 1))       # [B,Hl,P,P]
        cb_ = jnp.einsum("bpn,bqn->bpq", cb, bb)          # C_t·B_s
        w = cb_[:, None] * Lmat                           # [B,Hl,t,s]
        y = jnp.einsum("bhts,bsh,bshd->bthd", w, dtb, xb)
        cumin = jnp.exp(jnp.cumsum(ab, axis=1))           # [B,P,Hl]
        y = y + jnp.einsum("bph,bpn,bhdn->bphd", cumin, cb, S)
        tot = cumin[:, -1]                                # [B,Hl]
        cs = jnp.cumsum(ab, axis=1)
        decay_s = jnp.exp(cs[:, -1:, :] - cs)             # [B,P,Hl]
        Snew = S * tot[:, :, None, None] + jnp.einsum(
            "bph,bphd,bpn->bhdn", decay_s * dtb, xb, bb)
        return Snew, y

    S, ys = jax.lax.scan(
        step, state.ssm,
        (xh.transpose(1, 0, 2, 3, 4), Bc.transpose(1, 0, 2, 3),
         Cc.transpose(1, 0, 2, 3), dac.transpose(1, 0, 2, 3),
         dtc.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, T, Hl, hd)
    y = y + xh.reshape(B, T, Hl, hd) * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, T, Hl * hd)
    # gated *per-head group* RMSNorm (shard-invariant under head-parallel TP;
    # equals mamba2's RMSNormGated with ngroups = heads — DESIGN.md §5),
    # then output projection (partial rows -> reduce/scatter)
    yg = (y * jax.nn.silu(z.astype(jnp.float32))).reshape(B, T, Hl, hd)
    var = jnp.mean(yg * yg, axis=-1, keepdims=True)
    yg = yg * jax.lax.rsqrt(var + 1e-6)
    y = (yg.reshape(B, T, Hl * hd)
         * (1.0 + p["norm_scale"].astype(jnp.float32))).astype(x.dtype)
    if name_tag is not None:
        y = name_tag(y)
    out = y @ p["out"]                                    # [B,T,d] partial
    if pre_gathered:
        out = ctx.psum_model(out)
    else:
        out = ctx.reduce_scatter_model(out, axis=1)
    return out, MambaState(ssm=S, conv=new_tail)


# ---------------------------------------------------------------------------
# RWKV6 (Finch) — sequence-sharded, associative cross-rank state composition
# ---------------------------------------------------------------------------


class RWKVState(NamedTuple):
    wkv: jax.Array      # [B, H, dk, dv] fp32 (replicated across model ranks)
    shift_t: jax.Array  # [B, 1, d] last token of previous chunk (time-mix)
    shift_c: jax.Array  # [B, 1, d] last token (channel-mix)


def rwkv6_init_state(cfg, batch: int, sp: int) -> RWKVState:
    H, dk = cfg.n_heads, cfg.hd
    return RWKVState(
        wkv=jnp.zeros((batch, H, dk, dk), jnp.float32),
        shift_t=jnp.zeros((batch, 1, cfg.d_model), jnp.float32),
        shift_c=jnp.zeros((batch, 1, cfg.d_model), jnp.float32),
    )


def _shard_token_shift(x_loc, prev_tail, ctx: Ctx):
    """Previous-token view of a sequence-sharded chunk.

    Rank r receives rank r-1's last token via ppermute; rank 0 uses the
    carried chunk tail.  Returns (x_prev [B,T_loc,d], new_tail [B,1,d] —
    the *global* chunk tail, replicated via a masked psum)."""
    last = x_loc[:, -1:]
    if ctx.sp > 1:
        from_prev = ctx.ppermute_model(
            last, perm=[(i, i + 1) for i in range(ctx.sp - 1)])
        ridx = ctx.model_index()
        head = jnp.where(ridx == 0, prev_tail.astype(x_loc.dtype), from_prev)
        is_last = (ridx == ctx.sp - 1).astype(last.dtype)
        new_tail = ctx.psum_model(last * is_last).astype(jnp.float32)
    else:
        head = prev_tail.astype(x_loc.dtype)
        new_tail = last.astype(jnp.float32)
    x_prev = jnp.concatenate([head, x_loc[:, :-1]], axis=1)
    return x_prev, new_tail


def _compose_states(S_start, dec_loc, S_loc, ctx: Ctx):
    """Stitch per-rank WKV summaries into each rank's incoming state.

    dec_loc: [B,H,dk] total decay over this rank's tokens;
    S_loc: [B,H,dk,dv] state produced from this rank's tokens alone.
    Returns (S_in for this rank, S_final replicated)."""
    if ctx.sp == 1:
        return S_start, S_start * dec_loc[..., None] + S_loc
    decs = ctx.all_gather_model(dec_loc[None], axis=0)   # [sp,B,H,dk]
    Ss = ctx.all_gather_model(S_loc[None], axis=0)       # [sp,B,H,dk,dv]
    ridx = ctx.model_index()
    S_run = S_start
    S_in = S_start
    for j in range(ctx.sp):
        S_new = S_run * decs[j][..., None] + Ss[j]
        S_in = jnp.where(ridx > j, S_new, S_in)
        S_run = S_new
    return S_in, S_run


def rwkv6_time_mix(x_loc, p, cfg, ctx: Ctx, state: RWKVState, *,
                   name_tag=None, pre_gathered=False, subchunk=32):
    """RWKV6 time-mix (WKV6) on the local sequence shard."""
    H, dk = cfg.n_heads, cfg.hd
    dv = dk
    x = x_loc
    B, T, d = x.shape
    xf = x.astype(jnp.float32)
    if pre_gathered:  # decode: replicated single token
        xprev = state.shift_t.astype(jnp.float32)
        new_tail = xf[:, -1:]
    else:
        xprev, new_tail = _shard_token_shift(xf, state.shift_t, ctx)
    xx = xprev - xf
    # data-dependent lerp via small LoRA
    xbar = xf + xx * p["mu_x"]
    lora = jnp.tanh(xbar @ p["ddl_a"]) @ p["ddl_b"]     # [B,T,5*d]
    lam = lora.reshape(B, T, 5, d) + p["mu_rkvwg"][None, None]
    xr, xk, xv, xw, xg = [(xf + xx * lam[:, :, i]).astype(x.dtype)
                          for i in range(5)]

    r = (xr @ p["wr"]).reshape(B, T, H, dk).astype(jnp.float32)
    k = (xk @ p["wk"]).reshape(B, T, H, dk).astype(jnp.float32)
    v = (xv @ p["wv"]).reshape(B, T, H, dv).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["wg"])                       # [B,T,d] gate
    dd = p["w0"][None, None] + jnp.tanh(xw @ p["dec_a"]) @ p["dec_b"]
    lw = -jnp.exp(dd.astype(jnp.float32)).reshape(B, T, H, dk)  # log-decay <=0
    u = p["u"].reshape(H, dk).astype(jnp.float32)

    P = pick_subchunk(T, subchunk)
    nc = T // P
    rb = r.reshape(B, nc, P, H, dk).transpose(1, 0, 3, 2, 4)   # [nc,B,H,P,dk]
    kb = k.reshape(B, nc, P, H, dk).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(B, nc, P, H, dv).transpose(1, 0, 3, 2, 4)
    lwb = lw.reshape(B, nc, P, H, dk).transpose(1, 0, 3, 2, 4)

    tri_strict = jnp.tril(jnp.ones((P, P), bool), -1)

    def step(carry, blk):
        S, dec = carry
        rr, kk, vv, ll = blk                     # [B,H,P,*]
        cs = jnp.cumsum(ll, axis=2)              # inclusive
        cs_prev = cs - ll                        # exclusive (before t)
        # intra-chunk per-channel decay in segsum form: every exponent <= 0
        diff = cs_prev[:, :, :, None, :] - cs[:, :, None, :, :]  # [B,H,t,s,c]
        dec_ts = jnp.exp(jnp.where(tri_strict[None, None, :, :, None],
                                   diff, -1e30))
        att = jnp.einsum("bhtc,bhtsc,bhsc->bhts", rr, dec_ts, kk)
        diag = jnp.einsum("bhtc,hc,bhtc->bht", rr, u, kk)
        y = jnp.einsum("bhts,bhsv->bhtv", att, vv) + diag[..., None] * vv
        q_dec = rr * jnp.exp(cs_prev)            # cs_prev <= 0: safe
        y = y + jnp.einsum("bhtc,bhcv->bhtv", q_dec, S)
        tot = jnp.exp(cs[:, :, -1])              # [B,H,dk]
        Snew = S * tot[..., None] + jnp.einsum(
            "bhsc,bhsv->bhcv", kk * jnp.exp(cs[:, :, -1:, :] - cs), vv)
        return (Snew, dec * tot), y

    S0 = jnp.zeros_like(state.wkv)
    dec0 = jnp.ones((B, H, dk), jnp.float32)
    (S_loc, dec_loc), ys = jax.lax.scan(step, (S0, dec0), (rb, kb, vb, lwb))
    # stitch ranks: add the incoming-state contribution for local tokens
    if pre_gathered:
        S_in, S_fin = state.wkv, state.wkv * dec_loc[..., None] + S_loc
    else:
        S_in, S_fin = _compose_states(state.wkv, dec_loc, S_loc, ctx)
    lw_cum_prev = jnp.cumsum(lw, axis=1) - lw               # [B,T,H,dk]
    q_dec_all = r * jnp.exp(lw_cum_prev)
    y_in = jnp.einsum("bthc,bhcv->bthv", q_dec_all, S_in)   # [B,T,H,dv]
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, T, H, dv) + y_in

    # per-head groupnorm, gate, output projection
    mu = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    y = (y - mu) * jax.lax.rsqrt(var + 64e-5)
    y = y.reshape(B, T, H * dv) * p["ln_x_scale"] + p["ln_x_bias"]
    y = (y * g).astype(x.dtype)
    if name_tag is not None:
        y = name_tag(y)
    out = y @ p["wo"]
    return out, RWKVState(wkv=S_fin, shift_t=new_tail, shift_c=state.shift_c)


def rwkv6_channel_mix(x_loc, p, cfg, ctx: Ctx, state: RWKVState, *,
                      name_tag=None, pre_gathered=False):
    """RWKV6 channel-mix (FFN analogue) on the local sequence shard."""
    x = x_loc
    xf = x.astype(jnp.float32)
    if pre_gathered:
        xprev = state.shift_c.astype(jnp.float32)
        new_tail = xf[:, -1:]
    else:
        xprev, new_tail = _shard_token_shift(xf, state.shift_c, ctx)
    xx = xprev - xf
    xk = (xf + xx * p["mu_k"]).astype(x.dtype)
    xr = (xf + xx * p["mu_r"]).astype(x.dtype)
    h = xk @ p["wk_c"]
    h = jnp.square(jax.nn.relu(h))
    if name_tag is not None:
        h = name_tag(h)
    kv = h @ p["wv_c"]
    out = jax.nn.sigmoid((xr @ p["wr_c"]).astype(jnp.float32)).astype(x.dtype) * kv
    return out, RWKVState(wkv=state.wkv, shift_t=state.shift_t,
                          shift_c=new_tail)
