"""Model zoo: parameter init, shard-dim specs, and ModelDef per architecture.

Shard-dim markers (strings/ints, leaves of a pytree mirroring the params):
  int d      — "ag": stored sharded on dim d over `model`, all-gathered per use
  "keepN"    — stored & used sharded on dim N (embedding, LM head, experts,
               mamba head shards)
  "rep"      — replicated over `model`

All markers describe the *per-slot / per-leaf* layout; the runner adds the
slot-stack and data-stack dims when building global shapes and
PartitionSpecs.
"""
from __future__ import annotations

import math
import zlib
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, get_config
from repro.models import attention as A
from repro.models import layers as L
from repro.models import ssm as S
from repro.models import transformer as T
from repro.parallel.ctx import Ctx


def _key(rng, *tags):
    k = rng
    for t in tags:
        # stable across processes — python's str hash is salted per run,
        # which made parameter init (and hence training losses) differ
        # between otherwise-identical CLI invocations
        k = jax.random.fold_in(k, zlib.crc32(str(t).encode()) % (2**31))
    return k


def keep(d: int) -> str:
    return f"keep{d}"


# ---------------------------------------------------------------------------
# Per-component init + spec builders (init returns FULL unsharded leaves;
# the runner shards on device placement via NamedSharding)
# ---------------------------------------------------------------------------


def _norm(rng, cfg, dtype):
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.zeros((cfg.d_model,), dtype)}
    return {"scale": jnp.ones((cfg.d_model,), dtype),
            "bias": jnp.zeros((cfg.d_model,), dtype)}


def _norm_spec(cfg):
    if cfg.norm == "rmsnorm":
        return {"scale": "rep"}
    return {"scale": "rep", "bias": "rep"}


def _attn(rng, cfg, dtype, out_scale=1.0):
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    p = {
        "wq": L.dense_init(_key(rng, "wq"), d, H * hd, dtype),
        "wk": L.dense_init(_key(rng, "wk"), d, Hkv * hd, dtype),
        "wv": L.dense_init(_key(rng, "wv"), d, Hkv * hd, dtype),
        "wo": L.dense_init(_key(rng, "wo"), H * hd, d, dtype, std=out_scale / math.sqrt(H * hd)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((Hkv * hd,), dtype)
        p["bv"] = jnp.zeros((Hkv * hd,), dtype)
    return p


def _attn_spec(cfg):
    s = {"wq": 1, "wk": 1, "wv": 1, "wo": 0}
    if cfg.qkv_bias:
        s.update({"bq": "rep", "bk": "rep", "bv": "rep"})
    return s


def _mla(rng, cfg, dtype, out_scale=1.0):
    m, d, H = cfg.mla, cfg.d_model, cfg.n_heads
    dn, dr, dv, dc, qr = (m.nope_head_dim, m.rope_head_dim, m.v_head_dim,
                          m.kv_lora_rank, m.q_lora_rank)
    return {
        "wq_a": L.dense_init(_key(rng, "wq_a"), d, qr, dtype),
        "q_norm": jnp.zeros((qr,), dtype),
        "wq_b": L.dense_init(_key(rng, "wq_b"), qr, H * (dn + dr), dtype),
        "wkv_a": L.dense_init(_key(rng, "wkv_a"), d, dc + dr, dtype),
        "kv_norm": jnp.zeros((dc,), dtype),
        "w_uk": L.trunc_normal(_key(rng, "w_uk"), (H, dn, dc), 1 / math.sqrt(dn), dtype),
        "w_uv": L.trunc_normal(_key(rng, "w_uv"), (H, dc, dv), 1 / math.sqrt(dc), dtype),
        "wo": L.dense_init(_key(rng, "wo"), H * dv, d, dtype,
                           std=out_scale / math.sqrt(H * dv)),
    }


def _mla_spec(cfg):
    return {"wq_a": 1, "q_norm": "rep", "wq_b": 1, "wkv_a": "rep",
            "kv_norm": "rep", "w_uk": 0, "w_uv": 0, "wo": 0}


def _mlp(rng, cfg, dtype, d_ff=None, out_scale=1.0):
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    p = {"w1": L.dense_init(_key(rng, "w1"), d, ff, dtype),
         "w2": L.dense_init(_key(rng, "w2"), ff, d, dtype,
                            std=out_scale / math.sqrt(ff))}
    if cfg.act in ("swiglu", "geglu"):
        p["w3"] = L.dense_init(_key(rng, "w3"), d, ff, dtype)
    elif cfg.mlp_bias:
        p["b1"] = jnp.zeros((ff,), dtype)
        p["b2"] = jnp.zeros((d,), dtype)
    return p


def _mlp_spec(cfg, gated=None):
    gated = cfg.act in ("swiglu", "geglu") if gated is None else gated
    s = {"w1": 1, "w2": 0}
    if gated:
        s["w3"] = 1
    elif cfg.mlp_bias:
        s.update({"b1": "rep", "b2": "rep"})
    return s


def _moe(rng, cfg, dtype, out_scale=1.0):
    m, d = cfg.moe, cfg.d_model
    E, ff = m.num_experts, m.d_ff_expert
    p = {
        "router": L.dense_init(_key(rng, "router"), d, E, jnp.float32),
        "w1": L.trunc_normal(_key(rng, "ew1"), (E, d, ff), 1 / math.sqrt(d), dtype),
        "w3": L.trunc_normal(_key(rng, "ew3"), (E, d, ff), 1 / math.sqrt(d), dtype),
        "w2": L.trunc_normal(_key(rng, "ew2"), (E, ff, d),
                             out_scale / math.sqrt(ff), dtype),
    }
    if m.n_shared_experts:
        sf = ff * m.n_shared_experts
        p["ws1"] = L.dense_init(_key(rng, "ws1"), d, sf, dtype)
        p["ws3"] = L.dense_init(_key(rng, "ws3"), d, sf, dtype)
        p["ws2"] = L.dense_init(_key(rng, "ws2"), sf, d, dtype,
                                std=out_scale / math.sqrt(sf))
    return p


def _moe_spec(cfg):
    s = {"router": "rep", "w1": keep(0), "w3": keep(0), "w2": keep(0)}
    if cfg.moe.n_shared_experts:
        s.update({"ws1": 1, "ws3": 1, "ws2": 0})
    return s


def _mamba(rng, cfg, dtype):
    ssm = cfg.ssm
    d = cfg.d_model
    d_in = ssm.expand * d
    H = d_in // ssm.head_dim
    ds, W = ssm.d_state, ssm.conv_width
    return {
        "in_x": L.dense_init(_key(rng, "in_x"), d, d_in, dtype),
        "in_bc": L.dense_init(_key(rng, "in_bc"), d, 2 * ds, dtype),
        "in_dt": L.dense_init(_key(rng, "in_dt"), d, H, dtype),
        "in_z": L.dense_init(_key(rng, "in_z"), d, d_in, dtype),
        "dt_bias": jnp.asarray(
            np.log(np.expm1(np.linspace(1e-3, 1e-1, H))), dtype),
        "conv_x": L.trunc_normal(_key(rng, "cx"), (W, d_in), 1 / math.sqrt(W), dtype),
        "conv_bc": L.trunc_normal(_key(rng, "cb"), (W, 2 * ds), 1 / math.sqrt(W), dtype),
        "A_log": jnp.asarray(np.log(np.linspace(1.0, 16.0, H)), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm_scale": jnp.zeros((d_in,), dtype),
        "out": L.dense_init(_key(rng, "out"), d_in, d, dtype),
    }


def _mamba_spec():
    return {"in_x": keep(1), "in_bc": "rep", "in_dt": keep(1),
            "in_z": keep(1), "dt_bias": keep(0), "conv_x": keep(1),
            "conv_bc": "rep", "A_log": keep(0), "D": keep(0),
            "norm_scale": keep(0), "out": keep(0)}


def _rwkv_tmix(rng, cfg, dtype):
    d = cfg.d_model
    R = 64
    return {
        "mu_x": jnp.zeros((d,), jnp.float32),
        "ddl_a": L.dense_init(_key(rng, "da"), d, 5 * 32, jnp.float32),
        "ddl_b": L.trunc_normal(_key(rng, "db"), (5 * 32, 5 * d), 0.01, jnp.float32),
        "mu_rkvwg": jnp.zeros((5, d), jnp.float32),
        "wr": L.dense_init(_key(rng, "wr"), d, d, dtype),
        "wk": L.dense_init(_key(rng, "wk"), d, d, dtype),
        "wv": L.dense_init(_key(rng, "wv"), d, d, dtype),
        "wg": L.dense_init(_key(rng, "wg"), d, d, dtype),
        "dec_a": L.dense_init(_key(rng, "dea"), d, R, jnp.float32),
        "dec_b": L.trunc_normal(_key(rng, "deb"), (R, d), 0.01, jnp.float32),
        "w0": jnp.asarray(np.linspace(-6.0, -1.0, d), jnp.float32),
        "u": L.trunc_normal(_key(rng, "u"), (d,), 0.3, jnp.float32),
        "ln_x_scale": jnp.ones((d,), jnp.float32),
        "ln_x_bias": jnp.zeros((d,), jnp.float32),
        "wo": L.dense_init(_key(rng, "wo"), d, d, dtype),
    }


def _rwkv_tmix_spec():
    return {"mu_x": "rep", "ddl_a": "rep", "ddl_b": 1, "mu_rkvwg": "rep",
            "wr": 1, "wk": 1, "wv": 1, "wg": 1, "dec_a": "rep", "dec_b": 1,
            "w0": "rep", "u": "rep", "ln_x_scale": "rep", "ln_x_bias": "rep",
            "wo": 0}


def _rwkv_cmix(rng, cfg, dtype):
    d, ff = cfg.d_model, cfg.d_ff
    return {
        "mu_k": jnp.zeros((d,), jnp.float32),
        "mu_r": jnp.zeros((d,), jnp.float32),
        "wk_c": L.dense_init(_key(rng, "wkc"), d, ff, dtype),
        "wv_c": L.dense_init(_key(rng, "wvc"), ff, d, dtype),
        "wr_c": L.dense_init(_key(rng, "wrc"), d, d, dtype),
    }


def _rwkv_cmix_spec():
    return {"mu_k": "rep", "mu_r": "rep", "wk_c": 1, "wv_c": 0, "wr_c": 1}


# ---------------------------------------------------------------------------
# Slot init per family
# ---------------------------------------------------------------------------


def _out_scale(cfg):
    return 1.0 / math.sqrt(2 * max(cfg.n_layers, 1))


def init_slot(cfg: ModelConfig, rng, slot_idx: int, n_real_slots: int, dtype):
    """Build one slot's params; slots >= n_real_slots are ghosts (gate 0)."""
    fam = cfg.family
    rng = _key(rng, "slot", slot_idx)
    ghost = slot_idx >= n_real_slots
    gate = jnp.float32(0.0 if ghost else 1.0)
    os = _out_scale(cfg)

    if fam in ("dense",):
        return {"ln1": _norm(rng, cfg, dtype), "ln2": _norm(_key(rng, 2), cfg, dtype),
                "attn": _attn(rng, cfg, dtype, os), "mlp": _mlp(rng, cfg, dtype, out_scale=os),
                "gate": gate}
    if fam == "moe":
        attn = (_mla(rng, cfg, dtype, os) if cfg.mla is not None
                else _attn(rng, cfg, dtype, os))
        return {"ln1": _norm(rng, cfg, dtype), "ln2": _norm(_key(rng, 2), cfg, dtype),
                "attn": attn, "moe": _moe(rng, cfg, dtype, os), "gate": gate}
    if fam == "vlm":
        n_self = cfg.cross_attn.every
        selfs = [
            {"ln1": _norm(_key(rng, i, 1), cfg, dtype),
             "ln2": _norm(_key(rng, i, 2), cfg, dtype),
             "attn": _attn(_key(rng, i, 3), cfg, dtype, os),
             "mlp": _mlp(_key(rng, i, 4), cfg, dtype, out_scale=os),
             "gate": gate}
            for i in range(n_self)
        ]
        stacked = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *selfs)
        xattn = _attn(_key(rng, "x"), cfg, dtype, os)
        return {"self": stacked, "xln1": _norm(_key(rng, 5), cfg, dtype),
                "xln2": _norm(_key(rng, 6), cfg, dtype), "xattn": xattn,
                "xmlp": _mlp(_key(rng, 7), cfg, dtype, out_scale=os),
                "xgate_attn": jnp.zeros((), jnp.float32),
                "xgate_mlp": jnp.zeros((), jnp.float32),
                "gate": gate}
    if fam == "hybrid":
        n_m = cfg.shared_attn_every
        total_mixers = cfg.n_layers
        base = slot_idx * n_m
        mambas = [
            {"ln": _norm(_key(rng, i, 1), cfg, dtype),
             "mix": _mamba(_key(rng, i, 2), cfg, dtype),
             "gate": jnp.float32(1.0 if (base + i) < total_mixers and not ghost else 0.0)}
            for i in range(n_m)
        ]
        stacked = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *mambas)
        return {"mamba": stacked, "gate_shared": gate, "gate": gate}
    if fam == "ssm":
        return {"ln1": _norm(rng, cfg, dtype), "ln2": _norm(_key(rng, 2), cfg, dtype),
                "tmix": _rwkv_tmix(rng, cfg, dtype),
                "cmix": _rwkv_cmix(_key(rng, 3), cfg, dtype), "gate": gate}
    if fam == "audio":
        return {"ln1": _norm(rng, cfg, dtype), "ln2": _norm(_key(rng, 2), cfg, dtype),
                "xln": _norm(_key(rng, 3), cfg, dtype),
                "attn": _attn(rng, cfg, dtype, os),
                "xattn": _attn(_key(rng, 4), cfg, dtype, os),
                "mlp": _mlp(rng, cfg, dtype, out_scale=os), "gate": gate}
    raise ValueError(fam)


def slot_spec(cfg: ModelConfig):
    fam = cfg.family
    if fam == "dense":
        return {"ln1": _norm_spec(cfg), "ln2": _norm_spec(cfg),
                "attn": _attn_spec(cfg), "mlp": _mlp_spec(cfg), "gate": "rep"}
    if fam == "moe":
        attn = _mla_spec(cfg) if cfg.mla is not None else _attn_spec(cfg)
        return {"ln1": _norm_spec(cfg), "ln2": _norm_spec(cfg),
                "attn": attn, "moe": _moe_spec(cfg), "gate": "rep"}
    if fam == "vlm":
        selfs = {"ln1": _norm_spec(cfg), "ln2": _norm_spec(cfg),
                 "attn": _attn_spec(cfg), "mlp": _mlp_spec(cfg), "gate": "rep"}
        # stacked sub-layer dim shifts ag dims by +1
        selfs = _shift_spec(selfs)
        return {"self": selfs, "xln1": _norm_spec(cfg), "xln2": _norm_spec(cfg),
                "xattn": _attn_spec(cfg), "xmlp": _mlp_spec(cfg),
                "xgate_attn": "rep", "xgate_mlp": "rep", "gate": "rep"}
    if fam == "hybrid":
        mamba = _shift_spec({"ln": _norm_spec(cfg), "mix": _mamba_spec(),
                             "gate": "rep"})
        return {"mamba": mamba, "gate_shared": "rep", "gate": "rep"}
    if fam == "ssm":
        return {"ln1": _norm_spec(cfg), "ln2": _norm_spec(cfg),
                "tmix": _rwkv_tmix_spec(), "cmix": _rwkv_cmix_spec(),
                "gate": "rep"}
    if fam == "audio":
        return {"ln1": _norm_spec(cfg), "ln2": _norm_spec(cfg),
                "xln": _norm_spec(cfg), "attn": _attn_spec(cfg),
                "xattn": _attn_spec(cfg), "mlp": _mlp_spec(cfg), "gate": "rep"}
    raise ValueError(fam)


def _shift_spec(spec):
    """Shift ag/keep dims by +1 for an extra leading stack dim."""
    def f(m):
        if isinstance(m, int):
            return m + 1
        if isinstance(m, str) and m.startswith("keep"):
            return keep(int(m[4:]) + 1)
        return m
    return jax.tree_util.tree_map(f, spec)


# ---------------------------------------------------------------------------
# Globals: embedding, positions, final norm, head, encoder, shared block
# ---------------------------------------------------------------------------


def init_globals(cfg: ModelConfig, rng, dtype):
    d = cfg.d_model
    vp = L.pad_vocab(cfg.vocab_size, 2048)
    g = {
        "embed": {"table": L.trunc_normal(_key(rng, "emb"), (vp, d), 0.02, dtype)},
        "final_norm": _norm(_key(rng, "fn"), cfg, dtype),
    }
    if not cfg.tie_embeddings:
        g["head"] = {"w": L.trunc_normal(_key(rng, "head"), (d, vp),
                                         1 / math.sqrt(d), dtype)}
    if cfg.pos_emb == "learned":
        g["pos"] = {"table": L.trunc_normal(_key(rng, "pos"),
                                            (cfg.max_position, d), 0.02, dtype)}
    if cfg.shared_attn_every:
        g["shared"] = {"ln1": _norm(_key(rng, "s1"), cfg, dtype),
                       "ln2": _norm(_key(rng, "s2"), cfg, dtype),
                       "attn": _attn(_key(rng, "sa"), cfg, dtype, _out_scale(cfg)),
                       "mlp": _mlp(_key(rng, "sm"), cfg, dtype,
                                   out_scale=_out_scale(cfg))}
    if cfg.encoder_layers:
        encs = [
            {"ln1": _norm(_key(rng, "e", i, 1), cfg, dtype),
             "ln2": _norm(_key(rng, "e", i, 2), cfg, dtype),
             "attn": _attn(_key(rng, "e", i, 3), cfg, dtype, _out_scale(cfg)),
             "mlp": _mlp(_key(rng, "e", i, 4), cfg, dtype,
                         out_scale=_out_scale(cfg))}
            for i in range(cfg.encoder_layers)
        ]
        g["encoder"] = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *encs)
        g["enc_final"] = _norm(_key(rng, "ef"), cfg, dtype)
    return g


def globals_spec(cfg: ModelConfig):
    g = {
        "embed": {"table": keep(0)},
        "final_norm": _norm_spec(cfg),
    }
    if not cfg.tie_embeddings:
        g["head"] = {"w": keep(1)}
    if cfg.pos_emb == "learned":
        g["pos"] = {"table": "rep"}
    if cfg.shared_attn_every:
        g["shared"] = {"ln1": _norm_spec(cfg), "ln2": _norm_spec(cfg),
                       "attn": _attn_spec(cfg), "mlp": _mlp_spec(cfg)}
    if cfg.encoder_layers:
        g["encoder"] = _shift_spec({"ln1": _norm_spec(cfg), "ln2": _norm_spec(cfg),
                                    "attn": _attn_spec(cfg), "mlp": _mlp_spec(cfg)})
        g["enc_final"] = _norm_spec(cfg)
    return g


# ---------------------------------------------------------------------------
# Per-slot state init (caches / recurrent states)
# ---------------------------------------------------------------------------


def init_slot_state(cfg: ModelConfig, ctx: Ctx, batch: int, cache_loc: int,
                    dtype, p_slot_full=None, context=None):
    fam = cfg.family
    Hkv, hd = cfg.n_kv_heads, cfg.hd
    if fam in ("dense",):
        return {"kv": A.init_cache(batch, cache_loc, Hkv, hd, hd, dtype)}
    if fam == "moe":
        if cfg.mla is not None:
            m = cfg.mla
            w = m.kv_lora_rank + m.rope_head_dim
            return {"kv": A.KVCache(
                k=jnp.zeros((batch, cache_loc, 1, w), dtype),
                v=jnp.zeros((batch, 1, 1, 1), dtype),   # latent is both k and v
                pos=jnp.full((cache_loc,), A.PAD, jnp.int32))}
        return {"kv": A.init_cache(batch, cache_loc, Hkv, hd, hd, dtype)}
    if fam == "vlm":
        n_self = cfg.cross_attn.every
        kv = A.init_cache(batch, cache_loc, Hkv, hd, hd, dtype)
        kvs = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (n_self,) + a.shape), kv)
        xkv = A.make_cross_kv(context, p_slot_full["xattn"], cfg, ctx,
                              cfg.cross_attn.n_context_tokens)
        return {"self": kvs, "xkv": xkv}
    if fam == "hybrid":
        n_m = cfg.shared_attn_every
        ms = S.mamba2_init_state(cfg, batch, ctx.sp)
        mstack = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (n_m,) + a.shape), ms)
        return {"mamba": mstack,
                "shared_kv": A.init_cache(batch, cache_loc, Hkv, hd, hd, dtype)}
    if fam == "ssm":
        return {"rwkv": S.rwkv6_init_state(cfg, batch, ctx.sp)}
    if fam == "audio":
        xkv = A.make_cross_kv(context, p_slot_full["xattn"], cfg, ctx,
                              cfg.n_frames)
        return {"kv": A.init_cache(batch, cache_loc, Hkv, hd, hd, dtype),
                "xkv": xkv}
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# ModelDef
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelDef:
    cfg: ModelConfig
    n_slots: int              # real slots (pre ghost-padding)
    layers_per_slot: int

    # ---- structure ---------------------------------------------------------
    def slots_per_stage(self, pp: int) -> int:
        return -(-self.n_slots // pp)

    def padded_slots(self, pp: int) -> int:
        return self.slots_per_stage(pp) * pp

    # ---- init --------------------------------------------------------------
    def init_stage_params(self, rng, stage: int, pp: int, dtype=jnp.bfloat16):
        spp = self.slots_per_stage(pp)
        slots = [init_slot(self.cfg, rng, stage * spp + i, self.n_slots, dtype)
                 for i in range(spp)]
        return jax.tree_util.tree_map(lambda *a: jnp.stack(a), *slots)

    def init_globals(self, rng, dtype=jnp.bfloat16):
        return init_globals(self.cfg, rng, dtype)

    def stage_spec(self):
        return slot_spec(self.cfg)

    def globals_spec(self):
        return globals_spec(self.cfg)

    # ---- execution pieces ---------------------------------------------------
    def embed(self, g, ids, q_pos_local, ctx: Ctx, *, decode=False):
        table = g["embed"]["table"]
        if decode:
            vloc = table.shape[0]
            lo = ctx.model_index() * vloc
            idx = jnp.clip(ids - lo, 0, vloc - 1)
            hit = ((ids >= lo) & (ids < lo + vloc))[..., None]
            x = ctx.psum_model(
                jnp.where(hit, jnp.take(table, idx, axis=0), 0)
                .astype(table.dtype))
        else:
            x = L.embed_tokens(ids, table, ctx, out_dtype=table.dtype)
        if self.cfg.pos_emb == "learned":
            pos = jnp.clip(q_pos_local, 0, self.cfg.max_position - 1)
            emb = jnp.take(g["pos"]["table"], pos, axis=0)
            # positions are [T] (shared) or [B, T] (per-request paged decode)
            x = x + (emb if pos.ndim == 2 else emb[None])
        return x

    def head_loss(self, g, x_loc, labels, mask, ctx: Ctx):
        x_loc = L.apply_norm(x_loc, g["final_norm"], self.cfg.norm)
        head = (g["embed"]["table"].T if self.cfg.tie_embeddings
                else g["head"]["w"])
        return L.vocab_parallel_xent(x_loc, head, labels, mask, ctx,
                                     real_vocab=self.cfg.vocab_size)

    def head_logits(self, g, x, ctx: Ctx):
        """Decode: full-vocab logits (gathered over model) for sampling."""
        x = L.apply_norm(x, g["final_norm"], self.cfg.norm)
        head = (g["embed"]["table"].T if self.cfg.tie_embeddings
                else g["head"]["w"])
        logits = (x @ head).astype(jnp.float32)
        logits = ctx.all_gather_model(logits, axis=2)
        return logits[..., :self.cfg.vocab_size]

    def encode(self, g, frames_loc, ctx: Ctx):
        """Whisper encoder over stub frame embeddings [B, F_loc, d]."""
        if not self.cfg.encoder_layers:
            return frames_loc
        spec = {"ln1": _norm_spec(self.cfg), "ln2": _norm_spec(self.cfg),
                "attn": _attn_spec(self.cfg), "mlp": _mlp_spec(self.cfg)}

        def body(x, p_layer):
            p = T.gather_params(p_layer, spec, ctx)
            return T.encoder_layer(self.cfg, p, x, ctx, self.cfg.n_frames), None

        x, _ = jax.lax.scan(body, frames_loc, g["encoder"])
        return L.apply_norm(x, g["enc_final"], self.cfg.norm)

    def init_state(self, stage_params_local, g, ctx: Ctx, batch: int,
                   cache_loc: int, dtype, context=None, spp: int = None):
        """Stacked per-slot state for this stage; cross-attn KV is computed
        here (chunk-invariant) from gathered per-slot projections."""
        spp = spp if spp is not None else jax.tree_util.tree_leaves(
            stage_params_local)[0].shape[0]
        spec = self.stage_spec()
        states = []
        for i in range(spp):
            p_full = None
            if self.cfg.family in ("vlm", "audio"):
                p_i = jax.tree_util.tree_map(lambda a: a[i], stage_params_local)
                p_full = T.gather_params(p_i, spec, ctx)
            states.append(init_slot_state(self.cfg, ctx, batch, cache_loc,
                                          dtype, p_full, context))
        return jax.tree_util.tree_map(lambda *a: jnp.stack(a), *states)

    def _extras(self, g, ctx):
        if not self.cfg.shared_attn_every:
            return None
        shared_spec = {"ln1": _norm_spec(self.cfg), "ln2": _norm_spec(self.cfg),
                       "attn": _attn_spec(self.cfg), "mlp": _mlp_spec(self.cfg)}
        return {"shared": T.gather_params(g["shared"], shared_spec, ctx)}

    def stage_apply(self, stage_params, state, x, ctx, meta, g, *,
                    offload=True, remat="sppo", offload_mode="explicit",
                    offload_dtype="none"):
        return T.stage_apply(self.cfg, self.cfg.family, stage_params,
                             self.stage_spec(), state, x, ctx, meta,
                             self._extras(g, ctx), offload=offload,
                             remat=remat, offload_mode=offload_mode,
                             offload_dtype=offload_dtype)

    def stage_apply_capture(self, stage_params, state, x, ctx, meta, g, *,
                            alpha: float, offload_dtype="none"):
        """Prefetch-'ahead' forward (DESIGN.md §12): returns the stage
        output plus the captured (off, keep, scale) residual sets."""
        return T.stage_apply_capture(self.cfg, self.cfg.family, stage_params,
                                     self.stage_spec(), state, x, ctx, meta,
                                     alpha, self._extras(g, ctx),
                                     offload_dtype=offload_dtype)

    def stage_apply_inject(self, stage_params, state, x, ctx, meta, g, *,
                           alpha: float, off_acts, keep_acts,
                           offload_dtype="none", scales=()):
        """Prefetch-'ahead' backward replay over staged residuals."""
        return T.stage_apply_inject(self.cfg, self.cfg.family, stage_params,
                                    self.stage_spec(), state, x, ctx, meta,
                                    alpha, off_acts, keep_acts,
                                    self._extras(g, ctx),
                                    offload_dtype=offload_dtype,
                                    scales=scales)


def build_model(name_or_cfg) -> ModelDef:
    cfg = (name_or_cfg if isinstance(name_or_cfg, ModelConfig)
           else get_config(name_or_cfg))
    fam = cfg.family
    if fam == "vlm":
        group = cfg.cross_attn.every
        n_slots = -(-cfg.n_layers // group)
        return ModelDef(cfg, n_slots, group + 1)
    if fam == "hybrid":
        group = cfg.shared_attn_every
        n_slots = -(-cfg.n_layers // group)
        return ModelDef(cfg, n_slots, group + 1)
    return ModelDef(cfg, cfg.n_layers, 1)
