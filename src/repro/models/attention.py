"""Attention blocks: GQA, MLA (DeepSeek), cross-attention — chunk-native.

Distribution recipe (DESIGN.md §4): activations and the KV cache are
*sequence-sharded* over the `model` axis.  For a chunk of queries we
all-gather q (cheap — chunk-sized), run partial flash attention against the
device-local KV shard, and merge the partial softmax statistics with one
pmax + two psum_scatters.  This is flash-decoding generalized to chunks; it
is head-count agnostic (the paper's §7.3 criticism of Ulysses does not apply)
and it keeps the paper's Type-0 "skeletal" KV memory balanced across devices.

The KV cache is position-tagged: every slot carries its global token
position (PAD = 2**30 for empty slots), so causality across subsequence
chunks, decode steps, and bidirectional encoder attention are all the same
kernel invocation.

Differentiability: the whole merge is training-grade on both kernel
backends.  The partial (o, l) outputs differentiate in (q, k, v) — via the
fused Pallas backward kernels' custom_vjp or the jnp scan's autodiff — and
every max statistic is gradient-frozen before the pmax/psum merge (pmax has
no VJP; the m-dependence cancels exactly in the o/l ratio, see
kernels/ref.py), so ∂loss/∂{q,k,v} flow through the exp-rescaled o and l
psums alone.  ``REPRO_USE_PALLAS=1`` training therefore runs the identical
code path as serve.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models import layers as L
from repro.parallel.ctx import Ctx

PAD = jnp.int32(2**30)


class KVCache(NamedTuple):
    """Sequence-sharded, position-tagged KV cache (one layer)."""

    k: jax.Array        # [B, S_loc, Hkv, hd_k]
    v: jax.Array        # [B, S_loc, Hkv, hd_v]  (may alias k for MLA)
    pos: jax.Array      # [S_loc] int32 global positions (PAD = empty)


def init_cache(batch: int, s_local: int, h_kv: int, hd_k: int, hd_v: int,
               dtype) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, s_local, h_kv, hd_k), dtype),
        v=jnp.zeros((batch, s_local, h_kv, hd_v), dtype),
        pos=jnp.full((s_local,), PAD, jnp.int32),
    )


def cache_append(cache: KVCache, k_new, v_new, pos_new, offset) -> KVCache:
    """Write this rank's shard of a chunk's KV at local slot `offset`
    (static int for chunked training, traced for decode)."""
    off = jnp.asarray(offset, jnp.int32)
    z = jnp.int32(0)
    return KVCache(
        k=jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype),
                                       (z, off, z, z)),
        v=jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype),
                                       (z, off, z, z)),
        pos=jax.lax.dynamic_update_slice(cache.pos,
                                         pos_new.astype(jnp.int32), (off,)),
    )


def _pick_mode(ctx: Ctx, q, k_loc, kv_view) -> str:
    """Byte-count switch (the §Perf 'auto' mode): gathering the KV shard
    costs ~(k+v) bytes; the gather-q merge moves q (bf16) + o (f32) + stats.
    GQA makes KV far narrower than q x heads, so short-chunk training cells
    prefer gather_kv, while decode/long-cache cells prefer gather_q."""
    if ctx.attn_mode != "auto":
        return ctx.attn_mode
    B, Tq, H, hdk = q.shape
    Hkv = k_loc.shape[2]
    kv_len = kv_view if kv_view is not None else k_loc.shape[1]
    kv_bytes = 2 * kv_len * Hkv * k_loc.shape[-1] * 2
    q_bytes = Tq * H * hdk * (2 + 4)  # q bf16 out f32 (per merge step)
    return "gather_kv" if kv_bytes < q_bytes else "gather_q"


def dist_attention(q, k_loc, v_loc, q_pos, kv_pos, ctx: Ctx, *, causal=True,
                   scale=None, kv_view: Optional[int] = None, q_start=None):
    """q: [B, Tq_loc, H, hd] this rank's query shard (all heads).
    k_loc/v_loc/kv_pos: the local KV shard (cache view).
    kv_view: static number of leading cache slots to attend over (compile-time
    truncation for chunked training; None = full buffer).
    q_start: optional [B, Tq_loc] int32 segment window for packed batches —
    each query sees only kv slots with kv_pos >= its document start, so
    packed documents never attend across boundaries (PAD on padding rows).
    Returns the attention output for this rank's query shard
    [B, Tq_loc, H, hd_v].
    """
    if kv_view is not None:
        k_loc, v_loc, kv_pos = (k_loc[:, :kv_view], v_loc[:, :kv_view],
                                kv_pos[:kv_view])
    mode = _pick_mode(ctx, q, k_loc, kv_view)
    if mode == "ring" and ctx.sp > 1:
        # rotate the KV shard around the model axis (DESIGN.md §15): no
        # device ever materializes more than two KV blocks, so the chunk's
        # visible extent is no longer bounded by one stage's HBM.  q, q_pos
        # and q_start are query-side and stay local.
        from repro.parallel import ring as _ring
        return _ring.ring_attention(q, k_loc, v_loc, q_pos, kv_pos, ctx,
                                    causal=causal, scale=scale,
                                    q_start=q_start)
    if mode == "gather_kv" and ctx.sp > 1:
        # gather the (narrow, GQA) KV shard; attention is then fully local
        # to this rank's query rows — zero merge collectives.  q_start is
        # query-side, so the local shard passes straight through.
        k_full = ctx.all_gather_model(k_loc, axis=1)
        v_full = ctx.all_gather_model(v_loc, axis=1)
        kp_full = ctx.all_gather_model(kv_pos, axis=0)
        qp = q_pos if q_pos.ndim == 1 else q_pos[0]
        o, m, l = kops.attention_partial(q, k_full, v_full, qp, kp_full,
                                         causal=causal, scale=scale,
                                         q_start=q_start)
        out = o / jnp.maximum(l, 1e-30)[..., None]
        return out.astype(q.dtype)

    q_full = ctx.all_gather_model(q, axis=1)
    if q_pos.ndim == 1:
        qp_full = ctx.all_gather_model(q_pos, axis=0)
    else:
        qp_full = ctx.all_gather_model(q_pos, axis=1)
    qs_full = (None if q_start is None
               else ctx.all_gather_model(q_start, axis=1))
    o, m, l = kops.attention_partial(q_full, k_loc, v_loc, qp_full, kv_pos,
                                     causal=causal, scale=scale,
                                     q_start=qs_full)
    # cross-shard softmax merge; scatter back to this rank's query rows.
    # max stats are gradient-frozen (see kernels/ref.py).
    m = jax.lax.stop_gradient(m)
    m_g = jax.lax.stop_gradient(ctx.pmax_model(m))            # [B, Tq, H]
    alpha = jnp.exp(m - m_g)
    o_s = o * alpha[..., None]
    if ctx.merge_bf16:
        o_s = o_s.astype(jnp.bfloat16)
    o = ctx.reduce_scatter_model(o_s, axis=1).astype(jnp.float32)
    l = ctx.reduce_scatter_model(l * alpha, axis=1)
    out = o / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA self-attention block (dense / vlm self / zamba shared / whisper)
# ---------------------------------------------------------------------------


def gqa_self_attention(x, p, cfg, ctx: Ctx, cache: KVCache, q_pos,
                       cache_offset, kv_view, *, name_tag=None,
                       q_start=None):
    """x: [B, T_loc, d]; returns (attn_out [B, T_loc, d], new cache).

    q_pos: [T_loc] global positions of this rank's tokens in the chunk.
    cache_offset: local cache slot where this chunk's shard is written.
    kv_view: static visible cache length after the append.
    q_start: optional [B, T_loc] packed-document window (see dist_attention).
    """
    B, Tl, _ = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, Tl, H, hd)
    k = k.reshape(B, Tl, Hkv, hd)
    v = v.reshape(B, Tl, Hkv, hd)
    if cfg.rope:
        q = L.apply_rope(q, q_pos, cfg.rope_theta, cfg.rope_fraction)
        k = L.apply_rope(k, q_pos, cfg.rope_theta, cfg.rope_fraction)
    if name_tag is not None:
        q, k, v = name_tag(q), name_tag(k), name_tag(v)
    cache = cache_append(cache, k, v, q_pos, cache_offset)
    out = dist_attention(q, cache.k, cache.v, q_pos, cache.pos, ctx,
                         causal=True, kv_view=kv_view, q_start=q_start)
    out = out.reshape(B, Tl, H * hd)
    if name_tag is not None:
        out = name_tag(out)
    y = out @ p["wo"]
    return y, cache


def gqa_decode_attention(x, p, cfg, ctx: Ctx, cache: KVCache, step_pos,
                         my_slot):
    """Single-token decode. x: [B_loc, 1, d]; step_pos: [] int32 global pos.

    Cache layout is striped: token t lives on rank (t % sp) at slot (t // sp).
    `my_slot` is this rank's write slot or -1 (no write this step) — computed
    by the caller from step_pos and the rank index.
    """
    B = x.shape[0]
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"])
    k = (x @ p["wk"])
    v = (x @ p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, 1, H, hd)
    k = k.reshape(B, 1, Hkv, hd)
    v = v.reshape(B, 1, Hkv, hd)
    pos_arr = jnp.full((1,), step_pos, jnp.int32)
    if cfg.rope:
        q = L.apply_rope(q, pos_arr, cfg.rope_theta, cfg.rope_fraction)
        k = L.apply_rope(k, pos_arr, cfg.rope_theta, cfg.rope_fraction)
    # conditional striped write: write at my_slot if it's mine, else write a
    # PAD entry into a scratch tail slot (slot S_loc-1 reserved... instead we
    # mask by writing the same values but position PAD, which the kernel
    # ignores). Simpler: select on position tag only.
    slot = jnp.maximum(my_slot, 0)
    mine = my_slot >= 0
    new_pos = jnp.where(mine, step_pos, cache.pos[slot])
    k_old = jax.lax.dynamic_slice(cache.k, (0, slot, 0, 0),
                                  (B, 1, Hkv, hd))
    v_old = jax.lax.dynamic_slice(cache.v, (0, slot, 0, 0),
                                  (B, 1, Hkv, hd))
    k_w = jnp.where(mine, k.astype(cache.k.dtype), k_old)
    v_w = jnp.where(mine, v.astype(cache.v.dtype), v_old)
    cache = cache_append(cache, k_w, v_w, new_pos[None], slot)
    # q is identical on every model rank (x replicated for decode), so no
    # gather: run the partial kernel directly and merge.
    o, m, l = kops.attention_partial(q, cache.k, cache.v, pos_arr, cache.pos,
                                     causal=True)
    m = jax.lax.stop_gradient(m)
    m_g = jax.lax.stop_gradient(ctx.pmax_model(m))
    alpha = jnp.exp(m - m_g)
    o = ctx.psum_model(o * alpha[..., None])
    l = ctx.psum_model(l * alpha)
    out = (o / jnp.maximum(l, 1e-30)[..., None]).astype(x.dtype)
    y = out.reshape(B, 1, H * hd) @ p["wo"]
    return y, cache


class PooledKV(NamedTuple):
    """Paged KV pool (one layer, one rank): physical block storage shared by
    every request slot through a block table (runtime/kvpool.py).  Unlike
    KVCache there is no batch dim and no position array — logical slot j has
    the static per-rank position ``pos_map[j]`` for every request."""

    k: jax.Array        # [P_loc, Hkv, hd]
    v: jax.Array        # [P_loc, Hkv, hd]


class PagedMeta(NamedTuple):
    """Per-step paged-decode metadata (ChunkMeta.paged).

    q_pos is per-request: slot b feeds its token at global position q_pos[b]
    (0 marks an inactive slot — its write is dropped and its output is
    discarded by the scheduler).  btab maps logical blocks to physical pool
    blocks (-1 = unallocated; such slots are causally masked because their
    pos_map position exceeds the request's horizon).  base / s_bucket /
    block_tokens are static geometry (PoolGeometry).
    """

    q_pos: Any          # [B] int32 per-request global feed position
    btab: Any           # [B, max_blocks] int32 block table
    pos_map: Any        # [L_loc] int32 static positions of logical slots
    base: int           # prefill logical slots per rank (static)
    s_bucket: int       # padded prompt bucket length (static)
    block_tokens: int   # logical slots per block (static)


def gqa_paged_decode_attention(x, p, cfg, ctx: Ctx, pool: PooledKV,
                               pg: PagedMeta):
    """Single-token decode against the paged pool. x: [B, 1, d].

    Every request slot carries its *own* position (pg.q_pos), so rows at
    different decode depths batch together.  The write is striped like the
    static path — decode token d lives on rank (d % sp) at logical slot
    (base + d // sp) — routed through the block table to a physical slot;
    non-owning ranks and inactive slots write to an out-of-bounds sentinel
    that scatter-drops (never -1: jnp wraps negative indices).
    """
    B = x.shape[0]
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, 1, H, hd)
    k = k.reshape(B, 1, Hkv, hd)
    v = v.reshape(B, 1, Hkv, hd)
    qpos = pg.q_pos[:, None]                     # [B, 1] per-row positions
    if cfg.rope:
        q = L.apply_rope(q, qpos, cfg.rope_theta, cfg.rope_fraction)
        k = L.apply_rope(k, qpos, cfg.rope_theta, cfg.rope_fraction)

    sp, rank = ctx.sp, ctx.model_index()
    bt = pg.block_tokens
    p_loc = pool.k.shape[0]
    l_loc = pg.pos_map.shape[0]
    d = pg.q_pos - pg.s_bucket                   # [B] decode index (<0: none)
    mine = (d >= 0) & (d % sp == rank)
    j_w = jnp.clip(pg.base + d // sp, 0, l_loc - 1)
    blk = jnp.take_along_axis(pg.btab, (j_w // bt)[:, None], axis=1)[:, 0]
    phys_w = jnp.where(mine & (blk >= 0), blk * bt + j_w % bt, p_loc)
    pool = PooledKV(
        k=pool.k.at[phys_w].set(k[:, 0].astype(pool.k.dtype), mode="drop"),
        v=pool.v.at[phys_w].set(v[:, 0].astype(pool.v.dtype), mode="drop"))

    # per-request gather in logical-slot order: identical kv ordering to the
    # static cache, so a solo request decodes bit-identically to the static
    # lock-step loop regardless of which physical blocks it landed in
    jlog = jnp.arange(l_loc)
    blk_g = pg.btab[:, jlog // bt]               # [B, L_loc]
    phys_g = jnp.clip(blk_g, 0) * bt + jlog % bt
    k_g = pool.k[phys_g]                         # [B, L_loc, Hkv, hd]
    v_g = pool.v[phys_g]
    o, m, l = kops.attention_partial(q, k_g, v_g, qpos, pg.pos_map,
                                     causal=True)
    m = jax.lax.stop_gradient(m)
    m_g = jax.lax.stop_gradient(ctx.pmax_model(m))
    alpha = jnp.exp(m - m_g)
    o = ctx.psum_model(o * alpha[..., None])
    l = ctx.psum_model(l * alpha)
    out = (o / jnp.maximum(l, 1e-30)[..., None]).astype(x.dtype)
    y = out.reshape(B, 1, H * hd) @ p["wo"]
    return y, pool


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3 multi-head latent attention), absorbed form
# ---------------------------------------------------------------------------


def mla_attention(x, p, cfg, ctx: Ctx, cache: KVCache, q_pos, cache_offset,
                  kv_view, *, name_tag=None, decode=False, my_slot=None,
                  q_start=None):
    """Multi-head latent attention.  The cache stores the compressed latent
    kv = [c_kv (kv_lora) | k_rope (rope_hd)] per token — MLA's memory edge.
    Scores use the absorbed form: q_eff = [q_nope @ W_uk | q_rope], shared
    single KV "head"; values are the latent, up-projected after attention.
    """
    mla = cfg.mla
    B, Tl, _ = x.shape
    H = cfg.n_heads
    dn, dr, dv, dc = mla.nope_head_dim, mla.rope_head_dim, mla.v_head_dim, mla.kv_lora_rank

    # --- queries (LoRA down/up), rope/nope split
    cq = L.rms_norm(x @ p["wq_a"], p["q_norm"])           # [B,T,q_lora]
    q = (cq @ p["wq_b"]).reshape(B, Tl, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    # --- latent kv
    ckv_full = x @ p["wkv_a"]                              # [B,T,dc+dr]
    c_kv = L.rms_norm(ckv_full[..., :dc], p["kv_norm"])
    k_rope = ckv_full[..., None, dc:]                      # [B,T,1,dr]
    pos_arr = q_pos if q_pos.ndim == 1 else q_pos[0]
    q_rope = L.apply_rope(q_rope, q_pos, cfg.rope_theta)
    k_rope = L.apply_rope(k_rope, q_pos, cfg.rope_theta)
    # absorbed q: [B,T,H,dn] @ [H,dn,dc] -> [B,T,H,dc]
    q_abs = jnp.einsum("bthn,hnc->bthc", q_nope, p["w_uk"])
    q_eff = jnp.concatenate([q_abs, q_rope], axis=-1)      # [B,T,H,dc+dr]
    k_eff = jnp.concatenate([c_kv[:, :, None, :], k_rope], axis=-1)
    if name_tag is not None:
        q_eff, k_eff = name_tag(q_eff), name_tag(k_eff)
    scale = 1.0 / ((dn + dr) ** 0.5)

    if decode:
        slot = jnp.maximum(my_slot, 0)
        mine = my_slot >= 0
        new_pos = jnp.where(mine, pos_arr[0], cache.pos[slot])
        k_old = jax.lax.dynamic_slice(cache.k, (0, slot, 0, 0),
                                      (B, 1, 1, dc + dr))
        k_w = jnp.where(mine, k_eff.astype(cache.k.dtype), k_old)
        cache = KVCache(
            k=jax.lax.dynamic_update_slice(cache.k, k_w, (0, slot, 0, 0)),
            v=cache.v,
            pos=jax.lax.dynamic_update_slice(cache.pos, new_pos[None], (slot,)))
        kv = cache.k
        o, m, l = kops.attention_partial(q_eff, kv, kv[..., :dc], pos_arr,
                                         cache.pos, causal=True, scale=scale)
        m = jax.lax.stop_gradient(m)
        m_g = jax.lax.stop_gradient(ctx.pmax_model(m))
        alpha = jnp.exp(m - m_g)
        o = ctx.psum_model(o * alpha[..., None])
        l = ctx.psum_model(l * alpha)
        out = (o / jnp.maximum(l, 1e-30)[..., None]).astype(x.dtype)
    else:
        cache = KVCache(
            k=jax.lax.dynamic_update_slice(
                cache.k, k_eff.astype(cache.k.dtype),
                (jnp.int32(0), jnp.asarray(cache_offset, jnp.int32),
                 jnp.int32(0), jnp.int32(0))),
            v=cache.v,
            pos=jax.lax.dynamic_update_slice(
                cache.pos, pos_arr.astype(jnp.int32),
                (jnp.asarray(cache_offset, jnp.int32),)))
        kv = cache.k[:, :kv_view]
        out = dist_attention(q_eff, kv, kv[..., :dc], q_pos,
                             cache.pos[:kv_view], ctx, causal=True,
                             scale=scale, q_start=q_start)
    # up-project latent values per head then output proj
    o_v = jnp.einsum("bthc,hcv->bthv", out, p["w_uv"])     # [B,T,H,dv]
    if name_tag is not None:
        o_v = name_tag(o_v)
    y = o_v.reshape(B, Tl, H * dv) @ p["wo"]
    return y, cache


# ---------------------------------------------------------------------------
# Cross-attention (vlm image layers / whisper decoder) — chunk-invariant KV
# ---------------------------------------------------------------------------


def cross_attention(x, p, cfg, ctx: Ctx, xkv, *, name_tag=None):
    """x: [B, T_loc, d]; xkv: precomputed context KV
    (k [B, Nctx_loc, Hkv, hd], v ..., pos [Nctx_loc]) sharded over `model`.
    Bidirectional over the context (causal=False)."""
    B, Tl, _ = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(B, Tl, H, hd)
    if name_tag is not None:
        q = name_tag(q)
    q_pos = jnp.zeros((Tl,), jnp.int32)  # positions unused when causal=False
    out = dist_attention(q, xkv["k"], xkv["v"], q_pos, xkv["pos"], ctx,
                         causal=False)
    out = out.reshape(B, Tl, H * hd)
    if name_tag is not None:
        out = name_tag(out)
    return out @ p["wo"]


def make_cross_kv(context, p, cfg, ctx: Ctx, n_valid: int):
    """context: [B, Nctx_loc, d] sequence-sharded stub embeddings.
    n_valid: global count of real (non-padded) context tokens."""
    B, Nl, _ = context.shape
    Hkv, hd = cfg.n_kv_heads, cfg.hd
    k = (context @ p["wk"]).reshape(B, Nl, Hkv, hd)
    v = (context @ p["wv"]).reshape(B, Nl, Hkv, hd)
    gidx = ctx.model_index() * Nl + jnp.arange(Nl, dtype=jnp.int32)
    pos = jnp.where(gidx < n_valid, gidx, PAD)
    return {"k": k, "v": v, "pos": pos}
