"""The distributed execution engine: SPPO pipeline inside shard_map.

Builds the three step functions per (arch x shape x mesh) cell:

  train_step(params, opt_state, batch)  -> (params', opt_state', metrics)
  prefill_step(params, batch)           -> (caches, last_hidden)
  serve_step(params, caches, batch)     -> (caches', next_tokens)

Everything distributed runs in one ``shard_map`` over the production mesh;
the optimizer applies outside shard_map on the global (sharded) arrays so
moment host-offload / ZeRO-1 shardings are plain GSPMD annotations.

Pipeline semantics (DESIGN.md §2/§4): at tick t, stage s = data_idx % pp
processes chunk c = t − s; hand-off by ppermute along the data axis within
dp groups; the backward pipeline comes from differentiating the tick loop.
pp == 1 uses exact FLOPs-balanced variable-length chunks with per-chunk
offload ratios; pp > 1 uses equal chunks (lock-step SPMD) with tick-aligned
ratios.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelPlan, ShapeConfig
from repro.core import costmodel as cm
from repro.core import mutation
from repro.core import offload as ofl
from repro.core import partition as part
from repro.core import schedule as sched_mod
from repro.core import simulate as sim_mod
from repro.models import attention as A
from repro.models.model_zoo import ModelDef, build_model
from repro.models.transformer import ChunkMeta
from repro.parallel import specs as SP
from repro.parallel.ctx import Ctx
from repro.parallel.plans import resolve_plan

try:  # jax >= 0.8
    from jax import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
except (ImportError, TypeError):  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _sm

    def shard_map(f, mesh, in_specs, out_specs):
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)


DECODE_BUDGET = 128  # extra decode slots beyond the shape's cache length


# ---------------------------------------------------------------------------
# Cell: one fully-resolved (arch x shape x mesh) configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Cell:
    mdef: ModelDef
    plan: ParallelPlan
    shape: ShapeConfig
    pods: int
    data_size: int
    model_size: int
    sched: part.ChunkSchedule
    alphas: tuple
    dtype: Any = jnp.bfloat16
    # document lengths of the packed variable-length batch (empty = the
    # classic uniform layout).  When set, the batch carries a ``doc_start``
    # array and the attention path masks cross-document visibility
    # (DESIGN.md §13).
    doc_lens: tuple = ()

    @property
    def cfg(self) -> ModelConfig:
        return self.mdef.cfg

    @property
    def varlen(self) -> bool:
        return bool(self.doc_lens)

    @property
    def b_loc(self) -> int:
        return max(1, self.shape.global_batch // (self.pods * self.plan.dp))

    @property
    def cache_loc(self) -> int:
        s = self.shape.seq_len
        # prefill leaves room for subsequent decode appends (same geometry,
        # so a prefill cache feeds serve_step directly)
        extra = (DECODE_BUDGET * self.plan.sp
                 if self.shape.kind in ("decode", "prefill") else 0)
        return (s + extra) // self.plan.sp

    def ctx(self) -> Ctx:
        return Ctx(model_axis="model", data_axis="data",
                   pod_axis="pod" if self.pods > 1 else None,
                   sp=self.plan.sp, dp=self.plan.dp, pp=self.plan.pp,
                   pods=self.pods,
                   attn_mode=self.plan.attn_mode,
                   merge_bf16=self.plan.merge_bf16,
                   grad_compress=self.plan.grad_compress)


def resolve_cell(arch, shape_cfg: ShapeConfig, *, data_size=16, model_size=16,
                 pods=1, overrides=None, hw=cm.V5E, doc_lens=None) -> Cell:
    mdef = arch if isinstance(arch, ModelDef) else build_model(arch)
    cfg = mdef.cfg
    plan = resolve_plan(cfg, shape_cfg, data_size=data_size,
                        model_size=model_size, pods=pods, overrides=overrides)
    n = plan.n_chunks
    doc_lens = tuple(int(x) for x in
                     (doc_lens if doc_lens is not None else ()))
    if shape_cfg.kind == "decode":
        assert not doc_lens, "packed varlen layouts are train/prefill-only"
        # decode has no backward pass: there is no reload window to hide a
        # transfer under, so an offloaded residual could only ever be paid
        # for, never redeemed.  resolve_plan pins offload off for decode
        # shapes; reject overrides that try to turn it back on.
        assert not plan.offload, (
            "decode plans must not offload: a decode step has no backward, "
            "so offloaded activations are never reloaded (DESIGN.md §4)")
        # compressed residency rides the offload channels; with offload
        # pinned off on decode a codec could only quantize tensors that are
        # never offloaded in the first place — reject it as a config error
        # rather than silently ignoring the knob (DESIGN.md §14)
        assert plan.offload_dtype == "none" and plan.moments_dtype == "none", (
            "decode plans must not request compressed residency: with "
            "offload disabled there is no host channel to compress "
            f"(offload_dtype={plan.offload_dtype!r}, "
            f"moments_dtype={plan.moments_dtype!r})")
        sched = part.ChunkSchedule((1,), (0,), 1, "decode")
        alphas = (0.0,)
    else:
        mult = max(model_size, 128) if plan.pp == 1 else model_size
        policy = plan.partition if plan.pp == 1 else "length"
        r = part.flops_per_token_ratio(cfg)
        profile = None
        if doc_lens:
            # histogram-driven packed layout: the cost profile sums the
            # per-row causal sawtooth (cost restarts at every document
            # boundary) over the whole global batch, so chunk boundaries
            # and offload ratios below see the *actual* token/FLOPs mix.
            rows = part.pack_lengths(list(doc_lens), shape_cfg.seq_len)
            row_lens = [[doc_lens[i] for i in row] for row in rows]
            assert len(row_lens) <= shape_cfg.global_batch, (
                f"packing needs {len(row_lens)} rows > global_batch "
                f"{shape_cfg.global_batch}")
            # filler rows up to the global batch are all-padding but still
            # ride the dense matmuls: linear-only cost
            row_lens += [[] for _ in
                         range(shape_cfg.global_batch - len(row_lens))]
            profile = part.packed_cost_profile(row_lens, shape_cfg.seq_len, r)
        if plan.pp > 1:
            assert shape_cfg.seq_len % (n * model_size) == 0
            if plan.msp:
                # ramp sub-chunk loss regions must tile the chunk evenly
                assert (shape_cfg.seq_len // n) % plan.msp_split == 0, (
                    f"chunk len {shape_cfg.seq_len // n} not divisible by "
                    f"msp_split {plan.msp_split}")
                # sub-events recompute their full chunk; that is idempotent
                # for the position-tagged KV cache but NOT for SSM/RWKV
                # recurrent state, which would be advanced `split` times
                # (DESIGN.md §2) — reject stateful-recurrence families
                assert not cfg.sub_quadratic, (
                    f"msp unsupported for family {cfg.family!r}: recurrent "
                    "state updates are not idempotent under full-chunk "
                    "recompute")
            sched = part.partition_length(shape_cfg.seq_len, n)
        elif profile is not None and policy == "flops":
            # Seq1F1B-style FLOPs balance over the packed profile, snapping
            # to aligned document boundaries where one is nearby
            sched = part.partition_profile(
                profile, n, multiple=mult,
                doc_bounds=part.aligned_doc_bounds(row_lens,
                                                   shape_cfg.seq_len))
        else:
            sched = part.partition(shape_cfg.seq_len, n, cfg, policy,
                                   multiple=mult)
        # sequence-aware offload ratios from the cost model (§5.2); packed
        # cells use the measured per-chunk profile sums (already summed over
        # the batch rows), uniform cells the analytic single-sequence costs
        n_params = SP.count_active_params(mdef, plan.pp, data_size)
        if profile is not None:
            costs = [c / max(1, shape_cfg.global_batch)
                     for c in part.profile_chunk_costs(profile, sched)]
        else:
            costs = part.chunk_costs(sched, r)
        scale = (6 * n_params * shape_cfg.global_batch * shape_cfg.seq_len
                 / sum(costs) / (plan.sp * plan.pp * hw.peak_flops_bf16))
        # the §5.2 hiding window is the next chunk's *forward* compute —
        # the same fwd/bwd split the solver plans with (cm.BWD_RATIO); the
        # two sides still differ in launch-overhead and grad-accum terms
        times = [c * scale / (1.0 + cm.BWD_RATIO) for c in costs]
        b_loc = max(1, shape_cfg.global_batch // (pods * plan.dp))
        acts = cm.chunk_act_bytes(cfg, sched.lengths, batch=b_loc,
                                  pp=plan.pp, sp=plan.sp,
                                  grad_accum=plan.grad_accum)
        # compressed residency crosses the link at wire_ratio·A bytes per
        # offloaded row-set, so the α solver sees the effective raw-bytes
        # link rate and can offload more per hiding window (DESIGN.md §14)
        bw_eff = hw.d2h_bw / cm.offload_wire_ratio(plan.offload_dtype)
        alphas = ofl.sequence_aware_alphas(acts, times, bw_eff).alphas
        if not plan.offload:
            alphas = tuple(0.0 for _ in alphas)
    return Cell(mdef=mdef, plan=plan, shape=shape_cfg, pods=pods,
                data_size=data_size, model_size=model_size,
                sched=sched, alphas=alphas, doc_lens=doc_lens)


# ---------------------------------------------------------------------------
# The pipeline forward (shared by train loss / prefill)
# ---------------------------------------------------------------------------


def _squeeze_lead(tree, n: int):
    return jax.tree_util.tree_map(
        lambda a: a.reshape(a.shape[n:]), tree)


def chunk_tag(cell: Cell, chunk: int, *, suffix: str, train: bool):
    """(tag, names) for one tick/chunk of the pipeline loops.

    Executed offloading (plan.offload_mode == 'explicit', DESIGN.md §10)
    routes the act_off rows through host memory inside the differentiated
    train loops; prefill has no backward — nothing is ever reloaded — so it
    keeps the plain named tags.  The names are suffix-qualified so the
    memledger can attribute each tick's saved bytes from the traced jaxpr."""
    names = ofl.chunk_names(suffix)
    alpha = cell.alphas[chunk]
    plan = cell.plan
    if train and plan.offload and plan.offload_mode == "explicit":
        return ofl.make_exec_tag(alpha, names=names,
                                 codec=plan.offload_dtype), names
    return ofl.make_tag(alpha, names=names), names


def use_ahead_prefetch(plan: ParallelPlan, *, train: bool) -> bool:
    """Whether a loop iteration goes through the prefetch='ahead' seam
    (DESIGN.md §12): only the differentiated explicit-offload path has a
    backward reload to place — prefill/decode and the remat ablations keep
    their existing structure."""
    return (train and plan.offload and plan.offload_mode == "explicit"
            and plan.remat == "sppo" and plan.prefetch == "ahead")


def prefetch_chunk(cell: Cell, ctx: Ctx, *, alpha: float, names: tuple,
                   q_pos, cache_off, kv_view: int, q_start=None):
    """The prefetch='ahead' seam for one tick/chunk (DESIGN.md §12).

    Returns ``run(stage_p, g, state, x, link_in) -> (y, state', aux,
    link_out)`` — a ``jax.custom_vjp`` above the per-slot ``jax.checkpoint``:

    * **forward** runs the chunk with the capture tag and saves the
      *host-resident* off-row residuals (one D2H per tag site over the
      slot-stacked rows, carrying the tick-qualified ``act_off`` name the
      memledger counts) plus the device-resident keep rows.  The host set
      is returned as ``link_out`` — a handle threaded to the *next*
      chunk's seam, never consumed by forward math.
    * **backward** receives its own staged reloads as the cotangent of
      ``link_out`` (issued by the next chunk's backward, i.e. one event
      ahead), issues the H2D for the *previous* chunk's ``link_in`` — a
      dataflow-independent copy XLA can overlap with this chunk's backward
      compute — and replays the chunk through the inject tag over the
      staged residuals.  The single in-flight link cotangent is the
      one-slot staging buffer that keeps the backward peak bounded by the
      forward peak (the simulator's memory-mirror rule, §3.2)."""
    from repro.runtime import hostmem

    mdef = cell.mdef
    off_name, keep_name = names
    codec = cell.plan.offload_dtype
    kind = hostmem.resolve_host_kind("auto")
    meta = ChunkMeta(q_pos=q_pos, cache_off=cache_off, kv_view=kv_view,
                     tag=None, names=names, q_start=q_start)

    def capture(stage_p, g, state, x):
        y, s2, aux, off_acts, keep_acts, scales = mdef.stage_apply_capture(
            stage_p, state, x, ctx, meta, g, alpha=alpha,
            offload_dtype=codec)
        # Compressed residency (DESIGN.md §14): the captured off rows are
        # already the codec's wire payloads; int8 crosses the link bitcast
        # into an fp8 byte container because the reloads ride custom_vjp
        # *cotangents* (integer outputs have float0 tangents — nothing to
        # carry the bytes).  Same byte count either way, so the ledger's
        # act_off accounting is unchanged by the transport view.
        off_host = tuple(
            checkpoint_name(hostmem.to_host(hostmem.to_transport(t, codec),
                                            kind), off_name)
            for t in off_acts)
        if mutation.active("double-d2h"):
            off_host = tuple(hostmem.to_host(t, kind) for t in off_host)
        keep_dev = tuple(checkpoint_name(t, keep_name) for t in keep_acts)
        if mutation.active("scale-offloaded"):
            scales = tuple(hostmem.to_host(s, kind) for s in scales)
        if mutation.active("unnamed-scale"):
            scale_dev = tuple(scales)
        else:
            scale_dev = tuple(
                checkpoint_name(s, ofl.scale_name_for(off_name))
                for s in scales)
        return y, s2, aux, off_host, keep_dev, scale_dev

    @jax.custom_vjp
    def run(stage_p, g, state, x, link_in):
        y, s2, aux, off_host, _, _ = capture(stage_p, g, state, x)
        return y, s2, aux, off_host

    def run_fwd(stage_p, g, state, x, link_in):
        y, s2, aux, off_host, keep_dev, scale_dev = capture(stage_p, g,
                                                            state, x)
        return ((y, s2, aux, off_host),
                (stage_p, g, state, x, link_in, keep_dev, scale_dev))

    def run_bwd(res, cts):
        stage_p, g, state, x, link_in, keep_dev, scale_dev = res
        ct_y, ct_s2, ct_aux, staged_off = cts
        # one-chunk-ahead H2D: reload the *previous* chunk's host residuals
        # now; the copy has no data dependency on this chunk's backward
        # compute below, so it overlaps it, and the result rides the link
        # cotangent to the previous chunk's seam.  Reloads stay in wire
        # form across the link — dequantization belongs to the chunk that
        # owns the scales (its own backward, below).
        staged_prev = jax.tree_util.tree_map(
            lambda t: hostmem.to_device(t, kind), link_in)
        staged_off = tuple(hostmem.from_transport(t, codec)
                           for t in staged_off)

        def replay(stage_p, g, state, x):
            return mdef.stage_apply_inject(
                stage_p, state, x, ctx, meta, g, alpha=alpha,
                off_acts=staged_off, keep_acts=keep_dev,
                offload_dtype=codec, scales=scale_dev)

        _, vjp = jax.vjp(replay, stage_p, g, state, x)
        gp, gg, gs, gx = vjp((ct_y, ct_s2, ct_aux))
        return gp, gg, gs, gx, staged_prev

    run.defvjp(run_fwd, run_bwd)
    return run


def link_drain(y, link):
    """Terminal consumer of the last chunk's link: identity on `y`, with a
    hand-written backward that issues the final (first-to-run) H2D as soon
    as the backward pass reaches `y`'s cotangent — the seam's hand-off for
    the chunk with no later backward to hide under (why reserve_last pins
    its α to 0, core/offload.py)."""
    if not link:
        return y
    from repro.runtime import hostmem

    kind = hostmem.resolve_host_kind("auto")

    @jax.custom_vjp
    def attach(y, link):
        return y

    def attach_fwd(y, link):
        return y, link

    def attach_bwd(link_res, ct_y):
        staged = jax.tree_util.tree_map(
            lambda t: hostmem.to_device(t, kind), link_res)
        return ct_y, staged

    attach.defvjp(attach_fwd, attach_bwd)
    return attach(y, link)


def pipeline_feed_events(plan: ParallelPlan, n_chunks: int):
    """The (chunk, sub, n_sub) feed sequence the pp>1 tick loop executes.

    This is the runner's side of the runner-vs-simulator contract: the
    event-driven simulator (core/simulate.py) plays out exactly this
    sequence, and tests assert the two agree (DESIGN.md §2/§3)."""
    if plan.msp and plan.pp > 1:
        return sched_mod.msp_ramp_schedule(n_chunks, plan.pp, plan.msp_split)
    return sim_mod.plain_events(n_chunks)


def pipeline_tick_trace(cell: Cell):
    """Static per-tick trace of the pp>1 loop: one dict per tick with the
    feed event entering stage 0 and the drain event leaving stage pp−1."""
    plan = cell.plan
    events = pipeline_feed_events(plan, cell.sched.n)
    n_ticks = len(events) + plan.pp - 1
    trace = []
    for t in range(n_ticks):
        feed = events[t] if t < len(events) else None
        e_last = t - (plan.pp - 1)
        drain = events[e_last] if 0 <= e_last < len(events) else None
        trace.append(dict(tick=t, feed=feed, drain=drain))
    return trace


def run_pipeline(cell: Cell, ctx: Ctx, stage_p, g, tokens, labels, context,
                 *, with_loss: bool, collect_state: bool = False,
                 ledger=None, doc_start=None):
    """tokens/labels: [B_loc, S] local; context: [B_loc, Nctx_loc, d] or None.

    doc_start: optional [B_loc, S] int32 — global start position of the
    document containing each token (PAD_START on padding) for packed
    variable-length batches; threaded to attention as the per-query segment
    window so packed documents never attend across boundaries.  Loss tokens
    are selected by the label sentinel (labels < 0 carry zero weight).

    ledger: optional runtime.memledger.MemLedger — inserts per-tick probes
    (fwd/bwd wall-clock + execution order) on the compute path.

    Returns dict(loss_sum, denom, aux, state, last_x)."""
    mdef, cfg, plan = cell.mdef, cell.cfg, cell.plan
    sp, pp = plan.sp, plan.pp
    N = cell.sched.n
    S = cell.shape.seq_len
    B = tokens.shape[0]
    d = cfg.d_model

    ctxt = None
    if cfg.encoder_layers:
        ctxt = mdef.encode(g, context, ctx)
    elif cfg.cross_attn is not None:
        ctxt = context
    state = mdef.init_state(stage_p, g, ctx, B, cell.cache_loc, cell.dtype,
                            context=ctxt)
    rank = ctx.model_index()
    stage = ctx.stage_index()
    loss_acc = jnp.float32(0.0)
    den_acc = jnp.float32(0.0)
    aux_acc = jnp.float32(0.0)

    def chunk_positions(off, lloc):
        return off + rank * lloc + jnp.arange(lloc, dtype=jnp.int32)

    if pp == 1:
        x_last = None
        ahead = use_ahead_prefetch(plan, train=with_loss)
        link = ()
        for c in range(N):
            off, ln = cell.sched.offsets[c], cell.sched.lengths[c]
            lloc = ln // sp
            ids = jax.lax.slice_in_dim(tokens, off, off + ln, axis=1)
            q_pos = chunk_positions(off, lloc)
            ds_loc = None
            if doc_start is not None:
                # local shard of the chunk's segment window: embed's
                # reduce-scatter makes the local rows the rank's contiguous
                # [off + rank*lloc, off + (rank+1)*lloc) slice, so slice the
                # per-token doc_start the same way
                ds_chunk = jax.lax.slice_in_dim(doc_start, off, off + ln,
                                                axis=1)
                ds_loc = jax.lax.dynamic_slice_in_dim(
                    ds_chunk, rank * lloc, lloc, axis=1)
            x = mdef.embed(g, ids, q_pos, ctx)
            if ahead:
                run = prefetch_chunk(cell, ctx, alpha=cell.alphas[c],
                                     names=ofl.chunk_names(f"@c{c}"),
                                     q_pos=q_pos, cache_off=off // sp,
                                     kv_view=(off + ln) // sp,
                                     q_start=ds_loc)
                x, state, aux, link = run(stage_p, g, state, x, link)
            else:
                tag, names = chunk_tag(cell, c, suffix=f"@c{c}",
                                       train=with_loss)
                meta = ChunkMeta(q_pos=q_pos, cache_off=off // sp,
                                 kv_view=(off + ln) // sp,
                                 tag=tag, names=names, q_start=ds_loc)
                x, state, aux = mdef.stage_apply(
                    stage_p, state, x, ctx, meta, g,
                    offload=plan.offload, remat=plan.remat,
                    offload_mode=plan.offload_mode,
                    offload_dtype=plan.offload_dtype if with_loss else "none")
            if ledger is not None:
                from repro.runtime import memledger as _ml
                x = _ml.tick_probe(x, ledger, c)
            aux_acc = aux_acc + aux
            if with_loss:
                lab = jax.lax.slice_in_dim(labels, off, off + ln, axis=1)
                # the label sentinel (<0) zero-weights padding and each
                # document's last token; uniform batches have no sentinel
                # labels, so this is the same all-ones weighting as before
                wts = (lab >= 0).astype(jnp.float32)
                ls, cnt = mdef.head_loss(g, x, lab, wts, ctx)
                loss_acc, den_acc = loss_acc + ls, den_acc + cnt
            x_last = x
        loss_acc = link_drain(loss_acc, link)
        return dict(loss=loss_acc, denom=den_acc, aux=aux_acc, state=state,
                    last_x=x_last)

    # ---- pp > 1: lock-step tick pipeline -----------------------------------
    # The tick loop executes the feed-event schedule (plain, or the MSP ramp
    # when plan.msp): at tick t, stage s handles event t−s.  An MSP sub-event
    # recomputes its *full* chunk (lock-step SPMD needs uniform shapes —
    # DESIGN.md §2); the KV-cache rewrite is idempotent (same tokens, same
    # positions, same weights) and the loss mask restricts each sub-event to
    # its own sub-chunk region, so every token is counted exactly once and
    # the loss equals the plain schedule's bit-for-bit function of params.
    #
    # Warmup and drain ticks are NOT idempotent: they clamp e_my to a real
    # event but feed it garbage (stage 0 embeds zeros once t >= E; later
    # stages consume a stale drain carry), so their cache rewrite clobbers
    # the event's kv with junk.  A warmup write is repaired by the stage's
    # first valid tick, but a drain write on any stage except the last is
    # final — the returned prefill state would hand the decode loop a
    # zeroed cache.  The state update below is therefore masked to valid
    # ticks; training is bit-unaffected (state is re-initialised per call
    # and each stage's garbage writes land after its last valid read).
    clen = S // N
    lloc = clen // sp
    events = pipeline_feed_events(plan, N)
    E = len(events)
    chunk_arr = jnp.array([ev[0] for ev in events], jnp.int32)
    inv_ns = jnp.array([1.0 / ev[2] for ev in events], jnp.float32)
    carry = jnp.zeros((B, lloc, d), cell.dtype)
    x_out = carry
    ahead = use_ahead_prefetch(plan, train=with_loss)
    link = ()
    for t in range(E + pp - 1):
        e_new = min(t, E - 1)
        if t < E:
            off_new = events[t][0] * clen
            ids = jax.lax.slice_in_dim(tokens, off_new, off_new + clen,
                                       axis=1)
            x0 = mdef.embed(g, ids, chunk_positions(off_new, lloc), ctx)
        else:
            x0 = jnp.zeros((B, lloc, d), cell.dtype)
        h = jnp.where(stage == 0, x0, carry)
        e_my = jnp.clip(t - stage, 0, E - 1)
        c_my = chunk_arr[e_my]
        off_my = c_my * clen
        q_pos = chunk_positions(off_my, lloc)
        ds_loc = None
        if doc_start is not None:
            # this stage's chunk offset is traced (off_my), so take the
            # local segment window with a dynamic slice; drain ticks clamp
            # harmlessly (their output is masked out below)
            ds_loc = jax.lax.dynamic_slice_in_dim(
                doc_start, off_my + rank * lloc, lloc, axis=1)
        valid = (t - stage >= 0) & (t - stage < E)
        prev_state = state
        # tick-aligned offload ratio: the SPMD program is uniform across
        # stages, so every stage tags with the fed event's deployed alpha
        if ahead:
            run = prefetch_chunk(cell, ctx, alpha=cell.alphas[events[e_new][0]],
                                 names=ofl.chunk_names(f"@t{t}"),
                                 q_pos=q_pos, cache_off=c_my * lloc,
                                 kv_view=min(events[e_new][0] + 1, N) * lloc,
                                 q_start=ds_loc)
            x_out, state, aux, link = run(stage_p, g, state, h, link)
        else:
            tag, names = chunk_tag(cell, events[e_new][0], suffix=f"@t{t}",
                                   train=with_loss)
            meta = ChunkMeta(q_pos=q_pos, cache_off=c_my * lloc,
                             kv_view=min(events[e_new][0] + 1, N) * lloc,
                             tag=tag, names=names, q_start=ds_loc)
            x_out, state, aux = mdef.stage_apply(
                stage_p, state, h, ctx, meta, g,
                offload=plan.offload, remat=plan.remat,
                offload_mode=plan.offload_mode,
                offload_dtype=plan.offload_dtype if with_loss else "none")
        # drop warmup/drain rewrites (see the block comment above)
        if not mutation.active("drain-tick-write"):
            state = jax.tree_util.tree_map(
                lambda old, new: jnp.where(valid, new, old),
                prev_state, state)
        if ledger is not None:
            from repro.runtime import memledger as _ml
            x_out = _ml.tick_probe(x_out, ledger, t)
        # sub-events of one chunk run identical compute; scale aux (MoE
        # balance) by 1/n_sub so each chunk contributes once in total
        aux_acc = aux_acc + jnp.where(valid, aux * inv_ns[e_my], 0.0)
        e_last = t - (pp - 1)
        if with_loss and 0 <= e_last < E:
            c_l, sub_l, ns_l = events[e_last]
            lab = jax.lax.slice_in_dim(labels, c_l * clen,
                                       (c_l + 1) * clen, axis=1)
            sublen = clen // ns_l
            pos_in = jnp.arange(clen)
            mask = ((pos_in >= sub_l * sublen)
                    & (pos_in < (sub_l + 1) * sublen)).astype(jnp.float32)
            wts = (jnp.broadcast_to(mask[None, :], lab.shape)
                   * (lab >= 0).astype(jnp.float32))
            ls, cnt = mdef.head_loss(g, x_out, lab, wts, ctx)
            is_last = (stage == pp - 1).astype(jnp.float32)
            loss_acc = loss_acc + is_last * ls
            den_acc = den_acc + is_last * cnt
        carry = ctx.ppermute_stage(x_out, ctx.next_stage_perm())
    # the final tick's link drains at backward start; SPMD: every stage
    # attaches its own last-tick residuals to its (psum-connected) loss term
    loss_acc = link_drain(loss_acc, link)
    return dict(loss=loss_acc, denom=den_acc, aux=aux_acc, state=state,
                last_x=x_out)


# ---------------------------------------------------------------------------
# Batch structs + shardings
# ---------------------------------------------------------------------------


def batch_struct(cell: Cell):
    """ShapeDtypeStructs + PartitionSpecs for one step's inputs."""
    B_loc, S = cell.b_loc, cell.shape.seq_len
    pods, data = cell.pods, cell.data_size
    cfg = cell.cfg
    lead = (pods, data)
    st: Dict[str, Any] = {}
    sp_: Dict[str, Any] = {}
    if cell.shape.kind == "decode":
        st["tokens"] = jax.ShapeDtypeStruct(lead + (B_loc, 1), jnp.int32)
        sp_["tokens"] = P("pod", "data") if pods > 1 else P(None, "data")
        st["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
        sp_["pos"] = P()
    else:
        st["tokens"] = jax.ShapeDtypeStruct(lead + (B_loc, S), jnp.int32)
        st["labels"] = jax.ShapeDtypeStruct(lead + (B_loc, S), jnp.int32)
        tok_spec = P("pod", "data") if pods > 1 else P(None, "data")
        sp_["tokens"] = tok_spec
        sp_["labels"] = tok_spec
        if cell.varlen:
            st["doc_start"] = jax.ShapeDtypeStruct(lead + (B_loc, S),
                                                   jnp.int32)
            sp_["doc_start"] = tok_spec
    if cfg.cross_attn is not None:
        n_ctx = (cfg.n_frames if cfg.encoder_layers
                 else cfg.cross_attn.n_context_tokens)
        n_pad = -(-n_ctx // cell.plan.sp) * cell.plan.sp
        st["context"] = jax.ShapeDtypeStruct(
            lead + (B_loc, n_pad, cfg.d_model), cell.dtype)
        sp_["context"] = (P("pod", "data", None, "model")
                          if pods > 1 else P(None, "data", None, "model"))
    return st, sp_


def _in_specs_for_params(cell: Cell):
    return {"stages": SP.stage_specs(cell.mdef, cell.plan.pp),
            "globals": SP.globals_specs(cell.mdef)}


# ---------------------------------------------------------------------------
# train_step
# ---------------------------------------------------------------------------


def make_train_step(cell: Cell, mesh, *, lr_kwargs=None, ledger=None):
    from repro.optim import adamw

    plan = cell.plan
    pspecs = _in_specs_for_params(cell)
    bstruct, bspecs = batch_struct(cell)
    lr_kwargs = lr_kwargs or {}

    def smap_body(stage_p, g, batch):
        ctx = cell.ctx()
        stage_p = _squeeze_lead(stage_p, 1)
        tokens = _squeeze_lead(batch["tokens"], 2)
        labels = _squeeze_lead(batch["labels"], 2)
        context = (_squeeze_lead(batch["context"], 2)
                   if "context" in batch else None)
        doc_start = (_squeeze_lead(batch["doc_start"], 2)
                     if "doc_start" in batch else None)

        def loss_fn(stage_p, g, tok, lab, ctxt, ds):
            out = run_pipeline(cell, ctx, stage_p, g, tok, lab, ctxt,
                               with_loss=True, ledger=ledger,
                               doc_start=ds if cell.varlen else None)
            num = ctx.psum_loss_all(out["loss"])
            den = ctx.psum_loss_all(out["denom"])
            aux = ctx.psum_loss_all(out["aux"])
            loss = num / jnp.maximum(den, 1.0)
            if cell.cfg.moe is not None:
                loss = loss + 0.01 * aux / (cell.data_size * cell.pods
                                            * cell.plan.sp * cell.sched.n
                                            * max(1, cell.mdef.n_slots))
            return loss

        A = plan.grad_accum
        if A > 1:
            Bm = tokens.shape[0] // A
            tks = tokens.reshape(A, Bm, -1)
            lbs = labels.reshape(A, Bm, -1)
            cxs = (context.reshape((A, Bm) + context.shape[1:])
                   if context is not None else None)
            dss = (doc_start.reshape(A, Bm, -1)
                   if doc_start is not None else None)

            def acc_step(carry, xs):
                gsum, lsum = carry
                tok, lab, cx, ds = xs
                l, gr = jax.value_and_grad(loss_fn, argnums=(0, 1))(
                    stage_p, g, tok, lab, cx, ds)
                gsum = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(a.dtype), gsum, gr)
                return (gsum, lsum + l), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), (stage_p, g))
            (grads, loss), _ = jax.lax.scan(
                acc_step, (zeros, jnp.float32(0.0)),
                (tks, lbs, cxs if cxs is not None else jnp.zeros((A, Bm)),
                 dss if dss is not None else jnp.zeros((A, Bm))))
            loss = loss / A
            grads = jax.tree_util.tree_map(lambda a: a / A, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn, argnums=(0, 1))(
                stage_p, g, tokens, labels, context, doc_start)
        # stage grads reduce over dp replicas; global grads over all stages
        g_stage = ctx.psum_grads(grads[0])
        g_glob = ctx.psum_globals(grads[1])
        g_st = jax.tree_util.tree_map(lambda a: a[None], g_stage)
        return loss, g_st, g_glob

    smapped = shard_map(
        smap_body, mesh,
        in_specs=(pspecs["stages"], pspecs["globals"], bspecs),
        out_specs=(P(), pspecs["stages"], pspecs["globals"]))

    def train_step(params, opt_state, batch):
        loss, gs, gg = smapped(params["stages"], params["globals"], batch)
        grads = {"stages": gs, "globals": gg}
        lr = adamw.cosine_lr(opt_state.step, **lr_kwargs)
        new_p, new_o, met = adamw.apply_update(
            params, grads, opt_state, lr=lr,
            offload_moments=plan.offload_moments,
            moments_mode=plan.moments_mode,
            moments_dtype=plan.moments_dtype)
        met["loss"] = loss
        return new_p, new_o, met

    return train_step


# ---------------------------------------------------------------------------
# prefill_step / serve_step
# ---------------------------------------------------------------------------


def make_prefill_step(cell: Cell, mesh):
    pspecs = _in_specs_for_params(cell)
    bstruct, bspecs = batch_struct(cell)
    _, sstruct, sspecs = _serve_state(cell)

    def smap_body(stage_p, g, batch):
        ctx = cell.ctx()
        stage_p = _squeeze_lead(stage_p, 1)
        tokens = _squeeze_lead(batch["tokens"], 2)
        context = (_squeeze_lead(batch["context"], 2)
                   if "context" in batch else None)
        out = run_pipeline(cell, ctx, stage_p, g, tokens, tokens, context,
                           with_loss=False)
        state = jax.tree_util.tree_map(lambda a: a[None], out["state"])
        return state, out["last_x"][None]

    last_spec = P("data", None, None, None)
    smapped = shard_map(
        smap_body, mesh,
        in_specs=(pspecs["stages"], pspecs["globals"], bspecs),
        out_specs=(sspecs, last_spec))

    def prefill_step(params, batch):
        return smapped(params["stages"], params["globals"], batch)

    return prefill_step, sstruct, sspecs


def max_decode_steps(cell: Cell) -> int:
    """Longest decode run the striped cache can absorb: token S + i lands at
    local slot base + i // sp, and the buffer holds DECODE_BUDGET slots past
    base — so step DECODE_BUDGET * sp is the first to fall off the end."""
    return DECODE_BUDGET * cell.plan.sp


def make_serve_step(cell: Cell, mesh, *, decode_steps=None):
    """Build the static lock-step decode step.

    decode_steps: when given, the number of steps the caller intends to run;
    rejected at construction if it exceeds the cache's decode budget —
    beyond it ``my_slot`` runs past ``cache_loc`` and the clamped
    dynamic-update would silently overwrite the last slot, corrupting every
    later logit with no error.
    """
    if decode_steps is not None and decode_steps > max_decode_steps(cell):
        raise ValueError(
            f"decode_steps={decode_steps} exceeds the cache's decode budget "
            f"of {max_decode_steps(cell)} steps (DECODE_BUDGET={DECODE_BUDGET}"
            f" slots x sp={cell.plan.sp}); the striped write would silently "
            "wrap onto the last cache slot")
    pspecs = _in_specs_for_params(cell)
    bstruct, bspecs = batch_struct(cell)
    _, sstruct, sspecs_g = _serve_state(cell)
    sspecs = sspecs_g

    plan = cell.plan
    S = cell.shape.seq_len
    sp = plan.sp

    def smap_body(stage_p, g, state, batch):
        ctx = cell.ctx()
        stage_p = _squeeze_lead(stage_p, 1)
        state = _squeeze_lead(state, 1)
        tokens = _squeeze_lead(batch["tokens"], 2)   # [B_loc, 1]
        pos = batch["pos"]                            # [] global position
        rank = ctx.model_index()
        base = S // sp
        idx = pos - S
        my_slot = jnp.where((idx % sp) == rank, base + idx // sp, -1)
        meta = ChunkMeta(
            q_pos=jnp.full((1,), pos, jnp.int32), cache_off=0,
            kv_view=cell.cache_loc, tag=ofl.null_tag, decode=True,
            my_slot=my_slot)

        # Decode consumes the plan like every other loop.  resolve_plan pins
        # offload=False / remat="none" for decode shapes (and resolve_cell
        # asserts it): a decode step has no backward, so there is no reload
        # to hide and no residual worth evicting — offloading here would be
        # pure added H2D latency on the critical path (DESIGN.md §4).
        def one_micro(state_m, tok_m):
            x = cell.mdef.embed(g, tok_m, jnp.full((1,), pos, jnp.int32),
                                ctx, decode=True)
            x, state_m, _ = cell.mdef.stage_apply(
                stage_p, state_m, x, ctx, meta, g, offload=plan.offload,
                remat=plan.remat, offload_mode=plan.offload_mode)
            return state_m, x

        if plan.pp == 1:
            state, x = one_micro(state, tokens)
            logits = cell.mdef.head_logits(g, x, ctx)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            # Microbatch pipeline over the batch dim, as a lax.scan over
            # ticks so the per-stage cache is threaded (double-buffered)
            # instead of copied once per unrolled tick.
            M = plan.decode_microbatch
            Bm = tokens.shape[0] // M
            stage = ctx.stage_index()
            n_ticks = M + plan.pp - 1

            def tick(carry_t, t):
                state, carry, nxt = carry_t
                m_my = jnp.clip(t - stage, 0, M - 1)
                boff = m_my * Bm
                state_m = jax.tree_util.tree_map(
                    lambda a: (jax.lax.dynamic_slice_in_dim(a, boff, Bm,
                                                            axis=1)
                               if a.ndim >= 3 else a), state)
                tok_m = jax.lax.dynamic_slice_in_dim(
                    tokens, jnp.clip(t, 0, M - 1) * Bm, Bm, axis=0)
                x0 = cell.mdef.embed(g, tok_m,
                                     jnp.full((1,), pos, jnp.int32),
                                     ctx, decode=True)
                h = jnp.where(stage == 0, x0, carry)
                # plan-driven like one_micro above: decode never offloads
                # (no backward, nothing to hide under — DESIGN.md §4)
                x, state_m, _ = cell.mdef.stage_apply(
                    stage_p, state_m, h, ctx, meta, g, offload=plan.offload,
                    remat=plan.remat, offload_mode=plan.offload_mode)
                state = jax.tree_util.tree_map(
                    lambda a, am: (jax.lax.dynamic_update_slice_in_dim(
                        a, am, boff, axis=1) if a.ndim >= 3 else am),
                    state, state_m)
                logits = cell.mdef.head_logits(g, x, ctx)
                tok_new = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                # only the last stage's sample on a valid drain tick is real
                m_last = t - (plan.pp - 1)
                valid = (m_last >= 0) & (stage == plan.pp - 1)
                off_l = jnp.clip(m_last, 0, M - 1) * Bm
                cur = jax.lax.dynamic_slice_in_dim(nxt, off_l, Bm, axis=0)
                nxt = jax.lax.dynamic_update_slice_in_dim(
                    nxt, jnp.where(valid, tok_new, cur), off_l, axis=0)
                carry = ctx.ppermute_stage(x, ctx.next_stage_perm())
                return (state, carry, nxt), None

            carry0 = jnp.zeros((Bm, 1, cell.cfg.d_model), cell.dtype)
            nxt0 = jnp.zeros((tokens.shape[0], 1), jnp.int32)
            (state, _, nxt), _ = jax.lax.scan(
                tick, (state, carry0, nxt0),
                jnp.arange(n_ticks, dtype=jnp.int32))
            # only the last stage sampled real tokens; replicate them to
            # every stage row of the dp group so callers can thread nxt
            # straight back in as the next step's tokens (no host gather)
            nxt = ctx.psum_stages(
                jnp.where(stage == plan.pp - 1, nxt, 0))
        state = jax.tree_util.tree_map(lambda a: a[None], state)
        return state, nxt[None]

    tok_out_spec = P("data", None, None)
    smapped = shard_map(
        smap_body, mesh,
        in_specs=(pspecs["stages"], pspecs["globals"], sspecs, bspecs),
        out_specs=(sspecs, tok_out_spec))

    def serve_step(params, state, batch):
        return smapped(params["stages"], params["globals"], state, batch)

    return serve_step, sstruct, sspecs


def _serve_state(cell: Cell):
    """State struct/specs for decode (global arrays passed between steps)."""
    ctx = Ctx(sp=cell.plan.sp, dp=cell.plan.dp, pp=cell.plan.pp)

    def f(k):
        stage_p = cell.mdef.init_stage_params(k, 0, cell.plan.pp, cell.dtype)
        g = cell.mdef.init_globals(k, cell.dtype)
        cfgc = cell.cfg
        ctxt = None
        if cfgc.cross_attn is not None:
            n_ctx = (cfgc.n_frames if cfgc.encoder_layers
                     else cfgc.cross_attn.n_context_tokens)
            n_loc = (-(-n_ctx // cell.plan.sp) * cell.plan.sp) // cell.plan.sp
            ctxt = jnp.zeros((cell.b_loc, n_loc, cfgc.d_model), cell.dtype)
            if cfgc.encoder_layers:
                ctxt = cell.mdef.encode(g, ctxt, ctx)
        return cell.mdef.init_state(stage_p, g, ctx, cell.b_loc,
                                    cell.cache_loc, cell.dtype, context=ctxt)

    local = jax.eval_shape(f, jax.ShapeDtypeStruct((2,), jnp.uint32))
    struct = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((cell.data_size,) + s.shape, s.dtype),
        local)
    specs = jax.tree_util.tree_map(
        lambda s: P(*(("data",) + (None,) * s.ndim)), local)
    return local, struct, specs


# ---------------------------------------------------------------------------
# Paged-pool continuous-batching decode (DESIGN.md §16)
# ---------------------------------------------------------------------------


def _assert_pool_cell(cell: Cell, geo):
    assert cell.plan.pp == 1, "paged decode pool requires pp == 1"
    assert cell.pods == 1, "paged decode pool is single-pod"
    cfg = cell.cfg
    assert (cfg.family == "dense" and cfg.cross_attn is None
            and cfg.mla is None), (
        f"paged decode pool supports dense GQA families only, got "
        f"family={cfg.family!r}")
    assert cell.plan.sp == geo.sp, (cell.plan.sp, geo.sp)
    assert cell.b_loc == geo.n_slots, (
        f"cell batch/shard {cell.b_loc} != pool slots {geo.n_slots}")


def _pool_specs():
    spec = P("data", None, None, None, None)
    return {"kv": A.PooledKV(k=spec, v=spec)}


def make_pool_state(cell: Cell, geo, mesh):
    """Zero-initialized paged KV pool for ``cell`` (global arrays + specs).

    One [P_loc, Hkv, hd] block buffer per layer-slot per (data, model) rank;
    the spec claims model-axis replication like ``_serve_state`` does (the
    shard_map wrapper disables replication checks), so each model rank keeps
    its own sequence shard of the pool.
    """
    _assert_pool_cell(cell, geo)
    spp = cell.mdef.slots_per_stage(cell.plan.pp)
    cfg = cell.cfg
    shape = (cell.data_size, spp, geo.p_loc, cfg.n_kv_heads, cfg.hd)
    spec = P("data", None, None, None, None)

    def arr():
        # transfer-lint: ok (pool init placement, device memory only)
        return jax.device_put(jnp.zeros(shape, cell.dtype),
                              jax.sharding.NamedSharding(mesh, spec))

    return {"kv": A.PooledKV(k=arr(), v=arr())}, _pool_specs()


def make_pool_ingest(pre_cell: Cell, geo, mesh):
    """Copy an admission wave's prefilled caches into the pool.

    Identity slot mapping: the engine prefills each admitted request in the
    batch row of its target pool slot, so prefill cache row b of a data
    shard feeds pool slot b of the same shard, and the first ``base``
    logical slots of the prefill cache are exactly the right-aligned prompt
    bucket.  Rows outside the admit mask scatter to an out-of-bounds
    sentinel and drop.
    """
    _assert_pool_cell(pre_cell, geo)
    assert pre_cell.shape.seq_len == geo.s_bucket, (
        pre_cell.shape.seq_len, geo.s_bucket)
    assert pre_cell.cache_loc >= geo.base
    _, _, sspecs = _serve_state(pre_cell)
    pool_specs = _pool_specs()
    bt, p_loc = geo.block_tokens, geo.p_loc
    io = P(None, "data")

    def smap_body(state_pre, pool, btab, admit):
        state_pre = _squeeze_lead(state_pre, 1)
        pool = _squeeze_lead(pool, 1)
        btab = _squeeze_lead(btab, 2)                    # [K, max_blocks]
        admit = _squeeze_lead(admit, 2)                  # [K] bool
        jlog = jnp.arange(geo.base)
        blk = btab[:, jlog // bt]                        # [K, base]
        phys = jnp.where(admit[:, None] & (blk >= 0),
                         blk * bt + jlog % bt, p_loc)

        def copy(pool_a, cache_a):
            # pool_a: [spp, P_loc, Hkv, hd]; cache_a: [spp, K, C_loc, ...]
            vals = cache_a[:, :, :geo.base]

            def one(pa, va):
                return pa.at[phys].set(va.astype(pa.dtype), mode="drop")

            return jax.vmap(one)(pool_a, vals)

        kv, pkv = state_pre["kv"], pool["kv"]
        new = {"kv": A.PooledKV(k=copy(pkv.k, kv.k), v=copy(pkv.v, kv.v))}
        return jax.tree_util.tree_map(lambda a: a[None], new)

    smapped = shard_map(smap_body, mesh,
                        in_specs=(sspecs, pool_specs, io, io),
                        out_specs=pool_specs)

    def ingest(state_pre, pool, btab, admit):
        return smapped(state_pre, pool, btab, admit)

    return ingest


def make_pool_serve_step(cell: Cell, geo, mesh, pos_map):
    """One continuous-batching decode step against the paged pool.

    Unlike ``make_serve_step`` there is no global position scalar: every
    request slot carries its own feed position (``q_pos``; 0 = inactive
    slot), its own block-table row, and its own sampled-token carry, so
    requests at different decode depths step together and the host never
    syncs mid-loop.  Admission folds in on device: rows under ``admit``
    take ``admit_tok`` (the request's last prompt token) instead of the
    carried sample.

    batch keys (lead dims (1, data), spec P(None, "data")):
      tokens    [1, D, K, 1]  carried sampled tokens (device-resident)
      q_pos     [1, D, K]     per-slot global feed position, 0 = inactive
      btab      [1, D, K, max_blocks] block table (host-pushed, -1 = unset)
      admit     [1, D, K]     bool: overwrite the carry with admit_tok
      admit_tok [1, D, K, 1]  first decode token of newly admitted rows
    Returns (pool', nxt [D, K, 1]).
    """
    _assert_pool_cell(cell, geo)
    import numpy as _np
    pos_map = _np.asarray(pos_map)
    assert pos_map.shape == (geo.sp, geo.l_loc), pos_map.shape
    pspecs = _in_specs_for_params(cell)
    pool_specs = _pool_specs()
    plan = cell.plan
    io = P(None, "data")
    bspecs = {"tokens": io, "q_pos": io, "btab": io, "admit": io,
              "admit_tok": io}

    def smap_body(stage_p, g, pool, batch):
        ctx = cell.ctx()
        stage_p = _squeeze_lead(stage_p, 1)
        pool = _squeeze_lead(pool, 1)
        tokens = _squeeze_lead(batch["tokens"], 2)       # [K, 1]
        qpos = _squeeze_lead(batch["q_pos"], 2)          # [K]
        btab = _squeeze_lead(batch["btab"], 2)           # [K, max_blocks]
        admit = _squeeze_lead(batch["admit"], 2)         # [K] bool
        atok = _squeeze_lead(batch["admit_tok"], 2)      # [K, 1]
        tokens = jnp.where(admit[:, None], atok, tokens)
        rank = ctx.model_index()
        paged = A.PagedMeta(q_pos=qpos, btab=btab,
                            pos_map=jnp.asarray(pos_map)[rank],
                            base=geo.base, s_bucket=geo.s_bucket,
                            block_tokens=geo.block_tokens)
        meta = ChunkMeta(q_pos=qpos, cache_off=0, kv_view=geo.l_loc,
                         tag=ofl.null_tag, decode=True, paged=paged)
        x = cell.mdef.embed(g, tokens, qpos[:, None], ctx, decode=True)
        x, pool, _ = cell.mdef.stage_apply(
            stage_p, pool, x, ctx, meta, g, offload=plan.offload,
            remat=plan.remat, offload_mode=plan.offload_mode)
        logits = cell.mdef.head_logits(g, x, ctx)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        pool = jax.tree_util.tree_map(lambda a: a[None], pool)
        return pool, nxt[None]

    smapped = shard_map(
        smap_body, mesh,
        in_specs=(pspecs["stages"], pspecs["globals"], pool_specs, bspecs),
        out_specs=(pool_specs, P("data", None, None)))

    def pool_step(params, pool, batch):
        return smapped(params["stages"], params["globals"], pool, batch)

    return pool_step
