"""Parallel context: named-axis collectives with a single-device no-op mode.

All model code takes a ``Ctx``.  Inside ``shard_map`` the ctx is bound to real
mesh axis names and every helper lowers to a collective; in single-device mode
(tests, reference oracles) every helper degenerates to the identity, so the
same model code is both the distributed implementation and its own oracle.

Axis roles (see DESIGN.md §4):
  model  — SP/TP domain: sequence-sharded activations, parameter shards
           (all-gathered per layer), expert parallelism, vocab-parallel loss.
  data   — dp x pp: pipeline stages are a sub-grouping; gradient reduction
           runs over dp subgroups (and the pod axis when present).
  pod    — pure DP across pods (slow DCI links); only gradient all-reduce.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Ctx:
    """Collective context. ``model_axis=None`` means single-device mode."""

    model_axis: Optional[str] = None
    data_axis: Optional[str] = None
    pod_axis: Optional[str] = None
    sp: int = 1      # size of model axis
    dp: int = 1      # data-parallel groups within data axis
    pp: int = 1      # pipeline stages within data axis (dp * pp == data size)
    pods: int = 1
    # perf knobs threaded from the ParallelPlan (see configs/base.py)
    attn_mode: str = "gather_q"
    merge_bf16: bool = False
    grad_compress: bool = False

    # ----- sizes / indices -------------------------------------------------
    @property
    def distributed(self) -> bool:
        return self.model_axis is not None

    def model_index(self):
        if self.model_axis is None:
            return jnp.int32(0)
        return jax.lax.axis_index(self.model_axis)

    def data_index(self):
        if self.data_axis is None:
            return jnp.int32(0)
        return jax.lax.axis_index(self.data_axis)

    def stage_index(self):
        """Pipeline stage of this device: data_index % pp (stage-major)."""
        return self.data_index() % self.pp

    def dp_index(self):
        return self.data_index() // self.pp

    # ----- model-axis collectives -----------------------------------------
    def psum_model(self, x):
        if self.model_axis is None or self.sp == 1:
            return x
        return jax.lax.psum(x, self.model_axis)

    def pmax_model(self, x):
        if self.model_axis is None or self.sp == 1:
            return x
        return jax.lax.pmax(x, self.model_axis)

    def all_gather_model(self, x, axis: int):
        """Gather shards along `axis` (tiled: result dim = sp * local dim)."""
        if self.model_axis is None or self.sp == 1:
            return x
        return jax.lax.all_gather(x, self.model_axis, axis=axis, tiled=True)

    def all_gather_param(self, x, axis: int):
        """Weight gather for compute.  With grad_compress the transpose
        (the weight-gradient reduce-scatter — the dominant train collective)
        runs in bf16 instead of the f32 the autodiff cotangents carry."""
        if self.model_axis is None or self.sp == 1:
            return x
        if not self.grad_compress:
            return jax.lax.all_gather(x, self.model_axis, axis=axis,
                                      tiled=True)
        return _ag_bf16_grad(x, self.model_axis, axis)

    def reduce_scatter_model(self, x, axis: int):
        if self.model_axis is None or self.sp == 1:
            return x
        return jax.lax.psum_scatter(x, self.model_axis,
                                    scatter_dimension=axis, tiled=True)

    def ppermute_model(self, x, perm: Sequence[Tuple[int, int]]):
        if self.model_axis is None or self.sp == 1:
            return x
        return jax.lax.ppermute(x, self.model_axis, perm=perm)

    def all_to_all_model(self, x, split_axis: int, concat_axis: int):
        if self.model_axis is None or self.sp == 1:
            return x
        return jax.lax.all_to_all(x, self.model_axis, split_axis=split_axis,
                                  concat_axis=concat_axis, tiled=True)

    # ----- data/pod-axis collectives ---------------------------------------
    def _dp_groups(self):
        """axis_index_groups for dp subgroups of the data axis (same stage)."""
        return [[g * self.pp + s for g in range(self.dp)] for s in range(self.pp)]

    def psum_grads(self, x):
        """Gradient reduction across dp replicas (same pipeline stage) + pods."""
        if self.data_axis is not None and self.dp > 1:
            x = jax.lax.psum(x, self.data_axis,
                             axis_index_groups=self._dp_groups())
        if self.pod_axis is not None and self.pods > 1:
            x = jax.lax.psum(x, self.pod_axis)
        return x

    def psum_globals(self, x):
        """Gradient reduction for *global* params (embed/head/shared blocks):
        contributions live on different stages, so reduce over the full data
        axis (+ pods), not just dp subgroups."""
        if self.data_axis is not None and self.dp * self.pp > 1:
            x = jax.lax.psum(x, self.data_axis)
        if self.pod_axis is not None and self.pods > 1:
            x = jax.lax.psum(x, self.pod_axis)
        return x

    def psum_loss_all(self, x):
        """Scalar reduction over every device (loss/metric aggregation)."""
        for ax, size in ((self.model_axis, self.sp),
                         (self.data_axis, self.dp * self.pp),
                         (self.pod_axis, self.pods)):
            if ax is not None and size > 1:
                x = jax.lax.psum(x, ax)
        return x

    def psum_stages(self, x):
        """Sum within each dp group *across its pipeline stages* (the
        transpose of ``_dp_groups``).  Used to replicate the last stage's
        sampled decode tokens to every stage row of its group, so the serve
        loop can feed tokens back device-to-device without a host gather."""
        if self.data_axis is None or self.pp == 1:
            return x
        groups = [[g * self.pp + s for s in range(self.pp)]
                  for g in range(self.dp)]
        return jax.lax.psum(x, self.data_axis, axis_index_groups=groups)

    def ppermute_stage(self, x, perm: Sequence[Tuple[int, int]]):
        """Permute along the data axis (pipeline stage hand-off)."""
        if self.data_axis is None or self.dp * self.pp == 1:
            return x
        return jax.lax.ppermute(x, self.data_axis, perm=perm)

    def next_stage_perm(self) -> Sequence[Tuple[int, int]]:
        """(i -> i+1) within each dp group; stage-major layout."""
        n = self.dp * self.pp
        return [(i, i + 1) for i in range(n) if (i % self.pp) != self.pp - 1]


import functools as _ft


@_ft.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def _ag_bf16_grad(x, axis_name, dim):
    return jax.lax.all_gather(x, axis_name, axis=dim, tiled=True)


def _ag_fwd(x, axis_name, dim):
    # residual: zero-size array carrying the primal dtype (dtypes are not
    # valid jax residual types)
    return _ag_bf16_grad(x, axis_name, dim), jnp.zeros((0,), x.dtype)


def _ag_bwd(axis_name, dim, proto, g):
    g = jax.lax.psum_scatter(g.astype(jnp.bfloat16), axis_name,
                             scatter_dimension=dim, tiled=True)
    return (g.astype(proto.dtype),)


_ag_bf16_grad.defvjp(_ag_fwd, _ag_bwd)


SINGLE = Ctx()


def make_ctx(plan, *, model_axis="model", data_axis="data", pod_axis=None,
             pods=1) -> Ctx:
    return Ctx(model_axis=model_axis if plan.sp > 1 else model_axis,
               data_axis=data_axis,
               pod_axis=pod_axis,
               sp=plan.sp, dp=plan.dp, pp=plan.pp, pods=pods)
