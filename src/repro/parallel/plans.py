"""Per-cell parallel plans: map (arch x shape) onto the production mesh.

Defaults follow the SPPO heuristics (§6.1) adapted to the TPU mesh
(DESIGN.md §4): SP pinned to the 16-wide `model` axis, PP a divisor of the
`data` axis with stage handoffs on intra-pod ICI, pods carry pure DP.  The
heuristic solver (core/solver.py) reproduces/justifies these choices in the
benchmarks; plans.py keeps them explicit and divisibility-safe.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig, ParallelPlan, ShapeConfig
from repro.core import costmodel as cm

ACT_BYTES_BUDGET = 3.5 * 2**30  # target tagged-activation bytes per device


def _pp_for(cfg: ModelConfig, shape: ShapeConfig, data_size: int) -> int:
    big = cfg.name.startswith("deepseek")
    if shape.kind == "train" or shape.kind == "prefill":
        if big:
            return min(16, data_size)
        if shape.seq_len >= 32768 and cfg.n_layers >= 24:
            return 2
        return 1
    # decode
    if big:
        return min(8, data_size)
    return 1


def resolve_plan(cfg: ModelConfig, shape: ShapeConfig, *, data_size: int = 16,
                 model_size: int = 16, pods: int = 1,
                 overrides: dict = None) -> ParallelPlan:
    pp = _pp_for(cfg, shape, data_size)
    dp = data_size // pp
    B = shape.global_batch
    # keep batch divisible across dp*pods (drop dp down if needed)
    while dp > 1 and B % (dp * pods):
        pp_candidates = [p for p in (pp * 2, pp * 4, data_size)
                         if data_size % p == 0]
        if not pp_candidates:
            break
        pp = pp_candidates[0]
        dp = data_size // pp
    if B % (dp * pods):
        dp = 1
        pp = data_size

    if shape.kind == "train":
        # keep the pipeline fed: N >= pp/2 even for short sequences (the
        # paper's bubble ratio (p-1)/N; garbage ticks are real compute here)
        n = max(2 if shape.seq_len >= 4096 else 1, pp // 2)
        while shape.seq_len % (n * model_size):
            n -= 1
    elif shape.kind == "prefill":
        n = max(pp, shape.seq_len // 4096)
    else:
        n = 1  # decode: single-token step, no chunking

    b_loc = max(1, B // (dp * pods))
    accum = 1
    if shape.kind == "train":
        # memory-aware microbatching: the full per-layer activation set
        # (costmodel.full_act_bytes_per_token, ~34·d bf16) spread over
        # pp*sp devices; pick the accumulation factor that fits
        # ACT_BYTES_BUDGET
        per_tok = (cm.full_act_bytes_per_token(cfg) * cfg.n_layers
                   / (pp * model_size))
        tok_budget = max(2048, int(ACT_BYTES_BUDGET / per_tok))
        want = max(1, (b_loc * shape.seq_len + tok_budget - 1) // tok_budget)
        # smallest divisor of b_loc >= want (cap at b_loc: microbatch of 1)
        accum = b_loc
        for c in range(want, b_loc + 1):
            if b_loc % c == 0:
                accum = c
                break

    micro = 1
    if shape.kind == "decode" and pp > 1:
        micro = min(8, b_loc)
        while b_loc % micro:
            micro -= 1

    plan = ParallelPlan(
        dp=dp, pp=pp, sp=model_size,
        n_chunks=n,
        partition="flops" if pp == 1 else "length",
        offload=shape.kind != "decode",
        # one-chunk-ahead backward reload on the trained explicit path
        # (DESIGN.md §12); prefill/decode have no backward, so the seam
        # would be dead structure — they keep the autodiff placement
        prefetch="ahead" if shape.kind == "train" else "sync",
        msp=False,
        remat="sppo" if shape.kind == "train" else "none",
        zero1=pods > 1,
        opt_dtype="bfloat16" if cfg.name.startswith("deepseek") else "float32",
        # big models keep AdamW m/v host-resident (executed ZeRO-Offload
        # analogue, DESIGN.md §11); only train shapes carry an optimizer
        offload_moments=(shape.kind == "train"
                         and cfg.name.startswith("deepseek")),
        grad_accum=accum,
        decode_microbatch=micro,
    )
    if overrides:
        plan = dataclasses.replace(plan, **overrides)
    plan.validate(data_size, model_size)
    return plan
