"""Ring-distributed chunked attention (DESIGN.md §15, FPDT arxiv 2408.16978).

The gather modes in models/attention.py move either the queries or the whole
visible KV through one collective, so some device always materializes the
full KV extent of the chunk — which is exactly what caps per-stage sequence
length.  The ring schedule never gathers: each rank keeps its sequence shard
of (k, v, kv_pos) and the shards *rotate* around the model axis via
``ppermute``, one hop per step.  At hop h rank r holds the block that
originated on rank (r − h) mod sp; the arriving block is consumed by one
``attention_partial`` call and its (o, m, l) triple is scattered into a
per-source buffer.  After sp hops every rank has seen every block and folds
the buffers once, in canonical source order, via ``merge_partials``.

Why fold from buffers instead of streaming the running merge: float addition
is not associative, so a running fold would make the result depend on the
*arrival* order of the blocks — which is rank-dependent in a ring.  The
canonical-order fold makes the output bit-identical on every rank and under
every rotation of the arrival sequence (tests/test_kernel_grads.py
hypothesis-checks exactly this invariance through ``fold_arrivals``).  The
buffers are query-chunk-sized (same scale as the gather_q merge buffers);
the KV working set — the term that scales with context — stays at two
blocks: the resident block and the one in flight.

Overlap: the ppermute for hop h+1 is issued *before* hop h's attention
compute.  The two have no data dependency, so XLA is free to run the ICI
transfer under the tile compute — the double-buffer recurrence that
``core/simulate.ring_overlap`` prices per hop.

Causality / hop skipping: in the lock-step SPMD program no hop is globally
skippable — every hop's block carries visible KV from earlier chunks for at
least one rank (and rank sp−1 needs all of them), and a traced rank index
cannot prune a collective.  The executed ring therefore runs all sp hops
and lets the kernels' positional masking zero the invisible pairs; the
causality rule lives in the *pricing*: ``costmodel.ring_hop_fractions``
gives the per-hop compute fraction the slowest rank must execute under a
block-contiguous layout (late ranks serialize: every hop costs a full
block) vs the striped/zig-zag assignment (balanced: ~half a block per hop),
and the solver charges the zig-zag schedule.

Gradients are training-grade: ppermute's VJP is the inverse permutation,
the scatter is a dynamic_update_slice, the per-hop partials differentiate
on both kernel backends, and the max statistics are gradient-frozen per the
``merge_partials`` contract (kernels/ref.py).
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.kernels.ref import NEG_INF, merge_partials, normalize
from repro.parallel.ctx import Ctx


def ring_perm(sp: int) -> List[Tuple[int, int]]:
    """One-hop rotation on the model axis: rank i sends to rank i+1, so
    after h hops rank r holds the block that originated on (r − h) mod sp."""
    return [(i, (i + 1) % sp) for i in range(sp)]


def _merge_buffers(o_buf, m_buf, l_buf):
    """Fold source-indexed (o, m, l) buffers in canonical block order.

    This is THE fold of the ring schedule: because every path through the
    ring scatters into the same canonical slots, the merge graph — and
    hence the result, bitwise — is independent of the order the blocks
    arrived in."""
    n = o_buf.shape[0]
    return merge_partials([(o_buf[i], m_buf[i], l_buf[i]) for i in range(n)])


def fold_arrivals(parts: Sequence[Tuple[jax.Array, jax.Array, jax.Array]],
                  sources: Sequence[int], n_blocks: int = None):
    """Fold per-block partials exactly the way the executed ring does.

    parts: (o, m, l) triples in *arrival* order; sources[i] is the canonical
    block id of parts[i] (each id written exactly once).  Returns the merged
    (o, m, l) — bit-identical for every permutation of the arrival order,
    the invariance the ring schedule silently depends on."""
    n = n_blocks if n_blocks is not None else len(parts)
    o0, m0, l0 = parts[0]
    o_buf = jnp.zeros((n,) + tuple(o0.shape), jnp.float32)
    m_buf = jnp.full((n,) + tuple(m0.shape), NEG_INF, jnp.float32)
    l_buf = jnp.zeros((n,) + tuple(l0.shape), jnp.float32)
    for (o, m, l), s in zip(parts, sources):
        o_buf = jax.lax.dynamic_update_index_in_dim(
            o_buf, o.astype(jnp.float32), s, 0)
        m_buf = jax.lax.dynamic_update_index_in_dim(
            m_buf, m.astype(jnp.float32), s, 0)
        l_buf = jax.lax.dynamic_update_index_in_dim(
            l_buf, l.astype(jnp.float32), s, 0)
    return _merge_buffers(o_buf, m_buf, l_buf)


def ring_attention(q, k_loc, v_loc, q_pos, kv_pos, ctx: Ctx, *, causal=True,
                   scale=None, q_start=None):
    """Ring-distributed attention over the model axis.

    q/q_pos/q_start stay local (query-side, like gather_kv); the KV shard
    (k_loc, v_loc, kv_pos) rotates.  Shapes as in dist_attention; returns
    the normalized output for this rank's query shard [B, Tq_loc, H, hd_v].
    Degenerates to a single partial + normalize at sp == 1 (the oracle
    property every executed mode here shares)."""
    sp = ctx.sp
    if not ctx.distributed or sp == 1:
        o, m, l = kops.attention_partial(q, k_loc, v_loc, q_pos, kv_pos,
                                         causal=causal, scale=scale,
                                         q_start=q_start)
        return normalize(o, l).astype(q.dtype)

    perm = ring_perm(sp)
    rank = ctx.model_index()
    B, Tq, H = q.shape[0], q.shape[1], q.shape[2]
    hdv = v_loc.shape[-1]
    o_buf = jnp.zeros((sp, B, Tq, H, hdv), jnp.float32)
    m_buf = jnp.full((sp, B, Tq, H), NEG_INF, jnp.float32)
    l_buf = jnp.zeros((sp, B, Tq, H), jnp.float32)

    k_cur, v_cur, p_cur = k_loc, v_loc, kv_pos
    for h in range(sp):
        # issue the next hop's rotation BEFORE this hop's compute: the two
        # have no data dependency, so the ICI transfer overlaps the tile
        # compute (the double-buffer recurrence simulate.ring_overlap prices)
        if h + 1 < sp:
            k_nxt = ctx.ppermute_model(k_cur, perm)
            v_nxt = ctx.ppermute_model(v_cur, perm)
            p_nxt = ctx.ppermute_model(p_cur, perm)
        o_h, m_h, l_h = kops.attention_partial(q, k_cur, v_cur, q_pos, p_cur,
                                               causal=causal, scale=scale,
                                               q_start=q_start)
        # canonical slot of the block now resident here: its source rank
        src = jax.lax.rem(rank - h + sp, sp)
        o_buf = jax.lax.dynamic_update_index_in_dim(o_buf, o_h, src, 0)
        m_buf = jax.lax.dynamic_update_index_in_dim(m_buf, m_h, src, 0)
        l_buf = jax.lax.dynamic_update_index_in_dim(l_buf, l_h, src, 0)
        if h + 1 < sp:
            k_cur, v_cur, p_cur = k_nxt, v_nxt, p_nxt

    o, m, l = _merge_buffers(o_buf, m_buf, l_buf)
    return normalize(o, l).astype(q.dtype)
