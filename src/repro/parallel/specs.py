"""Global shapes + NamedShardings for params/optimizer/batch, per cell.

Shapes come from ``jax.eval_shape`` over the init functions — no allocation,
so this works for deepseek-v3-671b as well as the reduced smoke configs.

Layouts (DESIGN.md §4):
  stage params   [data_size, slots_per_stage, ...]   P('data', None, ...)
                 entry i holds stage (i % pp)'s slots (dp-replicated).
  globals        [...]                               replicated over data.
  tokens/labels  [pods, data_size, B_loc, S]         P('pod','data',...)
                 row (p, i) is the batch shard of dp group (p, i // pp).
  moments        like params; optional ZeRO-1 over the pod axis and/or
                 pinned_host memory kind (big-model plans).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.model_zoo import ModelDef


def _marker_spec(marker, lead: Tuple[Optional[str], ...]):
    """PartitionSpec for one leaf: lead axes + 'model' at the marker dim."""
    if isinstance(marker, int):
        dim = marker
    elif isinstance(marker, str) and marker.startswith("keep"):
        dim = int(marker[4:])
    else:
        return P(*lead) if lead else P()
    parts = list(lead) + [None] * (dim + 1)
    parts[len(lead) + dim] = "model"
    return P(*parts)


def stage_specs(mdef: ModelDef, pp: int):
    """Pytree of PartitionSpecs for stage params [data, spp, ...]."""
    spec_tree = mdef.stage_spec()
    return jax.tree_util.tree_map(
        lambda m: _marker_spec(m, ("data", None)), spec_tree)


def globals_specs(mdef: ModelDef):
    return jax.tree_util.tree_map(
        lambda m: _marker_spec(m, ()), mdef.globals_spec())


def stage_struct(mdef: ModelDef, pp: int, data_size: int,
                 dtype=jnp.bfloat16):
    """Global ShapeDtypeStructs for the stacked stage params."""
    per_stage = jax.eval_shape(
        lambda k: mdef.init_stage_params(k, 0, pp, dtype),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((data_size,) + s.shape, s.dtype),
        per_stage)


def globals_struct(mdef: ModelDef, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda k: mdef.init_globals(k, dtype),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def param_struct_and_specs(mdef: ModelDef, pp: int, data_size: int,
                           dtype=jnp.bfloat16):
    struct = {"stages": stage_struct(mdef, pp, data_size, dtype),
              "globals": globals_struct(mdef, dtype)}
    specs = {"stages": stage_specs(mdef, pp),
             "globals": globals_specs(mdef)}
    return struct, specs


def opt_specs(param_specs, *, zero1_pod: bool = False, param_struct=None,
              model_size: int = 16, pods: int = 2):
    """Moment shardings mirror the params; ZeRO-1 over the pod axis shards
    the 'model' dim jointly over ('model','pod') when requested — only for
    leaves whose dim remains divisible (small per-head vectors stay
    model-sharded)."""
    if not zero1_pod:
        return jax.tree_util.tree_map(lambda s: s, param_specs)

    def widen(spec: P, leaf=None):
        parts = list(spec)
        for i, ax in enumerate(parts):
            if ax == "model":
                if leaf is not None and leaf.shape[i] % (model_size * pods):
                    return spec
                parts[i] = ("model", "pod")
                return P(*parts)
        return spec

    if param_struct is not None:
        return jax.tree_util.tree_map(widen, param_specs, param_struct)
    return jax.tree_util.tree_map(widen, param_specs)


def shardings(mesh, specs, memory_kind: Optional[str] = None):
    def mk(spec):
        if memory_kind is not None:
            return NamedSharding(mesh, spec, memory_kind=memory_kind)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map(mk, specs)


def moment_shardings(mesh, opt_param_specs, *, offload_moments: bool = False,
                     host_kind="auto"):
    """NamedShardings for the AdamW moment trees (DESIGN.md §11): the
    param-mirroring specs from ``opt_specs``, committed to the backend's
    host memory kind when the plan offloads moments.  This is the sharding
    side of the executed path — apply_update's explicit H2D/D2H copies (or
    XLA's streaming, moments_mode="xla") are what move the bytes."""
    kind = None
    if offload_moments:
        from repro.runtime import hostmem
        kind = hostmem.resolve_host_kind(host_kind)
    return shardings(mesh, opt_param_specs, memory_kind=kind)


def count_params(mdef: ModelDef, pp: int, data_size: int) -> int:
    """Deduped parameter count (stage stack divided by dp replication)."""
    st = stage_struct(mdef, pp, data_size)
    gl = globals_struct(mdef)
    n_stage = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(st))
    n_stage = n_stage * pp // data_size
    n_glob = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(gl))
    return n_stage + n_glob


def count_active_params(mdef: ModelDef, pp: int, data_size: int) -> int:
    """MoE-aware active parameter count for MODEL_FLOPS = 6·N_active·D."""
    cfg = mdef.cfg
    total = count_params(mdef, pp, data_size)
    emb = L_embed_params(mdef)
    total -= emb
    if cfg.moe is None:
        return total
    st = stage_struct(mdef, pp, data_size)
    expert_leaves = ("w1", "w2", "w3")
    dense_of_experts = 0
    for name in expert_leaves:
        leaf = st["moe"][name] if "moe" in st else None
        if leaf is not None:
            dense_of_experts += int(np.prod(leaf.shape)) * pp // data_size
    active_frac = cfg.moe.top_k / cfg.moe.num_experts
    return total - dense_of_experts + int(dense_of_experts * active_frac)


def L_embed_params(mdef: ModelDef) -> int:
    gl = globals_struct(mdef)
    n = int(np.prod(gl["embed"]["table"].shape))
    if "pos" in gl:
        n += int(np.prod(gl["pos"]["table"].shape))
    return n
