"""Event-driven SPPO pipeline simulator (DESIGN.md §3).

Plays an arbitrary feed-event schedule — plain subsequence pipeline or the
MSP ramp schedule (core/schedule.py) — over per-chunk costs on a per-stage
timeline with four lanes per stage:

  compute  — forward then backward of every event, dependency-chained
             across stages (event e on stage s needs stage s−1's output);
  p2p      — inter-stage activation hand-off (serialized per link);
  d2h      — sequence-aware offload of each event's tagged activations,
             gated by the §5.2 memory recurrence: compute of event e may
             not start until the offload of event e−2 has drained (the
             "make-room" rule — chunk e−1's offload hides under e's
             compute, exactly M_i = M_{i-1} + A_i − α_{i-1}A_{i-1});
  h2d      — backward reloads, prefetched in reverse event order; the
             backward of event e waits for its own reload.

The closed forms in core/schedule.py assume bubbles only at the pipeline
ends; the per-tick playout exposes what they cannot see — steady-phase
resynchronization, queued transfers, unhidden-D2H stalls — which is why the
solver (core/solver.py) scores candidates here rather than with
``total_time``/``msp_total_time``.

Everything is plain floats: no jax, importable anywhere (CI runs it on CPU).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

from repro.core.schedule import msp_ramp_schedule

FWD = "fwd"
BWD = "bwd"
D2H = "d2h"
H2D = "h2d"
P2P = "p2p"
RING = "ring"


@dataclass(frozen=True)
class LaneEvent:
    """One occupied interval on one lane of one stage's timeline."""

    stage: int
    lane: str           # fwd | bwd | d2h | h2d | p2p
    chunk: int
    sub: int
    n_sub: int
    start: float
    end: float


@dataclass(frozen=True)
class SimResult:
    total: float                 # iteration wall time (last lane event end)
    feed_events: tuple           # (chunk, sub, n_sub) sequence fed to stage 0
    stage_busy: tuple            # per-stage compute-lane busy seconds
    fill_bubble: tuple           # per-stage idle before the first compute
    drain_bubble: tuple          # per-stage idle after the last compute
    d2h_stall: float             # compute delay charged to unhidden offload
    h2d_stall: float             # backward delay waiting on reloads
    p2p_stall: float             # compute delay from the hand-off *wire*
                                 # (transfer + link queuing; upstream compute
                                 # wait is fill_bubble, not p2p)
    peak_units: tuple            # per-stage forward-pass peak activation units
    peak_units_full: tuple       # per-stage peak over fwd+bwd (with reloads)
    trace: tuple                 # LaneEvent timeline, time-sorted
    ring_stall: float = 0.0      # compute delay from exposed ring-attention
                                 # KV rotation (DESIGN.md §15) — the per-hop
                                 # transfer time ring_overlap could not hide
                                 # under the hop compute

    @property
    def bubble_ratio(self) -> float:
        """Idle fraction of the aggregate compute timeline."""
        p = len(self.stage_busy)
        if self.total <= 0.0:
            return 0.0
        return 1.0 - sum(self.stage_busy) / (p * self.total)


def plain_events(n_chunks: int) -> List[Tuple[int, int, int]]:
    """Feed-event form of the plain schedule: every chunk whole, in order."""
    return [(c, 0, 1) for c in range(n_chunks)]


def _xfer(nbytes: float, bw: Optional[float]) -> float:
    if not nbytes or not bw:
        return 0.0
    if bw == float("inf"):
        return 0.0
    return nbytes / bw


def ring_overlap(hop_compute: Sequence[float],
                 hop_xfer: Sequence[float]
                 ) -> Tuple[float, float, list]:
    """Per-hop playout of one layer's ring attention (DESIGN.md §15).

    hop_compute[h]: tile-compute seconds of hop h (the slowest rank's share
    — costmodel.ring_hop_fractions).  hop_xfer[h]: ICI transfer seconds of
    hop h's KV block (hop 0's block is already resident, so 0).

    Double-buffer recurrence: the send of hop h+1's block is issued at hop
    h's compute *start* (the executed schedule issues the ppermute before
    the partial-attention call), the link serializes transfers, and hop h's
    compute cannot start before its block has arrived.  Returns
    (wall, exposed, events): wall = attention wall time, exposed = wall
    minus total compute (the stall the chunk's critical path inherits),
    events = (kind, hop, start, end) intervals for tracing."""
    n = len(hop_compute)
    arrive = [0.0] * n
    link_free = 0.0
    t = 0.0
    exposed = 0.0
    events = []
    for h in range(n):
        start = max(t, arrive[h])
        exposed += start - t
        events.append(("compute", h, start, start + hop_compute[h]))
        if h + 1 < n:
            s0 = max(link_free, start)
            arrive[h + 1] = s0 + hop_xfer[h + 1]
            link_free = arrive[h + 1]
            if hop_xfer[h + 1]:
                events.append(("xfer", h + 1, s0, arrive[h + 1]))
        t = start + hop_compute[h]
    return t, exposed, events


def simulate(events: Sequence[Tuple[int, int, int]],
             chunk_costs: Sequence[float],
             *,
             pp: int,
             chunk_acts: Optional[Sequence[float]] = None,
             alphas: Optional[Sequence[float]] = None,
             d2h_bw: Optional[float] = None,
             h2d_bw: Optional[float] = None,
             p2p_bytes: Optional[Sequence[float]] = None,
             ici_bw: Optional[float] = None,
             bwd_ratio: float = 2.0,
             prefetch: str = "ahead",
             off_wire_ratio: float = 1.0,
             ring_t: Optional[Sequence[float]] = None,
             ring_exposed: Optional[Sequence[float]] = None,
             ring_bwd_exposed: Optional[Sequence[float]] = None) -> SimResult:
    """Play `events` through a pp-stage pipeline.

    events: (chunk, sub, n_sub) feed order for stage 0 (see
        schedule.msp_ramp_schedule / plain_events).  A sub-event carries
        1/n_sub of its chunk's cost, activation bytes, and p2p payload.
    chunk_costs: per-stage fwd+bwd seconds per *whole* chunk (the solver's
        F(N)/N units: one chunk through one stage's layers).
    chunk_acts/alphas: per-chunk Type-1 activation units and offload ratios
        (§5.2); omit (or alphas of 0) to disable the offload lanes.
    p2p_bytes: per-chunk hand-off payload bytes; with ici_bw drives the p2p
        lane (omit for free hand-offs).
    bwd_ratio: backward/forward cost split of the lumped chunk cost
        (2.0 = the standard 2x-fwd backward; 0.0 = forward-only playout).
    prefetch: H2D reload placement, mirroring ``ParallelPlan.prefetch``
        (DESIGN.md §12) — "ahead": the memory-mirror rule, reload of event
        e issued at the backward *start* of event e+1, hidden under its
        compute; "sync": autodiff placement, reload of event e issued only
        when e's own backward is ready, fully exposed on the critical path.
    off_wire_ratio: compressed-residency lane multiplier (DESIGN.md §14,
        ``costmodel.offload_wire_ratio``) — scales only the D2H/H2D
        transfer *volumes*; the memory recurrence stays in raw device
        units because what materializes and drains on device is the
        uncompressed tagged set (dequantization reconstructs full rows).
    ring_t / ring_exposed / ring_bwd_exposed: the ring-attention lane
        (DESIGN.md §15), per chunk.  ring_t is the total KV-rotation wire
        seconds of the chunk's attention (all hops, all resident layers) —
        drawn as a "ring" lane interval concurrent with the chunk's
        compute.  ring_exposed / ring_bwd_exposed are the parts the
        per-hop playout (``ring_overlap``, run upstream by the solver)
        could NOT hide under hop compute: they extend the chunk's forward /
        backward compute and accumulate into ``ring_stall``.  (The backward
        re-rotates the blocks — the remat'd attention backward replays the
        ring — so it carries its own lane occupancy and exposure.)

    Forward runs events in feed order, backward in reverse (the runner
    differentiates an unrolled forward loop, so each stage finishes all
    forward work before its first backward — DESIGN.md §3).
    """
    assert prefetch in ("ahead", "sync"), prefetch
    events = list(events)
    ne = len(events)
    if ne == 0 or pp < 1:
        return SimResult(0.0, tuple(events), (0.0,) * pp, (0.0,) * pp,
                         (0.0,) * pp, 0.0, 0.0, 0.0, (0.0,) * pp,
                         (0.0,) * pp, ())
    n_chunks = len(chunk_costs)
    alphas = list(alphas) if alphas is not None else [0.0] * n_chunks
    acts = list(chunk_acts) if chunk_acts is not None else [0.0] * n_chunks
    h2d_bw = h2d_bw if h2d_bw is not None else d2h_bw

    f_frac = 1.0 / (1.0 + bwd_ratio)
    fcost = [chunk_costs[c] * f_frac / ns for c, _, ns in events]
    bcost = [chunk_costs[c] * (1.0 - f_frac) / ns for c, _, ns in events]
    off_t = [_xfer(off_wire_ratio * alphas[c] * acts[c] / ns, d2h_bw)
             for c, _, ns in events]
    rld_t = [_xfer(off_wire_ratio * alphas[c] * acts[c] / ns, h2d_bw)
             for c, _, ns in events]
    p2p_t = [_xfer((p2p_bytes[c] if p2p_bytes else 0.0) / ns, ici_bw)
             for c, _, ns in events]
    rng_t = [(ring_t[c] if ring_t else 0.0) / ns for c, _, ns in events]
    rexp_f = [(ring_exposed[c] if ring_exposed else 0.0) / ns
              for c, _, ns in events]
    rexp_b = [(ring_bwd_exposed[c] if ring_bwd_exposed else 0.0) / ns
              for c, _, ns in events]

    trace: List[LaneEvent] = []
    busy = [0.0] * pp
    first_start = [0.0] * pp
    last_end = [0.0] * pp
    d2h_stall = h2d_stall = p2p_stall = 0.0
    ring_stall = 0.0
    # per-stage memory deltas: (time, priority, delta, phase); priority 0
    # applies drains before materializations at timestamp ties, so an
    # offload that exactly fills its hiding window is credited before the
    # next-but-one chunk materializes — the recurrence ordering of
    # offload.peak_memory (peak_i counts drains of chunks <= i-2 only,
    # DESIGN.md §3.2).  phase 0 events bound the forward-pass peak.
    mem: List[List[Tuple[float, int, float, int]]] = [[] for _ in range(pp)]

    # ---- forward ----------------------------------------------------------
    fwd_end = [[0.0] * ne for _ in range(pp)]       # compute completion
    arrival = [[0.0] * ne for _ in range(pp)]       # input availability
    d2h_end = [[0.0] * ne for _ in range(pp)]       # offload completion
    for s in range(pp):
        comp_free = 0.0
        p2p_free = 0.0
        d2h_free = 0.0
        for e, (c, sub, ns) in enumerate(events):
            ready = max(comp_free, arrival[s][e])
            gate = d2h_end[s][e - 2] if e >= 2 else 0.0
            if gate > ready:
                d2h_stall += gate - ready
            if s > 0 and arrival[s][e] > max(comp_free, gate):
                # only the wire component (transfer + link queuing) counts
                # as hand-off stall; waiting on the upstream *compute* is
                # the ordinary fill bubble, reported separately
                wire = arrival[s][e] - fwd_end[s - 1][e]
                p2p_stall += min(wire, arrival[s][e] - max(comp_free, gate))
            start = max(ready, gate)
            end = start + fcost[e] + rexp_f[e]
            ring_stall += rexp_f[e]
            if rng_t[e]:
                trace.append(LaneEvent(s, RING, c, sub, ns, start,
                                       start + rng_t[e]))
            if e == 0:
                first_start[s] = start
            fwd_end[s][e] = end
            comp_free = end
            busy[s] += fcost[e]
            trace.append(LaneEvent(s, FWD, c, sub, ns, start, end))
            mem[s].append((start, 1, acts[c] / ns, 0))
            if s + 1 < pp:
                p_start = max(end, p2p_free)
                p_end = p_start + p2p_t[e]
                p2p_free = p_end
                arrival[s + 1][e] = p_end
                if p2p_t[e]:
                    trace.append(LaneEvent(s, P2P, c, sub, ns, p_start, p_end))
            if alphas[c] > 0.0:
                d_start = max(end, d2h_free)
                d_end = d_start + off_t[e]
                d2h_free = d_end
                d2h_end[s][e] = d_end
                trace.append(LaneEvent(s, D2H, c, sub, ns, d_start, d_end))
                mem[s].append((d_end, 0, -alphas[c] * acts[c] / ns, 0))

    # ---- backward ---------------------------------------------------------
    if bwd_ratio > 0.0:
        bwd_end = [[0.0] * ne for _ in range(pp)]
        barrive = [[0.0] * ne for _ in range(pp)]
        for s in range(pp - 1, -1, -1):
            comp_free = fwd_end[s][ne - 1]          # all fwd first, then bwd
            p2p_free = 0.0
            # the reload lane opens at the stage's first-*backward*
            # readiness, not its last forward: the runner's drain hand-off
            # (link_drain) issues the first H2D with the first cotangent,
            # which on stages < pp−1 arrives only after the downstream
            # backward + hand-off (barrive).  The old fwd_end init let
            # upstream stages pre-load during their drain bubble — a
            # placement the executed program has no dataflow for.
            bwd_ready0 = fwd_end[s][ne - 1]
            if s < pp - 1:
                bwd_ready0 = max(bwd_ready0, barrive[s][ne - 1])
            h2d_free = bwd_ready0
            h2d_done = [0.0] * ne
            prev_bwd_start = bwd_ready0
            for e in range(ne - 1, -1, -1):
                c, sub, ns = events[e]
                up = (fwd_end[s][e] if s == pp - 1 else barrive[s][e])
                ready = max(comp_free, up)
                if alphas[c] > 0.0:
                    if prefetch == "ahead":
                        # memory-mirror prefetch: reload of event e hides
                        # under the backward of event e+1 (whose activations
                        # are still resident), never earlier — keeps the
                        # backward peak bounded by the forward peak
                        # (DESIGN.md §3.2).
                        h_start = max(h2d_free, d2h_end[s][e],
                                      prev_bwd_start)
                    else:
                        # sync: autodiff places the reload inside event e's
                        # own remat replay — it cannot issue before e's
                        # backward is otherwise ready, and is fully exposed
                        h_start = max(h2d_free, d2h_end[s][e], ready)
                    h_end = h_start + rld_t[e]
                    h2d_free = h_end
                    h2d_done[e] = h_end
                    trace.append(LaneEvent(s, H2D, c, sub, ns, h_start, h_end))
                    mem[s].append((h_end, 1, alphas[c] * acts[c] / ns, 1))
                if alphas[c] > 0.0 and h2d_done[e] > ready:
                    h2d_stall += h2d_done[e] - ready
                start = max(ready, h2d_done[e])
                prev_bwd_start = start
                end = start + bcost[e] + rexp_b[e]
                ring_stall += rexp_b[e]
                if rng_t[e]:
                    trace.append(LaneEvent(s, RING, c, sub, ns, start,
                                           start + rng_t[e]))
                bwd_end[s][e] = end
                comp_free = end
                busy[s] += bcost[e]
                trace.append(LaneEvent(s, BWD, c, sub, ns, start, end))
                mem[s].append((end, 0, -acts[c] / ns, 1))
                if s > 0:
                    p_start = max(end, p2p_free)
                    p_end = p_start + p2p_t[e]
                    p2p_free = p_end
                    barrive[s - 1][e] = p_end
                    if p2p_t[e]:
                        trace.append(
                            LaneEvent(s, P2P, c, sub, ns, p_start, p_end))
        for s in range(pp):
            last_end[s] = bwd_end[s][0]
    else:
        for s in range(pp):
            last_end[s] = fwd_end[s][ne - 1]

    total = max(ev.end for ev in trace)
    peaks_fwd, peaks_full = [], []
    for s in range(pp):
        m = peak_f = peak = 0.0
        for _, _, delta, phase in sorted(mem[s], key=lambda x: (x[0], x[1])):
            m += delta
            peak = max(peak, m)
            if phase == 0:
                peak_f = max(peak_f, m)
        peaks_fwd.append(peak_f)
        peaks_full.append(peak)
    trace.sort(key=lambda ev: (ev.start, ev.stage, ev.lane))
    return SimResult(
        total=total,
        feed_events=tuple(events),
        stage_busy=tuple(busy),
        fill_bubble=tuple(first_start),
        drain_bubble=tuple(total - t for t in last_end),
        d2h_stall=d2h_stall,
        h2d_stall=h2d_stall,
        p2p_stall=p2p_stall,
        peak_units=tuple(peaks_fwd),
        peak_units_full=tuple(peaks_full),
        trace=tuple(trace),
        ring_stall=ring_stall,
    )


def simulate_schedule(chunk_costs: Sequence[float], *, pp: int,
                      msp: bool = False, split: int = 2,
                      **kw) -> SimResult:
    """Convenience wrapper: plain or MSP-ramp feed events over `chunk_costs`."""
    n = len(chunk_costs)
    ev = msp_ramp_schedule(n, pp, split) if msp and pp > 1 else plain_events(n)
    return simulate(ev, chunk_costs, pp=pp, **kw)


def opt_update_transfer(n_params_local: int, moment_bytes_per_param: float,
                        d2h_bw: Optional[float],
                        h2d_bw: Optional[float] = None) -> float:
    """Post-step optimizer-transfer time for host-resident AdamW moments
    (DESIGN.md §11): the update stages one H2D of the full local moment set
    onto the device and one D2H writes the new moments back.  Unlike the
    activation offload of §5.2 there is no next-chunk compute left to hide
    under — the last backward has already drained — so the solver charges
    the full round trip as an epilogue on the iteration time."""
    vol = n_params_local * moment_bytes_per_param
    h2d_bw = h2d_bw if h2d_bw is not None else d2h_bw
    return _xfer(vol, h2d_bw) + _xfer(vol, d2h_bw)


def spmd_tick_peak(events: Sequence[Tuple[int, int, int]], *, pp: int,
                   chunk_acts: Sequence[float],
                   alphas: Sequence[float],
                   chunk_scales: Optional[Sequence[float]] = None
                   ) -> Tuple[float, list]:
    """Predicted §5.2 memory recurrence of the *lock-step SPMD* tick loop
    (parallel/runner.py, pp > 1): every stage materializes one tagged set
    per tick — including the pp−1 drain ticks, which replay the last feed
    event's chunk (masked compute, real allocation), and MSP sub-events,
    which rematerialize their full chunk (DESIGN.md §2).  This is the
    apples-to-apples prediction for the memledger's measured per-tick
    ledger; the per-stage event playout above (`simulate`) remains the
    idealized pipeline target.  Returns (peak, per-tick resident).

    chunk_scales: per-chunk device-resident codec scale bytes of the rows
    that offload (DESIGN.md §14) — they materialize with the chunk like
    its activations but never drain with the off rows (they stay on device
    until the backward consumes them); caller pre-multiplies by the
    deployed (quantized) α, mirroring how the off-bytes drain is scaled."""
    events = list(events)
    ne = len(events)
    if ne == 0:
        return 0.0, []
    scales = (list(chunk_scales) if chunk_scales is not None
              else [0.0] * len(chunk_acts))
    n_ticks = ne + max(pp, 1) - 1
    resident = []
    m = 0.0
    prev_off = 0.0
    peak = 0.0
    for t in range(n_ticks):
        c = events[min(t, ne - 1)][0]
        a = chunk_acts[c]
        m += a + scales[c]
        peak = max(peak, m)
        resident.append(m)
        m -= prev_off
        prev_off = alphas[c] * a
    return peak, resident
