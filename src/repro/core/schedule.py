"""SPPO adaptive pipeline schedule (§6): ticks, bubbles, MSP (Defs 6.1/6.2).

The subsequence pipeline: stage s processes chunk c = t − s at tick t,
t ∈ [0, N + pp − 1).  Bubble model (§3.3):
    t_b = (p−1)·F(N)/N,   R_b = (p−1)/N,   T = (p−1+N)/N · F(N).

Multiplexed sequence partitioning (§6.2) is implemented two ways:
  * the paper's phase tables (Definition 6.1/6.2) verbatim — property-tested;
  * an executable *ramp-chunk* schedule for the SPMD pipeline: the
    bubble-adjacent chunks (the first and last pp−1) are split into `split`
    sub-chunks processed at 1/split duration, so fill/drain bubbles shrink
    from (p−1)·F/N to (p−1)·F/(split·N) — DESIGN.md §2 records why the
    per-stage-divergent original formulation is adapted this way for TPU.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Set, Tuple


# ---------------------------------------------------------------------------
# Bubble model (§3.3)
# ---------------------------------------------------------------------------


def bubble_ratio(pp: int, n: int) -> float:
    return (pp - 1) / n


def total_time(pp: int, n: int, f_n: float) -> float:
    """T = (p−1+N)/N · F(N)."""
    return (pp - 1 + n) / n * f_n


# ---------------------------------------------------------------------------
# Tick schedule
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Tick:
    """One pipeline tick. chunk_of(stage) = tick − stage (None if idle)."""

    index: int
    sub: int = 0          # MSP sub-chunk index within the chunk
    n_sub: int = 1        # number of sub-chunks this tick's chunk is split into


def ticks(n_chunks: int, pp: int) -> List[int]:
    """Plain SPPO schedule: tick t feeds chunk t into stage 0."""
    return list(range(n_chunks + pp - 1))


def chunk_at(tick: int, stage: int, n_chunks: int):
    c = tick - stage
    return c if 0 <= c < n_chunks else None


def msp_ramp_schedule(n_chunks: int, pp: int, split: int = 2
                      ) -> List[Tuple[int, int, int]]:
    """Executable MSP: list of (chunk, sub, n_sub) feed events for stage 0.

    The first and last (pp−1) chunks are split into `split` sub-chunks;
    steady chunks are whole.  Fill/drain bubble cost scales by 1/split."""
    ramp = min(pp - 1, n_chunks // 2)
    events = []
    for c in range(n_chunks):
        if c < ramp or c >= n_chunks - ramp:
            events.extend((c, s, split) for s in range(split))
        else:
            events.append((c, 0, 1))
    return events


def msp_total_time(pp: int, n: int, f_n: float, split: int = 2) -> float:
    """Analytic cost of the ramp schedule: steady ticks cost F/N, ramp
    sub-ticks cost F/(N·split); bubbles are (pp−1) sub-ticks on each side."""
    per_chunk = f_n / n
    ramp = min(pp - 1, n // 2)
    steady = (n - 2 * ramp) * per_chunk
    ramp_t = 2 * ramp * per_chunk            # same total work, split finer
    bubble = (pp - 1) * per_chunk / split
    return steady + ramp_t + bubble


# ---------------------------------------------------------------------------
# Paper Definitions 6.1 / 6.2 — phase ID mapping and communication scope
# ---------------------------------------------------------------------------


def left_sp_ids(pp: int, n: int, stage: int) -> Set[int]:
    """Subsequences stage handles in its Left-SP (fill-bubble) phase:
    {0 .. PP−2−stage} (Table 3)."""
    return set(range(0, pp - 1 - stage))


def right_sp_ids(pp: int, n: int, stage: int) -> Set[int]:
    """Right-SP (drain-bubble) phase: {N−stage .. N−1} (Table 3)."""
    return set(range(max(0, n - stage), n))


def steady_ids(pp: int, n: int, stage: int) -> Set[int]:
    """Steady phase (adaptive offloading): {PP−1−stage .. N−1−stage}."""
    return set(range(pp - 1 - stage, n - stage))


def comm_scope(pp: int, stage: int, phase: str) -> Set[int]:
    """Def 6.2: inter-stage communication range C(i) per phase."""
    if phase == "left":
        return set(range(stage, pp))
    if phase == "steady":
        return set(range(0, pp))
    if phase == "right":
        return set(range(0, stage + 1))
    raise ValueError(phase)


def msp_phase_table(pp: int, n: int) -> dict:
    """Reproduces Table 3 of the paper for arbitrary (PP, N)."""
    table = {}
    for s in range(pp):
        left = left_sp_ids(pp, n, s)
        right = right_sp_ids(pp, n, s)
        steady = steady_ids(pp, n, s)
        table[s] = {
            "left": left,
            "steady": steady,
            "right": right,
            "left_sp_range": comm_scope(pp, s, "left") if left else set(),
            "right_sp_range": comm_scope(pp, s, "right") if right else set(),
        }
    return table
