"""Seeded-mutation registry for the contract auditor (DESIGN.md §17).

The auditor's rules are only trustworthy if they demonstrably *bite*, so
``tests/mutants/`` re-introduces each historical bug class on demand and
asserts the expected finding id fires.  Mutations are plain process-local
flags checked at the (few) trace-construction sites they perturb; nothing
here runs in production paths — when no mutation is enabled every hook is
a single falsy set-membership test on an empty set.

Known mutations (each maps to one documented finding id):

  drain-tick-write    — skip the PR 9 tick-validity mask on pipeline state
                        (runner tick loop)            → R4-unmasked-state
  double-d2h          — offload each captured activation twice
                        (runner capture)              → R1-d2h-count
  unnamed-scale       — drop the checkpoint name from the quant scale
                        (runner capture)              → R5-codec-pairing
  scale-offloaded     — push the fp32 scale to host memory
                        (runner capture)              → R2-scale-placement
  fp8-named-residual  — skip the PR 7 int8 bitcast so a float8 payload is
                        named inside remat (offload.host_round_trip)
                                                      → R5-inexact-residual

The sixth corpus member, the sync-reload overlap hazard
(→ R3-overlap-hazard), needs no code mutation: it is the real
``prefetch="sync"`` plan, seeded by a plan override alone.
"""
from __future__ import annotations

from contextlib import contextmanager

KNOWN = frozenset({
    "drain-tick-write",
    "double-d2h",
    "unnamed-scale",
    "scale-offloaded",
    "fp8-named-residual",
})

_active: set = set()


def _check(name: str) -> str:
    if name not in KNOWN:
        raise ValueError(f"unknown mutation {name!r}; known: {sorted(KNOWN)}")
    return name


def active(name: str) -> bool:
    return name in _active


def enable(name: str) -> None:
    _active.add(_check(name))


def disable(name: str) -> None:
    _active.discard(name)


def reset() -> None:
    _active.clear()


@contextmanager
def seeded(name: str):
    """Enable one mutation for the duration of a block (test scaffolding)."""
    enable(name)
    try:
        yield
    finally:
        disable(name)
