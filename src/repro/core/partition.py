"""SPPO sequence partitioning (§3.2, §5.2): length-based vs FLOPs-balanced.

For causal attention the per-token cost grows with position: processing
tokens [a, b) of a sequence costs
    F(a, b) = c_lin * (b - a) + c_attn * (b^2 - a^2) / 2
(linear projections/MLP + the causal attention triangle).  A *length-based*
partition (equal token counts) therefore has imbalanced chunk compute, while
the paper's *FLOPs-balanced* partition solves for boundaries with equal
F(a,b) — earlier chunks are longer in tokens, so their activation volume
(∝ tokens) is larger: Figure 4/5's imbalance, which the sequence-aware
offload ratio (core/offload.py) absorbs.

For attention-free token mixers (RWKV) the profile is linear and the two
policies coincide (``flops_profile="linear"``) — DESIGN.md §5.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np


@dataclass(frozen=True)
class ChunkSchedule:
    """Static per-sequence chunk plan."""

    lengths: tuple            # tokens per chunk
    offsets: tuple            # start position per chunk
    seq_len: int
    policy: str

    @property
    def n(self) -> int:
        return len(self.lengths)


def flops_per_token_ratio(cfg) -> float:
    """c_attn / c_lin: relative weight of the position-dependent attention
    term vs the position-independent (projections + MLP) term, per layer."""
    d = cfg.d_model
    lin = 12 * d * d  # rough per-token matmul cost (qkv+o+mlp), scale-free
    if cfg.family == "ssm":
        return 0.0
    attn = 4 * cfg.n_heads * cfg.hd  # per (token, kv-token) qk+av cost
    return attn / lin


def chunk_cost(a: int, b: int, r: float) -> float:
    """Relative cost of processing tokens [a, b) causally; r = c_attn/c_lin."""
    return (b - a) + r * (b * b - a * a) / 2.0


def _clamp_chunks(seq_len: int, n: int, multiple: int) -> int:
    """Largest feasible chunk count: every chunk needs >= max(multiple, 1)
    tokens, so n*multiple > seq_len degrades to fewer chunks, never to
    zero-length (or negative) chunks."""
    return max(1, min(n, seq_len // max(multiple, 1)))


def partition_length(seq_len: int, n: int, multiple: int = 1) -> ChunkSchedule:
    n = _clamp_chunks(seq_len, n, multiple)
    if n == 1:  # single chunk: the multiple constraint is vacuous
        return ChunkSchedule((seq_len,), (0,), seq_len, "length")
    # base >= multiple by the feasibility clamp (n <= seq_len // multiple);
    # the last chunk absorbs the non-divisible remainder.
    base = seq_len // n // max(multiple, 1) * max(multiple, 1)
    lens = [base] * n
    lens[-1] += seq_len - base * n
    offs = [sum(lens[:i]) for i in range(n)]
    return ChunkSchedule(tuple(lens), tuple(offs), seq_len, "length")


def partition_flops(seq_len: int, n: int, r: float,
                    multiple: int = 1) -> ChunkSchedule:
    """FLOPs-balanced boundaries: F(0, b_1) = F(b_1, b_2) = ... (§4 workflow).

    Solve F(0, b_i) = (i/n) * F(0, S) for each boundary:
        b + r b^2/2 = (i/n)(S + r S^2/2)   (quadratic in b).
    Boundaries are rounded to ``multiple`` (sequence-shard divisibility).
    """
    n = _clamp_chunks(seq_len, n, multiple)
    if r <= 0 or n == 1:
        return partition_length(seq_len, n, multiple)
    total = chunk_cost(0, seq_len, r)
    bounds = [0]
    mult = max(multiple, 1)
    for i in range(1, n):
        target = total * i / n
        # solve r/2 b^2 + b - target = 0
        b = (-1 + math.sqrt(1 + 2 * r * target)) / r
        b = int(round(b / mult)) * mult
        # lower clamp first, upper clamp last.  The cap reserves >= mult
        # tokens per remaining chunk *in aligned units*: with a non-divisible
        # seq_len, `seq_len - (n - i) * mult` is itself unaligned and would
        # leak a misaligned interior boundary (e.g. S=37, mult=16 -> 21).
        # bounds[i-1] + mult never exceeds the cap once n is feasibility-
        # clamped, so by induction every length stays positive and every
        # interior boundary stays multiple-aligned; only the last chunk
        # absorbs the remainder.
        b = min((seq_len // mult - (n - i)) * mult, max(b, bounds[-1] + mult))
        bounds.append(b)
    bounds.append(seq_len)
    lens = tuple(bounds[i + 1] - bounds[i] for i in range(n))
    assert all(l > 0 for l in lens) and sum(lens) == seq_len
    return ChunkSchedule(lens, tuple(bounds[:-1]), seq_len, "flops")


def partition(seq_len: int, n: int, cfg, policy: str = "flops",
              multiple: int = 1) -> ChunkSchedule:
    n = max(1, min(n, seq_len // max(multiple, 1)))  # feasibility clamp
    r = flops_per_token_ratio(cfg)
    if policy == "flops" and r > 0 and n > 1:
        return partition_flops(seq_len, n, r, multiple)
    return partition_length(seq_len, n, multiple)


def chunk_costs(sched: ChunkSchedule, r: float) -> List[float]:
    return [chunk_cost(a, a + l, r)
            for a, l in zip(sched.offsets, sched.lengths)]


# ---------------------------------------------------------------------------
# Packed variable-length layouts (FlexSP / Seq1F1B adaptation, DESIGN.md §13)
# ---------------------------------------------------------------------------
#
# A packed batch keeps each document contiguous inside a fixed-width row of
# ``seq_len`` tokens (tail padding only).  Causal attention restarts at every
# document boundary, so the per-position cost profile is sawtoothed — a token
# at in-document offset d costs 1 + r*d (its causal window is d+1 tokens) —
# instead of the single triangle the uniform-sequence partitioner assumes.
# ``packed_cost_profile`` materializes that profile summed over the batch
# rows and ``partition_profile`` equalizes its cumulative sum (the Seq1F1B
# FLOPs-balance generalized to arbitrary profiles), snapping boundaries to
# nearby aligned document boundaries where possible.


def pack_lengths(lengths: Sequence[int], seq_len: int) -> List[List[int]]:
    """Greedy first-fit-decreasing bin packing of document lengths into rows
    of ``seq_len`` tokens.  Returns, per row, the list of *document indices*
    (into ``lengths``) in placement order.  Every document is placed exactly
    once — no drops, no duplicates, no splits (each length must fit a row)."""
    order = sorted(range(len(lengths)), key=lambda i: (-lengths[i], i))
    rows: List[List[int]] = []
    free: List[int] = []
    for i in order:
        ln = int(lengths[i])
        assert 0 < ln <= seq_len, f"doc {i} length {ln} vs row {seq_len}"
        for rix, f in enumerate(free):
            if f >= ln:
                rows[rix].append(i)
                free[rix] -= ln
                break
        else:
            rows.append([i])
            free.append(seq_len - ln)
    return rows


def packed_cost_profile(row_lens: Sequence[Sequence[int]], seq_len: int,
                        r: float) -> np.ndarray:
    """Per-position relative cost [seq_len] of a packed batch, summed over
    rows.  ``row_lens[row]`` lists the document lengths packed into that row
    (contiguous, in order, tail-padded).  A real token at in-document offset
    d costs 1 + r*d; padding positions cost 1 (they still ride the dense
    projections/MLP) with no attention term (fully masked)."""
    prof = np.zeros(seq_len, dtype=np.float64)
    for lens in row_lens:
        pos = 0
        for ln in lens:
            ln = int(ln)
            prof[pos:pos + ln] += 1.0 + r * np.arange(ln, dtype=np.float64)
            pos += ln
        assert pos <= seq_len, f"row overflows: {sum(lens)} > {seq_len}"
        prof[pos:] += 1.0
    return prof


def partition_profile(profile: Sequence[float], n: int, multiple: int = 1,
                      doc_bounds: Optional[Sequence[int]] = None
                      ) -> ChunkSchedule:
    """Chunk boundaries equalizing the cumulative cost ``profile`` (the
    packed-layout generalization of :func:`partition_flops`).  Boundaries
    are rounded to ``multiple``; when ``doc_bounds`` (global positions where
    a document starts in every row of the packed layout) offers an aligned
    boundary near the cost-balanced one, it is preferred so chunks respect
    document boundaries where possible."""
    prof = np.asarray(profile, dtype=np.float64)
    seq_len = int(prof.shape[0])
    n = _clamp_chunks(seq_len, n, multiple)
    if n == 1:
        return ChunkSchedule((seq_len,), (0,), seq_len, "flops-packed")
    mult = max(multiple, 1)
    cum = np.cumsum(prof)
    total = float(cum[-1])
    aligned_docs = sorted(int(b) for b in (doc_bounds or ())
                          if 0 < b < seq_len and b % mult == 0)
    bounds = [0]
    for i in range(1, n):
        target = total * i / n
        b = int(np.searchsorted(cum, target)) + 1
        b = int(round(b / mult)) * mult
        # aligned cap, as in partition_flops: keep interior boundaries on
        # the multiple even when seq_len % mult != 0
        lo, hi = bounds[-1] + mult, (seq_len // mult - (n - i)) * mult
        b = min(hi, max(b, lo))
        # prefer a document boundary within half a mean chunk of the
        # balanced position (it can only cost a bounded imbalance)
        window = max(mult, seq_len // (2 * n))
        cand = [d for d in aligned_docs if lo <= d <= hi
                and abs(d - b) <= window]
        if cand:
            b = min(cand, key=lambda d: abs(d - b))
        bounds.append(b)
    bounds.append(seq_len)
    lens = tuple(bounds[i + 1] - bounds[i] for i in range(n))
    assert all(l > 0 for l in lens) and sum(lens) == seq_len
    return ChunkSchedule(lens, tuple(bounds[:-1]), seq_len, "flops-packed")


def aligned_doc_bounds(row_lens: Sequence[Sequence[int]],
                       seq_len: int) -> List[int]:
    """Positions that are document boundaries in *every* row of the packed
    layout — a chunk cut there never splits a document.  A row's tail
    padding region counts as all-boundary (cutting padding is free)."""
    common: Optional[set] = None
    for lens in row_lens:
        lens = [int(l) for l in lens]
        cuts = set(np.cumsum(lens).tolist()) if lens else set()
        cuts |= set(range(sum(lens), seq_len + 1))
        common = cuts if common is None else (common & cuts)
    return sorted(b for b in (common or ()) if 0 < b < seq_len)


def profile_chunk_costs(profile: Sequence[float],
                        sched: ChunkSchedule) -> List[float]:
    """Per-chunk cost sums of a packed-layout profile under ``sched``."""
    prof = np.asarray(profile, dtype=np.float64)
    return [float(prof[a:a + l].sum())
            for a, l in zip(sched.offsets, sched.lengths)]


def imbalance(values: Sequence[float]) -> float:
    """max/mean ratio — 1.0 == perfectly balanced (Fig. 4/5 metric)."""
    values = list(values)
    return max(values) / (sum(values) / len(values))
