"""SPPO sequence partitioning (§3.2, §5.2): length-based vs FLOPs-balanced.

For causal attention the per-token cost grows with position: processing
tokens [a, b) of a sequence costs
    F(a, b) = c_lin * (b - a) + c_attn * (b^2 - a^2) / 2
(linear projections/MLP + the causal attention triangle).  A *length-based*
partition (equal token counts) therefore has imbalanced chunk compute, while
the paper's *FLOPs-balanced* partition solves for boundaries with equal
F(a,b) — earlier chunks are longer in tokens, so their activation volume
(∝ tokens) is larger: Figure 4/5's imbalance, which the sequence-aware
offload ratio (core/offload.py) absorbs.

For attention-free token mixers (RWKV) the profile is linear and the two
policies coincide (``flops_profile="linear"``) — DESIGN.md §5.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Sequence


@dataclass(frozen=True)
class ChunkSchedule:
    """Static per-sequence chunk plan."""

    lengths: tuple            # tokens per chunk
    offsets: tuple            # start position per chunk
    seq_len: int
    policy: str

    @property
    def n(self) -> int:
        return len(self.lengths)


def flops_per_token_ratio(cfg) -> float:
    """c_attn / c_lin: relative weight of the position-dependent attention
    term vs the position-independent (projections + MLP) term, per layer."""
    d = cfg.d_model
    lin = 12 * d * d  # rough per-token matmul cost (qkv+o+mlp), scale-free
    if cfg.family == "ssm":
        return 0.0
    attn = 4 * cfg.n_heads * cfg.hd  # per (token, kv-token) qk+av cost
    return attn / lin


def chunk_cost(a: int, b: int, r: float) -> float:
    """Relative cost of processing tokens [a, b) causally; r = c_attn/c_lin."""
    return (b - a) + r * (b * b - a * a) / 2.0


def partition_length(seq_len: int, n: int, multiple: int = 1) -> ChunkSchedule:
    if n == 1:  # single chunk: the multiple constraint is vacuous
        return ChunkSchedule((seq_len,), (0,), seq_len, "length")
    assert seq_len % (n * multiple) == 0 or multiple == 1, \
        f"seq {seq_len} not divisible into {n} chunks of multiple {multiple}"
    base = seq_len // n
    base = base // multiple * multiple
    lens = [base] * n
    lens[-1] += seq_len - base * n
    offs = [sum(lens[:i]) for i in range(n)]
    return ChunkSchedule(tuple(lens), tuple(offs), seq_len, "length")


def partition_flops(seq_len: int, n: int, r: float,
                    multiple: int = 1) -> ChunkSchedule:
    """FLOPs-balanced boundaries: F(0, b_1) = F(b_1, b_2) = ... (§4 workflow).

    Solve F(0, b_i) = (i/n) * F(0, S) for each boundary:
        b + r b^2/2 = (i/n)(S + r S^2/2)   (quadratic in b).
    Boundaries are rounded to ``multiple`` (sequence-shard divisibility).
    """
    if r <= 0:
        return partition_length(seq_len, n, multiple)
    total = chunk_cost(0, seq_len, r)
    bounds = [0]
    for i in range(1, n):
        target = total * i / n
        # solve r/2 b^2 + b - target = 0
        b = (-1 + math.sqrt(1 + 2 * r * target)) / r
        b = int(round(b / multiple)) * multiple
        b = max(bounds[-1] + multiple, min(b, seq_len - (n - i) * multiple))
        bounds.append(b)
    bounds.append(seq_len)
    lens = tuple(bounds[i + 1] - bounds[i] for i in range(n))
    assert all(l > 0 for l in lens) and sum(lens) == seq_len
    return ChunkSchedule(lens, tuple(bounds[:-1]), seq_len, "flops")


def partition(seq_len: int, n: int, cfg, policy: str = "flops",
              multiple: int = 1) -> ChunkSchedule:
    n = max(1, min(n, seq_len // max(multiple, 1)))  # feasibility clamp
    r = flops_per_token_ratio(cfg)
    if policy == "flops" and r > 0 and n > 1:
        return partition_flops(seq_len, n, r, multiple)
    return partition_length(seq_len, n, multiple)


def chunk_costs(sched: ChunkSchedule, r: float) -> List[float]:
    return [chunk_cost(a, a + l, r)
            for a, l in zip(sched.offsets, sched.lengths)]


def imbalance(values: Sequence[float]) -> float:
    """max/mean ratio — 1.0 == perfectly balanced (Fig. 4/5 metric)."""
    values = list(values)
    return max(values) / (sum(values) / len(values))
