"""Cost model: TPU v5e hardware constants + FLOPs/bytes/time estimators.

Used by the heuristic solver (§6.1), the offload-ratio solver (§5.2), the
analytic benchmarks (Figs. 7, 10–12) and the roofline report.  Everything is
per-chip unless stated.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np


@dataclass(frozen=True)
class Hardware:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12     # per chip
    hbm_bw: float = 819e9               # bytes/s per chip
    ici_bw: float = 50e9                # bytes/s per link (brief's constant)
    d2h_bw: float = 32e9                # host offload link (paper's testbed: 32 GB/s PCIe)
    hbm_bytes: float = 16 * 2**30       # v5e: 16 GiB
    host_bytes_per_chip: float = 48 * 2**30
    kernel_launch_us: float = 3.0       # per-op overhead for tiny chunks (§3.3)


V5E = Hardware()

# backward/forward FLOPs split of the lumped 6N train convention (2N fwd,
# 4N bwd): the D2H hiding window of §5.2 is the *forward* compute of the
# next chunk, so offload planning divides lumped chunk times by (1 + this)
BWD_RATIO = 2.0

# The recompute-based flash backward (kernels/flash_attention.py) runs five
# MXU passes over each score tile — QK^T recompute, dV = P^T dO, dP = dO V^T,
# dQ = dS K, dK = dS^T Q — against the forward's two (QK^T, PV), so the
# attention share of a chunk's FLOPs has a bwd/fwd ratio of 5/2, not the
# matmul convention's 4N/2N = 2.  effective_bwd_ratio blends the two by the
# attention fraction of forward compute.
ATTN_BWD_RATIO = 2.5

# A100-80G — used to sanity-check the paper's own numbers (Figs. 10-12)
A100 = Hardware(name="a100-80g", peak_flops_bf16=312e12, hbm_bw=2039e9,
                ici_bw=300e9, d2h_bw=32e9, hbm_bytes=80 * 2**30)


# ---------------------------------------------------------------------------
# Parameter / FLOPs accounting
# ---------------------------------------------------------------------------


def param_count(struct, *, exclude=("embed", "pos")) -> int:
    """Total parameter count from a (possibly nested) dict of
    ShapeDtypeStructs/arrays; top-level keys in `exclude` are skipped
    (MFU convention: 6·N uses non-embedding params)."""
    total = 0
    for key, sub in struct.items():
        if key in exclude:
            continue
        for leaf in jax.tree_util.tree_leaves(sub):
            total += int(np.prod(leaf.shape))
    return total


def dedup_stage_stack(n: int, data_size: int, pp: int) -> float:
    """Params stacked [data_size, ...] hold dp duplicates of each stage;
    scale raw counts by pp/data_size to get true (deduped) parameters."""
    return n * pp / data_size


def attn_flops(batch: int, seq: int, n_heads: int, hd: int,
               *, causal: bool = True, kv_len: int = None) -> float:
    """QK^T + AV flops for one layer's attention (fwd)."""
    kv = kv_len if kv_len is not None else seq
    pairs = batch * seq * kv * (0.5 if causal and kv == seq else 1.0)
    return 4 * pairs * n_heads * hd


def attn_bwd_flops(batch: int, seq: int, n_heads: int, hd: int,
                   *, causal: bool = True, kv_len: int = None) -> float:
    """dq/dk/dv matmul flops for one layer's attention backward
    (recompute-based flash: 5 MXU passes over the score tiles)."""
    return ATTN_BWD_RATIO * attn_flops(batch, seq, n_heads, hd,
                                       causal=causal, kv_len=kv_len)


def attn_bwd_bytes(batch: int, seq_q: int, kv_len: int, n_heads: int,
                   n_kv_heads: int, hd_k: int, hd_v: int,
                   *, io_bytes: int = 2) -> float:
    """HBM traffic of the two backward grids (dq pass + dkv pass): each
    streams q, k, v, dO and the (m, dl) row stats once and writes its own
    gradients.  Nothing S×S is ever resident — the score/probability tiles
    are recomputed in VMEM from the saved logsumexp statistic."""
    q_b = batch * seq_q * n_heads * hd_k * io_bytes
    do_b = batch * seq_q * n_heads * hd_v * 4          # dO/o are fp32
    kv_b = batch * kv_len * n_kv_heads * (hd_k + hd_v) * io_bytes
    stats = 2 * batch * seq_q * n_heads * 4            # m + dl rows, fp32
    reads = 2 * (q_b + do_b + kv_b + stats)
    # dq + dk/dv are emitted fp32 by the kernels (the caller downcasts)
    writes = (q_b + kv_b) * 4 // io_bytes
    return reads + writes


def effective_bwd_ratio(attn_frac: float) -> float:
    """Lumped bwd/fwd time ratio for a chunk whose forward FLOPs are
    `attn_frac` attention: matmuls follow the 4N/2N = 2 convention, the
    recompute-based attention backward costs 2.5x its forward."""
    attn_frac = min(1.0, max(0.0, attn_frac))
    return BWD_RATIO * (1.0 - attn_frac) + ATTN_BWD_RATIO * attn_frac


def model_flops_per_token(n_params: int, *, train: bool) -> float:
    """The 6·N (train) / 2·N (inference) matmul convention."""
    return (6 if train else 2) * n_params


# ---------------------------------------------------------------------------
# Type-1 (tagged) activation bytes — the offload planner's unit of account
# ---------------------------------------------------------------------------

# bf16 activations everywhere the tags fire
ACT_ITEMSIZE = 2


def tagged_bytes_per_token(cfg) -> float:
    """Per-layer bytes/token of the *tagged* Type-1 set — exactly the
    tensors the slot programs route through ``name_tag`` (models/*.py):

      dense/vlm/audio: q, k, v, attention out, MLP hidden
      moe:             q, k, v (or MLA q_eff/k_eff/o_v), routed expert hidden
      ssm/hybrid:      mixer inputs/outputs (expand·d per site)

    This replaces the earlier lumped 34·d estimate, which priced the *full*
    per-layer activation set (attention probabilities included) and so
    overstated the offloadable volume several-fold; the memledger
    (runtime/memledger.py) measures the real tagged bytes and CI's
    memory-gate keeps this estimate honest within its tolerance."""
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    if cfg.mla is not None:
        m = cfg.mla
        eff = m.kv_lora_rank + m.rope_head_dim
        attn = H * eff + eff + H * m.v_head_dim       # q_eff, k_eff, o_v
    else:
        attn = H * hd + 2 * Hkv * hd + H * hd         # q, k, v, out
    if cfg.moe is not None:
        mlp = cfg.moe.top_k * cfg.moe.d_ff_expert
        mlp += cfg.moe.n_shared_experts * cfg.moe.d_ff_expert
    else:
        mlp = cfg.d_ff
    if cfg.family in ("ssm", "hybrid"):
        # mamba2/rwkv tag the expanded mixer input and output
        expand = cfg.ssm.expand if cfg.ssm is not None else 2
        attn, mlp = expand * d, expand * d
    return (attn + mlp) * ACT_ITEMSIZE


def tagged_scale_elems_per_token(cfg) -> float:
    """Per-layer *scale elements* per token of the compressed channel
    (DESIGN.md §14): quantization is per-row over each tagged tensor's
    trailing axis, so every tag site contributes one fp32 scale per
    trailing-axis row per token —

      q [B,T,H,hd] -> H, k/v [B,T,Hkv,hd] -> Hkv each,
      attention out [B,T,H*hd] -> 1, MLP hidden [B,T,d_ff] -> 1

    (MLA tags q_eff/k_eff/o_v reshaped to per-head rows analogously; the
    ssm/hybrid mixer tensors are [B,T,expand*d] -> 1 per site)."""
    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    if cfg.mla is not None:
        attn = H + 1 + H                              # q_eff, k_eff, o_v
    else:
        attn = H + 2 * Hkv + 1                        # q, k, v, out
    mlp = 1.0
    if cfg.family in ("ssm", "hybrid"):
        attn, mlp = 1.0, 1.0
    return float(attn + mlp)


SCALE_ITEMSIZE = 4  # per-row scales are fp32


def codec_itemsize(offload_dtype: str = "none") -> int:
    """Wire bytes per element of the act_off payload under a codec
    (ACT_ITEMSIZE when uncompressed) — the costmodel view of
    hostmem.codec_itemsize, kept import-cycle-free."""
    if offload_dtype in (None, "none"):
        return ACT_ITEMSIZE
    assert offload_dtype in ("fp8", "int8"), offload_dtype
    return 1


def offload_wire_ratio(offload_dtype: str = "none") -> float:
    """D2H/H2D lane volume multiplier of the compressed act_off channel:
    payload bytes over raw bytes.  The per-row scales do *not* cross the
    wire — they stay device-resident with the keep set (DESIGN.md §14) —
    so the ratio is exactly the itemsize ratio."""
    return codec_itemsize(offload_dtype) / ACT_ITEMSIZE


def chunk_scale_bytes(cfg, lengths, *, batch: int, pp: int, sp: int,
                      grad_accum: int = 1,
                      offload_dtype: str = "none") -> list:
    """Per-chunk, per-device bytes of the device-resident codec scales —
    zero uncompressed.  Scales shadow the tagged set's row structure, so
    the sharding/stage factors mirror ``chunk_act_bytes``; only the rows
    that actually offload carry scales, which the caller accounts by
    multiplying with the (quantized) per-chunk α, exactly as it scales the
    off rows themselves."""
    if offload_dtype in (None, "none"):
        return [0.0 for _ in lengths]
    per_tok = (tagged_scale_elems_per_token(cfg) * SCALE_ITEMSIZE
               * (cfg.n_layers / pp) / sp)
    b = batch / max(grad_accum, 1)
    return [per_tok * b * ln for ln in lengths]


def full_act_bytes_per_token(cfg) -> float:
    """The lumped ~34·d bytes/token/layer estimate of the *entire* per-layer
    activation set (the classic transformer accounting) — used for
    microbatch sizing (parallel/plans.py), where transient untagged
    tensors count too.  The offload planner budgets the tagged subset
    (``tagged_bytes_per_token``) instead."""
    return 34 * cfg.d_model * ACT_ITEMSIZE


def chunk_act_bytes(cfg, lengths, *, batch: int, pp: int, sp: int,
                    grad_accum: int = 1) -> list:
    """Per-chunk, per-device tagged Type-1 activation bytes for one stage:
    every tag site sees the *local* (sequence-sharded) shard, so bytes
    divide by sp; a stage holds n_layers/pp layers; grad accumulation
    shrinks the resident microbatch."""
    per_tok = tagged_bytes_per_token(cfg) * (cfg.n_layers / pp) / sp
    b = batch / max(grad_accum, 1)
    return [per_tok * b * ln for ln in lengths]


# ---------------------------------------------------------------------------
# Optimizer-state (AdamW moment) bytes — the moments-channel unit of account
# ---------------------------------------------------------------------------

_OPT_ITEMSIZE = {"float32": 4, "bfloat16": 2, "float16": 2}


def moment_bytes_per_param(opt_dtype="float32") -> float:
    """AdamW first+second moment bytes per parameter at the given moment
    dtype — the closed form behind the ledger's `moments` channel: the
    jaxpr walk over the ``opt_m@``/``opt_v@`` names must sum to exactly
    ``n_params * moment_bytes_per_param(opt_dtype)``
    (tests/test_opt_offload.py)."""
    if isinstance(opt_dtype, str):
        itemsize = _OPT_ITEMSIZE[opt_dtype]
    else:
        itemsize = np.dtype(opt_dtype).itemsize
    return 2.0 * itemsize


def opt_state_bytes(n_params: int, opt_dtype="float32") -> float:
    """Total AdamW moment bytes for `n_params` parameters."""
    return n_params * moment_bytes_per_param(opt_dtype)


def moment_bytes_from_shapes(shapes, opt_dtype="float32",
                             moments_dtype: str = "none") -> float:
    """Exact host-resident moment bytes for concrete leaf shapes.  Raw
    residency reduces to the closed form above; compressed residency
    (DESIGN.md §14) is 1 payload byte per element plus one fp32 scale per
    trailing-axis row, for each of m and v — the scales ride the host
    channel here (unlike the activation channel's device-resident scales),
    so they count as host bytes and wire volume both."""
    if moments_dtype in (None, "none"):
        n = sum(int(np.prod(s)) for s in shapes)
        return opt_state_bytes(n, opt_dtype)
    assert moments_dtype in ("fp8", "int8"), moments_dtype
    n = sum(int(np.prod(s)) for s in shapes)
    rows = sum(int(np.prod(s[:-1])) for s in shapes)
    return 2.0 * (n * 1 + rows * SCALE_ITEMSIZE)


def moment_wire_bytes_per_param(opt_dtype="float32",
                                moments_dtype: str = "none",
                                *, row_len: int = 1024) -> float:
    """Per-param transfer bytes of one update's moment round trip — the
    solver's lane-pricing view (it has a parameter *count*, not shapes):
    compressed residency moves 1 payload byte + amortized scale bytes per
    element, with `row_len` the typical trailing-axis length (d_model for
    transformer weight matrices)."""
    if moments_dtype in (None, "none"):
        return moment_bytes_per_param(opt_dtype)
    assert moments_dtype in ("fp8", "int8"), moments_dtype
    return 2.0 * (1.0 + SCALE_ITEMSIZE / max(1, row_len))


def chunk_time_est(flops: float, bytes_moved: float, hw: Hardware,
                   n_ops: int = 1) -> float:
    """Roofline-max execution time + kernel overheads (Fig. 7 shape)."""
    return max(flops / hw.peak_flops_bf16, bytes_moved / hw.hbm_bw) \
        + n_ops * hw.kernel_launch_us * 1e-6


# ---------------------------------------------------------------------------
# Ring-distributed attention (DESIGN.md §15) — KV bytes-per-hop, the
# causality hop schedule, and the per-stage HBM demand of each attn_mode
# ---------------------------------------------------------------------------


def kv_bytes_per_token(cfg, itemsize: int = ACT_ITEMSIZE) -> float:
    """Bytes/token/layer of the position-tagged KV cache rows (k + v; the
    MLA cache stores the shared latent [c_kv | k_rope] once — v aliases
    k, so the latent width counts a single time)."""
    if cfg.mla is not None:
        return (cfg.mla.kv_lora_rank + cfg.mla.rope_head_dim) * itemsize
    return 2 * cfg.n_kv_heads * cfg.hd * itemsize


def kv_block_bytes(cfg, block_tokens: int,
                   itemsize: int = ACT_ITEMSIZE) -> float:
    """Device bytes of one paged-KV block on one rank for one layer
    (runtime/kvpool.py): ``block_tokens`` logical cache slots, each holding
    one token's k + v rows."""
    return block_tokens * kv_bytes_per_token(cfg, itemsize)


def kv_pool_bytes(cfg, n_blocks: int, block_tokens: int, n_layers: int,
                  itemsize: int = ACT_ITEMSIZE) -> float:
    """Per-rank device bytes of the whole paged KV pool — the Type-0
    channel the memledger gates: every layer owns ``n_blocks`` blocks."""
    return n_blocks * kv_block_bytes(cfg, block_tokens, itemsize) * n_layers


def ring_hop_bytes(cfg, kv_tokens_local: float, batch: int) -> float:
    """Wire bytes one rank sends per ring hop for one layer's attention:
    its resident KV block (batch x local tokens x kv rows) plus the int32
    position tags that travel with it (the tags are batch-invariant)."""
    return (batch * kv_tokens_local * kv_bytes_per_token(cfg)
            + kv_tokens_local * 4)


def ring_hop_fractions(sp: int, *, causal: bool = True,
                       layout: str = "zigzag") -> list:
    """Per-hop compute fraction (of one full KV block against the local
    queries) that the *slowest* rank must execute — the lock-step cost of
    hop h is the max over ranks, because the next ppermute is a barrier.

    block-contiguous layout: under causal masking rank sp−1's queries see
    every arriving block in full, so each hop costs a whole block and late
    ranks serialize the ring — sum = sp.
    zigzag (striped) layout: each rank owns an interleaved mix of early and
    late positions, so every arriving block is ~half visible everywhere and
    per-hop cost balances at 1/2 (+1/(2·sp) on the self hop for the
    unskippable diagonal tiles) — sum ≈ (sp+1)/2, the causal discount.
    Non-causal attention has no skippable pairs in either layout.

    The executed ring (parallel/ring.py) cannot skip hops — the rank index
    is traced and collectives are lock-step — so it runs all sp hops with
    positional masking; this table is the *pricing* of that masking."""
    if sp <= 1:
        return [1.0]
    if not causal or layout == "block":
        return [1.0] * sp
    assert layout == "zigzag", layout
    return [0.5 + 0.5 / sp] + [0.5] * (sp - 1)


def stage_attn_demand(cfg, *, seq_len: int, batch: int, sp: int, pp: int,
                      mode: str, n_params: int = None) -> dict:
    """Per-device HBM demand (bytes) of running attention over a
    ``seq_len``-token visible context under each attn_mode — the §15
    memory model that decides which modes a cell can even admit.

      params        parameter shard residency (bf16, sharded over the
                    stage grid and the model axis);
      kv_cache      the position-tagged Type-0 cache one stage must keep
                    resident through the whole sequence: full visible KV
                    under "local" (no collectives exist to reassemble
                    shards), 1/sp of it for every distributed mode;
      attn_transient  the largest per-layer working set one attention call
                    materializes on top of the cache: the gathered full KV
                    (gather_kv), two blocks — resident + in flight — for
                    the ring, one remote query/merge-buffer shard for
                    gather_q, nothing extra for local (the cache IS the
                    working set).
    """
    assert mode in ("local", "gather_q", "gather_kv", "auto", "ring"), mode
    row = kv_bytes_per_token(cfg)
    layers = cfg.n_layers / pp
    params = (n_params * ACT_ITEMSIZE / (pp * sp)) if n_params else 0.0
    if mode == "local":
        kv_cache = batch * seq_len * row * layers
        transient = 0.0
    else:
        kv_cache = batch * (seq_len / sp) * row * layers
        if mode == "gather_kv":
            transient = batch * seq_len * row
        elif mode == "ring":
            transient = 2.0 * batch * (seq_len / sp) * row
        else:  # gather_q / auto: the remote query shard + merge buffers
            transient = batch * (seq_len / sp) * row
    total = params + kv_cache + transient
    return {"params": params, "kv_cache": kv_cache,
            "attn_transient": transient, "total": total}
