"""SPPO heuristic solver (§6.1): pick (SP, PP, N) minimizing iteration time.

Search space restrictions (the paper's heuristics, translated to the TPU
mesh — DESIGN.md §2):
  * SP stays on the fast intra-pod `model` axis (no cross-pod SP) and is
    fixed to the axis size (16) by the production mesh;
  * PP divides the `data` axis; the `pod` axis carries only DP;
  * per-chunk workload between MIN_CHUNK_TOKENS and MAX_CHUNK_TOKENS per
    device (the paper's 2K–16K/layer/device heuristic, Fig. 7).

Objective: every candidate (PP, N) is *played out* by the event-driven
simulator (core/simulate.py, DESIGN.md §3): per-stage compute/P2P/D2H/H2D
lanes over the FLOPs-weighted chunk costs, so the score includes fill/drain
bubbles, steady-phase resynchronization, inter-stage hand-off time, and the
unhidden-D2H stall that the closed-form T = (p−1+N)/N·F(N) cannot see.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

from repro.core import costmodel as cm
from repro.core import offload as ofl
from repro.core import partition as part
from repro.core import simulate as sim

MIN_CHUNK_TOKENS = 2048
MAX_CHUNK_TOKENS = 16384


@dataclass(frozen=True)
class SolverResult:
    pp: int
    n_chunks: int
    sp: int
    est_time: float
    bubble_ratio: float
    alphas: tuple
    candidates: tuple  # (pp, n, time) explored — for the benchmark report


def iteration_time(cfg, seq_len: int, batch: int, n_params: int,
                   pp: int, n: int, sp: int,
                   hw: cm.Hardware = cm.V5E, *, msp: bool = False,
                   msp_split: int = 2,
                   offload: bool = True,
                   offload_moments: bool = False,
                   opt_dtype: str = "float32",
                   prefetch: str = "ahead") -> Tuple[float, tuple]:
    """Simulated per-iteration wall time for one dp replica (seconds)."""
    t, alphas, _ = simulate_candidate(cfg, seq_len, batch, n_params, pp, n,
                                      sp, hw, msp=msp, msp_split=msp_split,
                                      offload=offload,
                                      offload_moments=offload_moments,
                                      opt_dtype=opt_dtype, prefetch=prefetch)
    return t, alphas


def simulate_candidate(cfg, seq_len: int, batch: int, n_params: int,
                       pp: int, n: int, sp: int,
                       hw: cm.Hardware = cm.V5E, *, msp: bool = False,
                       msp_split: int = 2, offload: bool = True,
                       offload_moments: bool = False,
                       opt_dtype: str = "float32",
                       prefetch: str = "ahead",
                       offload_dtype: str = "none",
                       moments_dtype: str = "none",
                       doc_lens=None,
                       attn_mode: str = "gather_q"
                       ) -> Tuple[float, tuple, sim.SimResult]:
    """Build the candidate's cost/activation profile and play it out.

    offload_moments adds the optimizer-state epilogue (DESIGN.md §11): the
    per-device moment set crosses the host link once in each direction per
    step, after the last backward — nothing left to hide it under, so it is
    charged in full on top of the pipeline playout.  prefetch selects the
    simulator's H2D lane mode (DESIGN.md §12): "ahead" prices the
    one-chunk-ahead reload seam, "sync" the autodiff placement — both
    plan settings therefore have priced predictions.

    offload_dtype / moments_dtype (DESIGN.md §14) price the compressed
    channels: the act_off D2H/H2D lane volumes scale by the codec's wire
    ratio (the α solver itself keeps planning in raw device bytes — the
    recurrence drains full rows), and the moments epilogue moves the
    payload + host-side scale bytes instead of the full opt_dtype leaves.

    doc_lens (optional) switches the candidate to a packed variable-length
    workload cell (DESIGN.md §13): the documents are greedily packed into
    ``batch`` rows of ``seq_len``, the per-position causal-sawtooth cost
    profile replaces the single triangle, and chunk boundaries / offload
    ratios are balanced over that measured profile.

    attn_mode="ring" (DESIGN.md §15) adds the ring-attention lane: per
    chunk, the sp-hop KV rotation is played out by ``sim.ring_overlap``
    (hop h+1's P2P overlaps hop h's compute on a serialized link), the
    per-hop compute is discounted by the zig-zag causal hop fractions, and
    the per-chunk (occupancy, exposed-fwd, exposed-bwd) triple is handed to
    the schedule simulator's ring lane.  Other modes price no attention
    collectives beyond the baseline (gather/all-gather traffic is small
    against the chunk compute at solver scale)."""
    r = part.flops_per_token_ratio(cfg)
    tok_flops = cm.model_flops_per_token(n_params, train=True)
    chips = sp * pp
    if doc_lens:
        doc_lens = [int(x) for x in doc_lens]
        rows = part.pack_lengths(doc_lens, seq_len)
        row_lens = [[doc_lens[i] for i in row] for row in rows]
        assert len(row_lens) <= batch, (
            f"packing needs {len(row_lens)} rows > batch {batch}")
        row_lens += [[] for _ in range(batch - len(row_lens))]
        profile = part.packed_cost_profile(row_lens, seq_len, r)
        sched = part.partition_profile(
            profile, n, multiple=sp,
            doc_bounds=part.aligned_doc_bounds(row_lens, seq_len))
        # profile units already sum over the batch rows (padding rows ride
        # the dense matmuls at linear cost)
        costs = part.profile_chunk_costs(profile, sched)
        # profile cost units cover all batch rows, so the flops conversion
        # and the linear share are taken against batch*seq_len units
        scale = (batch * seq_len * tok_flops) / sum(costs)
        attn_frac = 1.0 - (batch * seq_len) / sum(costs)
    else:
        sched = part.partition(seq_len, n, cfg, "length")
        costs = part.chunk_costs(sched, r)
        # convert relative costs to flops: linear == per-token matmul flops
        scale = (batch * seq_len * tok_flops) / sum(costs)
        # backward/forward split: the recompute-based flash backward makes
        # the attention share cost 2.5x its forward (vs 2x for matmuls);
        # weight by the attention fraction of the relative chunk costs.
        # Σcosts = Σlengths + attention term: linear share is Σlen/Σcost.
        attn_frac = 1.0 - sum(sched.lengths) / sum(costs)
    chunk_flops = [c * scale for c in costs]
    bwd_ratio = cm.effective_bwd_ratio(attn_frac)
    # the 6N lumped convention prices bwd at 2x fwd; the QK^T recompute of
    # the attention backward adds (1+bwd_ratio)/3 on top
    times = [f / (chips * hw.peak_flops_bf16)
             * (1.0 + bwd_ratio) / (1.0 + cm.BWD_RATIO) +
             2 * cfg.n_layers / pp * hw.kernel_launch_us * 1e-6
             for f in chunk_flops]
    # offload: tagged Type-1 activation bytes per chunk (cost model's
    # per-site ledger — costmodel.tagged_bytes_per_token)
    act = cm.chunk_act_bytes(cfg, sched.lengths, batch=batch, pp=pp, sp=sp)
    # the D2H window is the *forward* compute of the next chunk (§5.2);
    # compression widens it in byte terms — only wire_ratio·A bytes must
    # cross per offloaded row-set, so the solver sees the link at its
    # effective (raw-bytes-per-second) rate and α can grow accordingly
    wire_ratio = cm.offload_wire_ratio(offload_dtype)
    fwd_times = [t / (1.0 + bwd_ratio) for t in times]
    plan = ofl.sequence_aware_alphas(act, fwd_times, hw.d2h_bw / wire_ratio)
    alphas = plan.alphas if offload else tuple(0.0 for _ in act)
    # per-device inter-stage hand-off payload: hidden states of the chunk
    p2p = ([2 * batch * ln * cfg.d_model / sp for ln in sched.lengths]
           if pp > 1 else None)
    ring_t = ring_exposed = ring_bwd_exposed = None
    if attn_mode == "ring" and sp > 1:
        layers = cfg.n_layers / pp
        fracs = cm.ring_hop_fractions(sp, causal=True, layout="zigzag")
        ring_t, ring_exposed, ring_bwd_exposed = [], [], []
        kv_end = 0
        for ln in sched.lengths:
            kv_end += ln
            hop_bytes = cm.ring_hop_bytes(cfg, kv_end / sp, batch)
            xfer = [0.0] + [hop_bytes / hw.ici_bw] * (sp - 1)
            # per-hop attention flops: local queries x one KV block
            hop_flops = (4.0 * batch * (ln / sp) * (kv_end / sp)
                         * cfg.n_heads * cfg.head_dim)
            comp_f = [f * hop_flops / hw.peak_flops_bf16 for f in fracs]
            comp_b = [c * cm.ATTN_BWD_RATIO for c in comp_f]
            _, exp_f, _ = sim.ring_overlap(comp_f, xfer)
            _, exp_b, _ = sim.ring_overlap(comp_b, xfer)
            ring_t.append(layers * sum(xfer))
            ring_exposed.append(layers * exp_f)
            ring_bwd_exposed.append(layers * exp_b)
    res = sim.simulate_schedule(
        times, pp=pp, msp=msp, split=msp_split,
        chunk_acts=act, alphas=alphas,
        d2h_bw=hw.d2h_bw, p2p_bytes=p2p, ici_bw=hw.ici_bw,
        bwd_ratio=bwd_ratio, prefetch=prefetch,
        off_wire_ratio=wire_ratio,
        ring_t=ring_t, ring_exposed=ring_exposed,
        ring_bwd_exposed=ring_bwd_exposed)
    total = res.total
    if offload_moments:
        total += sim.opt_update_transfer(
            n_params / chips,
            cm.moment_wire_bytes_per_param(opt_dtype, moments_dtype,
                                           row_len=cfg.d_model),
            hw.d2h_bw)
    return total, alphas, res


def admit_attn_mode(cfg, seq_len: int, batch: int, n_params: int,
                    pp: int, sp: int, hw: cm.Hardware = cm.V5E,
                    modes: tuple = ("local", "gather_kv", "ring")) -> dict:
    """Per-stage HBM admission for each attention schedule (DESIGN.md §15).

    Returns ``{mode: (fits, demand_dict)}`` where the demand comes from
    ``costmodel.stage_attn_demand`` — the resident KV cache plus the
    schedule's transient (gathered KV / in-flight ring blocks) plus the
    stage's parameter shard, checked against ``hw.hbm_bytes``.  This is the
    gate that rejects a multi-million-token cell at ``attn_mode="local"``
    (full visible KV on every device) while admitting it at ``"ring"``
    (one resident shard + two in-flight blocks)."""
    out = {}
    for mode in modes:
        d = cm.stage_attn_demand(cfg, seq_len=seq_len, batch=batch, sp=sp,
                                 pp=pp, mode=mode, n_params=n_params)
        out[mode] = (d["total"] <= hw.hbm_bytes, d)
    return out


def choose_attn_mode(cfg, seq_len: int, batch: int, n_params: int,
                     pp: int, n: int, sp: int,
                     hw: cm.Hardware = cm.V5E, *,
                     modes: tuple = ("local", "ring"),
                     **kw) -> Tuple[str, dict]:
    """Pick the fastest attention schedule among those that fit in HBM.

    Every mode in ``modes`` is first screened by ``admit_attn_mode``; the
    admitted ones are played out by ``simulate_candidate`` (extra solver
    kwargs pass through) and the fastest wins.  Returns ``(mode, report)``
    with the per-mode admission verdicts, demands, and simulated times."""
    admitted = admit_attn_mode(cfg, seq_len, batch, n_params, pp, sp, hw,
                               modes=modes)
    best = None
    report = {}
    for mode in modes:
        ok, demand = admitted[mode]
        if not ok:
            report[mode] = dict(admitted=False, demand=demand)
            continue
        t, _, _ = simulate_candidate(cfg, seq_len, batch, n_params, pp, n,
                                     sp, hw, attn_mode=mode, **kw)
        report[mode] = dict(admitted=True, demand=demand, est_time=t)
        if best is None or t < best[1]:
            best = (mode, t)
    assert best is not None, (
        f"no attention mode in {modes} fits in {hw.hbm_bytes} bytes of HBM")
    return best[0], report


def solve(cfg, seq_len: int, batch: int, n_params: int,
          data_size: int = 16, model_size: int = 16,
          hw: cm.Hardware = cm.V5E, *, msp: bool = False) -> SolverResult:
    """Search (PP, N) under the §6.1 heuristics, scoring by simulation."""
    sp = model_size
    best = None
    cands: List[Tuple[int, int, float]] = []
    pps = [p for p in (1, 2, 4, 8, 16) if data_size % p == 0]
    for pp in pps:
        if cfg.n_layers < pp:
            continue
        max_n = max(1, seq_len // (MIN_CHUNK_TOKENS))
        min_n = max(1, seq_len // (MAX_CHUNK_TOKENS * 4))
        for n in sorted({1, 2, 4, 8, 16, 32, 64, 128}):
            if n < min_n or n > max_n or n > seq_len // sp:
                continue
            if pp > 1 and n < pp:
                continue
            if seq_len % (n * sp):
                continue
            t, alphas = iteration_time(cfg, seq_len, batch, n_params,
                                       pp, n, sp, hw, msp=msp)
            cands.append((pp, n, t))
            if best is None or t < best[2]:
                best = (pp, n, t, alphas)
    if best is None:  # fall back: no chunking (short sequences)
        t, alphas = iteration_time(cfg, seq_len, batch, n_params, 1, 1,
                                   sp, hw, msp=False)
        best = (1, 1, t, alphas)
        cands.append((1, 1, t))
    pp, n, t, alphas = best
    return SolverResult(pp=pp, n_chunks=n, sp=sp, est_time=t,
                        bubble_ratio=(pp - 1) / n,
                        alphas=alphas, candidates=tuple(cands))
