"""SPPO heuristic solver (§6.1): pick (SP, PP, N) minimizing iteration time.

Search space restrictions (the paper's heuristics, translated to the TPU
mesh — DESIGN.md §2):
  * SP stays on the fast intra-pod `model` axis (no cross-pod SP) and is
    fixed to the axis size (16) by the production mesh;
  * PP divides the `data` axis; the `pod` axis carries only DP;
  * per-chunk workload between MIN_CHUNK_TOKENS and MAX_CHUNK_TOKENS per
    device (the paper's 2K–16K/layer/device heuristic, Fig. 7).

Objective: T(N, PP) = (PP−1+N)/N · F(N)  +  offload_overflow_penalty, where
F(N) adds per-chunk kernel overheads (more chunks → more launches) and the
penalty charges D2H time that cannot hide under compute (§5.2).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro.core import costmodel as cm
from repro.core import partition as part
from repro.core import offload as ofl
from repro.core.schedule import msp_total_time, total_time

MIN_CHUNK_TOKENS = 2048
MAX_CHUNK_TOKENS = 16384


@dataclass(frozen=True)
class SolverResult:
    pp: int
    n_chunks: int
    sp: int
    est_time: float
    bubble_ratio: float
    alphas: tuple
    candidates: tuple  # (pp, n, time) explored — for the benchmark report


def iteration_time(cfg, seq_len: int, batch: int, n_params: int,
                   pp: int, n: int, sp: int, dp: int,
                   hw: cm.Hardware = cm.V5E, *, msp: bool = False,
                   offload: bool = True) -> Tuple[float, tuple]:
    """Estimated per-iteration wall time for one dp replica (seconds)."""
    r = part.flops_per_token_ratio(cfg)
    sched = part.partition(seq_len, n, cfg, "length")
    costs = part.chunk_costs(sched, r)
    # convert relative costs to flops: linear term == per-token matmul flops
    tok_flops = cm.model_flops_per_token(n_params, train=True)
    lin_total = seq_len  # relative linear units for the whole sequence
    scale = (batch * seq_len * tok_flops) / sum(costs)
    chunk_flops = [c * scale for c in costs]
    chips = sp * pp
    times = [f / (chips * hw.peak_flops_bf16 / 1.0) +
             2 * cfg.n_layers / pp * hw.kernel_launch_us * 1e-6
             for f in chunk_flops]
    f_n = sum(times)
    t = msp_total_time(pp, n, f_n) if msp else total_time(pp, n, f_n)
    # offload: activation bytes per chunk (Type-1 ~ 34*B*s*H bf16 per layer)
    act = [34 * batch * l * cfg.d_model * 2 * (cfg.n_layers / pp) / sp
           for l in sched.lengths]
    plan = ofl.sequence_aware_alphas(act, times, hw.d2h_bw)
    if offload:
        # unhidden D2H time: whatever α<1 left resident must either stay
        # (memory) or stall; charge the stall for the fraction above HBM room
        unhidden = sum(max(0.0, a * (1 - al) - 0.0) for a, al in
                       zip(act, plan.alphas)) * 0.0
        t = t + unhidden
    return t, plan.alphas


def solve(cfg, seq_len: int, batch: int, n_params: int,
          data_size: int = 16, model_size: int = 16,
          hw: cm.Hardware = cm.V5E, *, msp: bool = False,
          kind: str = "train") -> SolverResult:
    """Search (PP, N) under the §6.1 heuristics."""
    sp = model_size
    best = None
    cands: List[Tuple[int, int, float]] = []
    pps = [p for p in (1, 2, 4, 8, 16) if data_size % p == 0]
    for pp in pps:
        if cfg.n_layers < pp:
            continue
        dp = data_size // pp
        if batch % (dp if kind != "decode" else 1) and batch >= dp:
            pass
        if batch < dp and seq_len * batch // dp == 0:
            continue
        max_n = max(1, seq_len // (MIN_CHUNK_TOKENS))
        min_n = max(1, seq_len // (MAX_CHUNK_TOKENS * 4))
        for n in sorted({1, 2, 4, 8, 16, 32, 64, 128}):
            if n < min_n or n > max_n or n > seq_len // sp:
                continue
            if pp > 1 and n < pp:
                continue
            if seq_len % (n * sp):
                continue
            t, alphas = iteration_time(cfg, seq_len, batch, n_params,
                                       pp, n, sp, dp, hw, msp=msp)
            cands.append((pp, n, t))
            if best is None or t < best[2]:
                best = (pp, n, t, alphas)
    if best is None:  # fall back: no chunking (short sequences)
        t, alphas = iteration_time(cfg, seq_len, batch, n_params, 1, 1,
                                   sp, data_size, hw, msp=False)
        best = (1, 1, t, alphas)
        cands.append((1, 1, t))
    pp, n, t, alphas = best
    return SolverResult(pp=pp, n_chunks=n, sp=sp, est_time=t,
                        bubble_ratio=(pp - 1) / n,
                        alphas=alphas, candidates=tuple(cands))
