"""SPPO adaptive offloading (§5): sequence-aware ratios + two-level policy.

Two pieces, matching the paper:

1. **Sequence-aware offloading** (§5.2) — per-chunk offload ratio α_i chosen
   so the D2H transfer of chunk i hides under the compute of chunk i+1:
   α_i·A_i = M_threshold = BW_D2H · T_next_comp.  Under a FLOPs-balanced
   partition all T are equal and the paper's invariant
   α_{i-1}A_{i-1} = α_iA_i (monotone α, since A_0 ≥ A_1 ≥ …) emerges as a
   special case; the solver here works for *any* partition (length-based
   chunks have growing T_i, so α_i grows — same mechanism, general form).
   The final chunk never offloads (its backward begins immediately): α_N = 0.

2. **Two-level activation management** (§5.1) — a `jax.checkpoint` policy:
   Type-0 skeletal tensors (KV cache) are *explicit carries*, always on
   device; tagged Type-1 tensors are row-split by α into an offloaded part
   (`act_off` → pinned_host) and a device-resident part (`act_keep`);
   everything untagged (norms, rope, elementwise) is rematerialized.

Memory recurrence (paper eq. §5.2): M_i = M_{i-1} + A_i − α_{i-1}·A_{i-1},
simulated by ``peak_memory`` and asserted in tests.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.core import mutation
from repro.runtime import hostmem

OFF_NAME = "act_off"
KEEP_NAME = "act_keep"
SCALE_NAME = "act_scale"


def scale_name_for(off_name: str) -> str:
    """The checkpoint name of a codec's per-row scales, carrying the same
    chunk/tick qualifier as the off rows they reconstruct: ``act_off@t3``
    -> ``act_scale@t3``.  Scales stay device-resident (they ride the keep
    set — 4 bytes per row vs the rows themselves; hosting them would add a
    second tiny transfer per site for no memory win) but must be *named*
    and saved: an unnamed scale would be rematerialized by the backward
    replay from the full-precision rows, i.e. the whole act_off tensor
    would come back on device and the offload would be fictitious."""
    return SCALE_NAME + off_name[len(OFF_NAME):]


# ---------------------------------------------------------------------------
# 1. Sequence-aware offload ratio solver
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class OffloadPlan:
    alphas: tuple               # per-chunk offload ratio in [0, 1]
    m_threshold: float          # bytes offloaded per chunk slot (paper's M_thr)
    peak_units: float           # peak device activation memory (chunk-activation units)


def sequence_aware_alphas(act_bytes: Sequence[float],
                          comp_times: Sequence[float],
                          bw_d2h: float,
                          *, reserve_last: bool = True,
                          bwd_over_fwd: float = 2.0) -> OffloadPlan:
    """act_bytes[i]: Type-1 activation volume of chunk i;
    comp_times[i]: *forward* compute time of chunk i; bw_d2h: host-link
    bytes/s.

    α_i = min(1, BW · T_{i+1} / A_i): offload exactly what hides under the
    next chunk's compute.  α of the final chunk is 0 (its backward starts
    immediately — offloading it would only add H2D latency, §5.2).

    With ``reserve_last=False`` the final chunk does offload — a
    memory-constrained override, not a free lunch: its backward is the
    *first backward event* and its replay consumes the reloaded rows, so
    the D2H→H2D round trip serializes onto the critical path (nothing can
    hide it; the simulator charges it in full under either prefetch lane
    mode).  The first backward event's duration —
    ``comp_times[-1] * bwd_over_fwd`` (lumped fwd:bwd split, cf.
    costmodel.BWD_RATIO) — is therefore used as the *sizing budget*: α is
    chosen so each direction of the exposed round trip costs at most about
    one such backward.  The old behavior budgeted by the chunk's own
    *forward* time, which is already spent when the D2H becomes
    schedulable and mis-sizes the bound by the bwd/fwd ratio.
    """
    n = len(act_bytes)
    alphas = []
    for i in range(n):
        if i == n - 1 and reserve_last:
            alphas.append(0.0)
            continue
        window = (comp_times[i + 1] if i + 1 < n
                  else comp_times[i] * bwd_over_fwd)
        alphas.append(max(0.0, min(1.0, bw_d2h * window / max(act_bytes[i], 1e-9))))
    m_thr = max((a * b for a, b in zip(alphas, act_bytes)), default=0.0)
    peak = peak_memory(act_bytes, alphas)
    return OffloadPlan(tuple(alphas), m_thr, peak)


def peak_memory(act_bytes: Sequence[float], alphas: Sequence[float]) -> float:
    """Simulate M_i = M_{i-1} + A_i − α_{i-1}A_{i-1} (offload of chunk i-1
    completes during chunk i's compute); returns the forward-pass peak."""
    m = 0.0
    peak = 0.0
    prev_off = 0.0
    for a, al in zip(act_bytes, alphas):
        m += a              # chunk i activations materialize
        peak = max(peak, m)
        m -= prev_off       # previous chunk's offload drains
        prev_off = al * a
    # last chunk's offload (if any) drains after the loop
    peak = max(peak, m)
    return peak


def fixed_full_alphas(n: int) -> tuple:
    """Baseline: fixed full offloading (α=1 everywhere) — §7.2 'w/ offload'."""
    return tuple(1.0 for _ in range(n))


# ---------------------------------------------------------------------------
# 2. Two-level activation management: checkpoint policy + row-split tagging
# ---------------------------------------------------------------------------


def sppo_policy(offload: bool = True,
                names: tuple = (OFF_NAME, KEEP_NAME)):
    """Checkpoint policy: act_keep saved on device; act_off to pinned_host.

    offload=False degrades to save-only (the 'SPPO w/o offload' ablation)."""
    off_name, keep_name = names
    if offload:
        return jax.checkpoint_policies.save_and_offload_only_these_names(
            names_which_can_be_saved=[keep_name],
            names_which_can_be_offloaded=[off_name],
            offload_src="device",
            offload_dst="pinned_host",
        )
    return jax.checkpoint_policies.save_only_these_names(off_name, keep_name)


def split_rows(rows: int, alpha: float) -> int:
    """Rows routed off-device for a fractional α (the tags' split point).

    Nearest-row rounding, clipped to [0, rows].  The old ``max(1, ...)``
    floor forced at least one row off-device for *any* α > 0, so on short
    chunks the measured off-bytes exceeded the continuous α·A the ledger
    and simulator predict; predictions now share this discretization via
    ``quantized_alpha`` so the memgate band cannot drift at small shapes."""
    if alpha <= 0.0:
        return 0
    if alpha >= 1.0:
        return rows
    return max(0, min(rows, int(round(rows * alpha))))


def quantized_alpha(rows: int, alpha: float) -> float:
    """The offload ratio the row split actually deploys for a tensor with
    `rows` rows: ``split_rows(rows, α) / rows``.  Ledger/simulator
    predictions use this discretized ratio (runtime/memledger.py) so the
    analytic side matches the executed split exactly."""
    if rows <= 0:
        return 0.0
    return split_rows(rows, float(alpha)) / rows


def chunk_names(suffix: str = "") -> tuple:
    """(off, keep) checkpoint names, optionally qualified per chunk/tick.

    Qualified names (e.g. ``act_off@t3``) let the memledger attribute the
    saved bytes of each pipeline tick exactly from the traced jaxpr
    (runtime/memledger.py); the policies below save any qualified variant."""
    return (OFF_NAME + suffix, KEEP_NAME + suffix)


def make_tag(alpha: float, *, axis: int = 1,
             names: tuple = (OFF_NAME, KEEP_NAME)):
    """Row-split tagger implementing the fractional offload ratio.

    Splits a tagged activation along `axis` (the token/row dim): the first
    ⌈α·rows⌉ rows are routed to pinned_host, the rest stay on device.  α is
    static per chunk (the chunk loop is unrolled), exactly the paper's
    per-subsequence ratio."""
    alpha = float(alpha)
    off_name, keep_name = names

    def tag(t):
        if alpha <= 0.0:
            return checkpoint_name(t, keep_name)
        if alpha >= 1.0:
            return checkpoint_name(t, off_name)
        k = split_rows(t.shape[axis], alpha)
        if k <= 0:                       # α quantizes to 0 rows on this shape
            return checkpoint_name(t, keep_name)
        if k >= t.shape[axis]:           # ... or to all rows
            return checkpoint_name(t, off_name)
        lo = jax.lax.slice_in_dim(t, 0, k, axis=axis)
        hi = jax.lax.slice_in_dim(t, k, t.shape[axis], axis=axis)
        lo = checkpoint_name(lo, off_name)
        hi = checkpoint_name(hi, keep_name)
        return jax.lax.concatenate([lo, hi], dimension=axis)

    return tag


def null_tag(t):
    """remat='none' mode: save everything on device."""
    return checkpoint_name(t, KEEP_NAME)


# ---------------------------------------------------------------------------
# 3. Executed offloading: explicit memory-kind placement of act_off rows
# ---------------------------------------------------------------------------
#
# The policy path above delegates placement to XLA's remat offloader.  The
# executed path makes the two-level split explicit dataflow instead: the
# act_off rows are device_put into host memory (D2H) *in the forward*, the
# named residual that jax.checkpoint saves is that host-resident copy, and
# the backward's rematerialization replays only the device_put back to
# device (H2D).  Double-buffering falls out of the dataflow: chunk i's D2H
# depends only on chunk i's forward, so it can overlap chunk i+1's compute,
# and the H2D is issued by the autodiff exactly at chunk i's backward.
# DESIGN.md §10 records the contract and the CPU fallback semantics.  The
# memory-kind probe and the D2H/H2D primitives are shared with the
# optimizer-moment offload path (optim/adamw.py) via runtime/hostmem.py.

host_memory_kind = hostmem.host_memory_kind


def host_round_trip(t, *, host_kind: Optional[str] = "auto",
                    name: str = OFF_NAME, codec: str = "none"):
    """Route `t` through host memory with the saved residual on the host:

      D2H -> checkpoint_name(act_off) -> H2D

    Under ``jax.checkpoint(policy=save_only_these_names(...))`` the named
    host-resident copy is what gets saved; the backward's remat replays only
    the H2D.  On backends without memory kinds the staged-copy emulation
    keeps the identical graph structure (a named save point fenced by
    optimization barriers, so XLA must materialize the staged buffer) —
    on either path the round trip is a value-level identity.

    With a codec the rows cross compressed: quantize before the D2H (the
    host residual is the 1-byte payload), dequantize after the H2D, and the
    per-row fp32 scales stay on device under their own checkpoint name
    (``scale_name_for``).  The round trip is then forward-*lossy* — the
    consumer sees dequant(quant(t)) — so the gradient seam matters: a
    naive round trip would differentiate through quantize (round/convert
    have zero tangents ⇒ dead gradients); ``residual_substitute`` makes it
    a straight-through estimator instead, primal = the reconstruction,
    cotangent routed untouched to `t`'s producers."""
    kind = hostmem.resolve_host_kind(host_kind)
    if codec in (None, "none"):
        if kind is None:
            staged = checkpoint_name(jax.lax.optimization_barrier(t), name)
            return jax.lax.optimization_barrier(staged)
        th = hostmem.to_host(t, kind)                             # D2H
        th = checkpoint_name(th, name)                            # host residual
        return hostmem.to_device(th, kind)                        # H2D
    payload, scale = hostmem.quantize(t, codec)
    scale = checkpoint_name(scale, scale_name_for(name))          # device-resident
    # The named host residual crosses as an int8 BYTE CONTAINER: a named
    # fp8 residual under save_only_these_names carries an inexact tangent
    # through the remat partial-eval and poisons the primal with NaNs
    # (jax 0.4.x); integer payloads get float0 tangents and are immune.
    # Bitcast is bit-exact both ways and does not change the byte count —
    # the mirror image of the prefetch seam's to_transport (there the
    # custom_vjp channel needs an INEXACT container for the same payload).
    wire = payload.dtype
    if mutation.active("fp8-named-residual"):
        # seeded PR 7 regression (tests/mutants): skip the byte container,
        # naming the raw inexact payload — the auditor must flag this
        wire = jnp.int8
    pc = (payload if wire == jnp.int8
          else jax.lax.bitcast_convert_type(payload, jnp.int8))
    if kind is None:
        staged = checkpoint_name(jax.lax.optimization_barrier(pc), name)
        pc_d = jax.lax.optimization_barrier(staged)
    else:
        ph = checkpoint_name(hostmem.to_host(pc, kind), name)
        pc_d = hostmem.to_device(ph, kind)
    payload_d = (pc_d if wire == jnp.int8
                 else jax.lax.bitcast_convert_type(pc_d, wire))
    deq = hostmem.dequantize(payload_d, scale, codec, t.dtype)
    return residual_substitute(t, deq)


def make_exec_tag(alpha: float, *, axis: int = 1,
                  names: tuple = (OFF_NAME, KEEP_NAME), host_kind="auto",
                  codec: str = "none"):
    """Executed form of ``make_tag``: same row split, but the act_off rows
    round-trip through host memory so the transfers are real program
    dataflow rather than an XLA remat hint.  The tag is a value-level
    identity (slice + concat + copies); it can still shift XLA fusion
    decisions, so offload on/off losses and grads are asserted to match to
    fp32 tolerance (<= 1e-5, tests/test_offload_exec.py), not bitwise.
    With a codec the off rows additionally quantize across the link
    (codec resolution replaces the fp32 tolerance; see
    tests/test_offload_quant.py for the pinned drift bounds)."""
    alpha = float(alpha)
    off_name, keep_name = names

    def tag(t):
        if alpha <= 0.0:
            return checkpoint_name(t, keep_name)
        if alpha >= 1.0:
            return host_round_trip(t, host_kind=host_kind, name=off_name,
                                   codec=codec)
        k = split_rows(t.shape[axis], alpha)
        if k <= 0:
            return checkpoint_name(t, keep_name)
        if k >= t.shape[axis]:
            return host_round_trip(t, host_kind=host_kind, name=off_name,
                                   codec=codec)
        lo = jax.lax.slice_in_dim(t, 0, k, axis=axis)
        hi = jax.lax.slice_in_dim(t, k, t.shape[axis], axis=axis)
        lo = host_round_trip(lo, host_kind=host_kind, name=off_name,
                             codec=codec)
        hi = checkpoint_name(hi, keep_name)
        return jax.lax.concatenate([lo, hi], dimension=axis)

    return tag


# ---------------------------------------------------------------------------
# 4. Prefetch="ahead" tag machinery (DESIGN.md §12)
# ---------------------------------------------------------------------------
#
# The executed path above leaves the backward H2D to autodiff: the remat of
# chunk i replays its reload exactly at chunk i's backward.  The "ahead"
# path moves residual management to a tick-level custom_vjp seam
# (parallel/runner.py: prefetch_chunk): the seam's *forward* runs the chunk
# with a capture tag — a dataflow identity that records the (off, keep) row
# split of every tagged tensor — and routes the off rows to host once, as
# the seam's explicit residual; the hand-written backward reloads chunk
# i's rows one event ahead (during chunk i+1's backward) and replays the
# chunk with an inject tag that substitutes the staged copies for the
# recomputed tensors.  ``residual_substitute`` is the gradient seam of that
# substitution: primal = the staged copy (bitwise equal — D2H/H2D round
# trips copy), cotangent routed entirely to the computed branch, so the
# replay differentiates the true producers while XLA can drop their
# forward values.


@jax.custom_vjp
def residual_substitute(computed, staged):
    """Identity-by-value swap: use `staged` (a reloaded residual, bitwise
    equal to `computed`) as the primal, route the cotangent to `computed`'s
    producers — exactly what saving `computed` under a checkpoint policy
    would do, with the residual's placement under caller control."""
    return staged


def _subst_fwd(computed, staged):
    return staged, None


def _subst_bwd(_, ct):
    return ct, jnp.zeros_like(ct)


residual_substitute.defvjp(_subst_fwd, _subst_bwd)


def make_capture_tag(alpha: float, collector: list, *, axis: int = 1,
                     codec: str = "none"):
    """Prefetch-'ahead' forward tag: a dataflow identity that appends the
    (kind, tensor) row split of every tagged tensor to `collector` in
    traversal order — "off" rows destined for host, "keep" rows staying on
    device.  The seam (runner.prefetch_chunk) stacks them over slots and
    performs the single D2H per site.  With a codec the off rows are
    captured *compressed*: the collector gets the ("off", payload) wire
    bytes plus a ("scale", scale) entry; the tag still returns `t`
    unchanged, so the capture forward itself stays exact — only the
    backward replay sees the reconstruction."""
    alpha = float(alpha)

    def capture_off(t):
        if codec in (None, "none"):
            collector.append(("off", t))
            return
        payload, scale = hostmem.quantize(t, codec)
        collector.append(("off", payload))
        collector.append(("scale", scale))

    def tag(t):
        rows = t.shape[axis]
        k = split_rows(rows, alpha)
        if k <= 0:
            collector.append(("keep", t))
            return t
        if k >= rows:
            capture_off(t)
            return t
        capture_off(jax.lax.slice_in_dim(t, 0, k, axis=axis))
        collector.append(("keep", jax.lax.slice_in_dim(t, k, rows, axis=axis)))
        return t

    return tag


def make_inject_tag(alpha: float, off_acts, keep_acts, *, axis: int = 1,
                    names: tuple = (OFF_NAME, KEEP_NAME),
                    codec: str = "none", scales=()):
    """Prefetch-'ahead' backward-replay tag: re-walks the same tag sites as
    ``make_capture_tag`` (same α ⇒ same split decisions ⇒ same traversal
    order) and substitutes the staged residuals — `off_acts` reloaded one
    event ahead by the seam, `keep_acts` passed through on device — via
    ``residual_substitute``.  Substituted values carry the checkpoint names
    so the per-slot ``save_only_these_names`` replay saves exactly them.
    With a codec, `off_acts` are the reloaded wire payloads and `scales`
    (device-resident, from the seam's residuals) reconstruct the rows at
    the site before substitution — the same straight-through seam as the
    exec path."""
    alpha = float(alpha)
    off_it = iter(off_acts)
    keep_it = iter(keep_acts)
    scale_it = iter(scales)
    off_name, keep_name = names

    def staged_off(t_part):
        staged = next(off_it)
        if codec in (None, "none"):
            return staged
        return hostmem.dequantize(staged, next(scale_it), codec, t_part.dtype)

    def tag(t):
        rows = t.shape[axis]
        k = split_rows(rows, alpha)
        if k <= 0:
            return checkpoint_name(
                residual_substitute(t, next(keep_it)), keep_name)
        if k >= rows:
            return checkpoint_name(
                residual_substitute(t, staged_off(t)), off_name)
        lo = jax.lax.slice_in_dim(t, 0, k, axis=axis)
        hi = jax.lax.slice_in_dim(t, k, rows, axis=axis)
        lo = checkpoint_name(residual_substitute(lo, staged_off(lo)), off_name)
        hi = checkpoint_name(residual_substitute(hi, next(keep_it)), keep_name)
        return jax.lax.concatenate([lo, hi], dimension=axis)

    return tag


def checkpoint_block(fn, *, offload: bool, remat: str = "sppo",
                     mode: str = "explicit",
                     names: tuple = (OFF_NAME, KEEP_NAME),
                     codec: str = "none"):
    """Wrap a layer/slot body with the SPPO two-level policy.

    mode='explicit' (the executed path): residual placement is explicit
    dataflow from the exec tags, so the policy only pins the two named
    classes as saved.  mode='xla': the original remat-offload policy —
    placement delegated to XLA (save_and_offload_only_these_names).
    With a codec the per-row scales join the save set under their own
    name — leaving them out would let the backward replay recompute them
    from the uncompressed rows, silently rematerializing the entire
    act_off tensor on device (see ``scale_name_for``)."""
    if remat == "full":
        return jax.checkpoint(fn)   # save nothing: full recompute baseline
    if remat == "none":
        return fn
    if mode == "xla":
        return jax.checkpoint(fn, policy=sppo_policy(offload, names=names))
    save = list(names)
    if codec not in (None, "none"):
        save.append(scale_name_for(names[0]))
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.save_only_these_names(*save))
