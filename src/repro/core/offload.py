"""SPPO adaptive offloading (§5): sequence-aware ratios + two-level policy.

Two pieces, matching the paper:

1. **Sequence-aware offloading** (§5.2) — per-chunk offload ratio α_i chosen
   so the D2H transfer of chunk i hides under the compute of chunk i+1:
   α_i·A_i = M_threshold = BW_D2H · T_next_comp.  Under a FLOPs-balanced
   partition all T are equal and the paper's invariant
   α_{i-1}A_{i-1} = α_iA_i (monotone α, since A_0 ≥ A_1 ≥ …) emerges as a
   special case; the solver here works for *any* partition (length-based
   chunks have growing T_i, so α_i grows — same mechanism, general form).
   The final chunk never offloads (its backward begins immediately): α_N = 0.

2. **Two-level activation management** (§5.1) — a `jax.checkpoint` policy:
   Type-0 skeletal tensors (KV cache) are *explicit carries*, always on
   device; tagged Type-1 tensors are row-split by α into an offloaded part
   (`act_off` → pinned_host) and a device-resident part (`act_keep`);
   everything untagged (norms, rope, elementwise) is rematerialized.

Memory recurrence (paper eq. §5.2): M_i = M_{i-1} + A_i − α_{i-1}·A_{i-1},
simulated by ``peak_memory`` and asserted in tests.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import jax
from jax.ad_checkpoint import checkpoint_name


OFF_NAME = "act_off"
KEEP_NAME = "act_keep"


# ---------------------------------------------------------------------------
# 1. Sequence-aware offload ratio solver
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class OffloadPlan:
    alphas: tuple               # per-chunk offload ratio in [0, 1]
    m_threshold: float          # bytes offloaded per chunk slot (paper's M_thr)
    peak_units: float           # peak device activation memory (chunk-activation units)


def sequence_aware_alphas(act_bytes: Sequence[float],
                          comp_times: Sequence[float],
                          bw_d2h: float,
                          *, reserve_last: bool = True) -> OffloadPlan:
    """act_bytes[i]: Type-1 activation volume of chunk i;
    comp_times[i]: compute time of chunk i; bw_d2h: host-link bytes/s.

    α_i = min(1, BW · T_{i+1} / A_i): offload exactly what hides under the
    next chunk's compute.  α of the final chunk is 0 (its backward starts
    immediately — offloading it would only add H2D latency, §5.2).
    """
    n = len(act_bytes)
    alphas = []
    for i in range(n):
        if i == n - 1 and reserve_last:
            alphas.append(0.0)
            continue
        window = comp_times[i + 1] if i + 1 < n else comp_times[i]
        alphas.append(max(0.0, min(1.0, bw_d2h * window / max(act_bytes[i], 1e-9))))
    m_thr = max((a * b for a, b in zip(alphas, act_bytes)), default=0.0)
    peak = peak_memory(act_bytes, alphas)
    return OffloadPlan(tuple(alphas), m_thr, peak)


def peak_memory(act_bytes: Sequence[float], alphas: Sequence[float]) -> float:
    """Simulate M_i = M_{i-1} + A_i − α_{i-1}A_{i-1} (offload of chunk i-1
    completes during chunk i's compute); returns the forward-pass peak."""
    m = 0.0
    peak = 0.0
    prev_off = 0.0
    for a, al in zip(act_bytes, alphas):
        m += a              # chunk i activations materialize
        peak = max(peak, m)
        m -= prev_off       # previous chunk's offload drains
        prev_off = al * a
    # last chunk's offload (if any) drains after the loop
    peak = max(peak, m)
    return peak


def fixed_full_alphas(n: int) -> tuple:
    """Baseline: fixed full offloading (α=1 everywhere) — §7.2 'w/ offload'."""
    return tuple(1.0 for _ in range(n))


# ---------------------------------------------------------------------------
# 2. Two-level activation management: checkpoint policy + row-split tagging
# ---------------------------------------------------------------------------


def sppo_policy(offload: bool = True):
    """Checkpoint policy: act_keep saved on device; act_off to pinned_host.

    offload=False degrades to save-only (the 'SPPO w/o offload' ablation)."""
    if offload:
        return jax.checkpoint_policies.save_and_offload_only_these_names(
            names_which_can_be_saved=[KEEP_NAME],
            names_which_can_be_offloaded=[OFF_NAME],
            offload_src="device",
            offload_dst="pinned_host",
        )
    return jax.checkpoint_policies.save_only_these_names(KEEP_NAME, OFF_NAME)


def make_tag(alpha: float, *, axis: int = 1):
    """Row-split tagger implementing the fractional offload ratio.

    Splits a tagged activation along `axis` (the token/row dim): the first
    ⌈α·rows⌉ rows are routed to pinned_host, the rest stay on device.  α is
    static per chunk (the chunk loop is unrolled), exactly the paper's
    per-subsequence ratio."""
    alpha = float(alpha)

    def tag(t):
        if alpha <= 0.0:
            return checkpoint_name(t, KEEP_NAME)
        if alpha >= 1.0:
            return checkpoint_name(t, OFF_NAME)
        rows = t.shape[axis]
        k = max(1, min(rows - 1, int(round(rows * alpha))))
        lo = jax.lax.slice_in_dim(t, 0, k, axis=axis)
        hi = jax.lax.slice_in_dim(t, k, rows, axis=axis)
        lo = checkpoint_name(lo, OFF_NAME)
        hi = checkpoint_name(hi, KEEP_NAME)
        return jax.lax.concatenate([lo, hi], dimension=axis)

    return tag


def null_tag(t):
    """remat='none' mode: save everything on device."""
    return checkpoint_name(t, KEEP_NAME)


def checkpoint_block(fn, *, offload: bool, remat: str = "sppo"):
    """Wrap a layer/slot body with the SPPO two-level policy."""
    if remat == "full":
        return jax.checkpoint(fn)   # save nothing: full recompute baseline
    if remat == "none":
        return fn
    return jax.checkpoint(fn, policy=sppo_policy(offload))
