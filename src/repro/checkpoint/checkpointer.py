"""Sharded, async, resumable checkpointing (no external deps).

Layout on disk:
  <dir>/step_000123/
    manifest.json        # pytree structure, shapes, dtypes, integrity hashes
    leaf_00000.npy ...   # one .npy per leaf (saved from the addressable
                         # shards; restore re-shards onto the current mesh)
    data_state.json      # data-pipeline position
    COMMIT               # written last — a checkpoint without COMMIT is
                         # incomplete and ignored by restore (atomicity)

Fault-tolerance contract (DESIGN.md §7): saves are atomic (COMMIT file),
async (background thread; `wait()` joins), rolling (`keep` most recent),
and restores re-shard onto whatever mesh the restart brings up (elastic dp:
the stage-major param layout is dp-invariant).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3, async_save=True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        """Snapshot to host (blocking) then write asynchronously."""
        self.wait()
        paths, leaves, _ = _flatten_with_paths(tree)
        host = [np.asarray(l) for l in leaves]   # device->host copy, blocking

        def _write():
            final = os.path.join(self.dir, f"step_{step:09d}")
            tmp = final + ".tmp"
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            manifest = {"step": step, "leaves": []}
            for i, (p, a) in enumerate(zip(paths, host)):
                fn = f"leaf_{i:05d}.npy"
                dtype_name = str(a.dtype)
                store = a
                if a.dtype.kind not in "biufc":  # ml_dtypes (bf16/fp8): store
                    store = a.view(np.uint16 if a.dtype.itemsize == 2
                                   else np.uint8)  # as raw bits
                np.save(os.path.join(tmp, fn), store)
                manifest["leaves"].append({
                    "path": p, "file": fn, "shape": list(a.shape),
                    "dtype": dtype_name,
                    "crc": hashlib.md5(a.tobytes()[:1 << 20]).hexdigest(),
                })
            if extra:
                with open(os.path.join(tmp, "data_state.json"), "w") as f:
                    json.dump(extra, f)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            with open(os.path.join(tmp, "COMMIT"), "w") as f:
                f.write(str(time.time()))
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "COMMIT")):
                    out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[Any, int, dict]:
        """Restore into the structure of `tree_like`, placing leaves with
        `shardings` (re-sharding onto the current mesh) when given."""
        self.wait()
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        paths, leaves, treedef = _flatten_with_paths(tree_like)
        by_path = {e["path"]: e for e in manifest["leaves"]}
        out = []
        shard_leaves = (jax.tree_util.tree_leaves(shardings)
                        if shardings is not None else [None] * len(leaves))
        for p, ref, sh in zip(paths, leaves, shard_leaves):
            e = by_path[p]
            a = np.load(os.path.join(d, e["file"]))
            if a.dtype.kind in "u" and e["dtype"] not in (str(a.dtype),):
                import ml_dtypes
                a = a.view(np.dtype(getattr(ml_dtypes, e["dtype"], None)
                                    or e["dtype"]))
            assert list(a.shape) == list(ref.shape), (p, a.shape, ref.shape)
            if sh is not None:
                # transfer-lint: ok (checkpoint restore, host->device staging)
                out.append(jax.device_put(a, sh))
            else:
                # cast jax-side: numpy lacks cast kernels for ml_dtypes pairs
                # transfer-lint: ok (checkpoint restore, host->device staging)
                out.append(jax.device_put(a).astype(ref.dtype))
        extra = {}
        ds = os.path.join(d, "data_state.json")
        if os.path.exists(ds):
            with open(ds) as f:
                extra = json.load(f)
        return jax.tree_util.tree_unflatten(treedef, out), step, extra
